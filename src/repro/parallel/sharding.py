"""Logical-axis sharding rules for the LM stack.

Every parameter and activation is annotated with *logical* axis names;
``MeshRules`` maps them onto mesh axes.  Changing parallelism = changing
one rule, not touching model code -- this is where the perf hillclimb
turns its knobs (sharding is the paper's own subject matter: who owns
which slice of the problem, and what must be communicated).

Default mapping (TPU v5e pod, mesh ("data", "model") = (16, 16)):

  batch          -> ("pod","data")   data parallelism (pod extends DP)
  fsdp (params)  -> "data"           FSDP: params/opt-state sharded over
                                     DP peers *within* a pod, gathered
                                     per layer (cross-pod stays pure DP)
  heads          -> "model"          tensor parallelism (when divisible)
  mlp / experts  -> "model"          TP for dense FFN, EP for MoE
  vocab          -> "model"          vocab-parallel embedding + logits
  kv_seq         -> "model"          decode-time KV caches shard their
                                     sequence dim (flash-decoding style)
  seq            -> None             (SP hillclimb knob for prefill)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    batch: Axis = ("pod", "data")
    fsdp: Axis = "data"
    heads: Axis = "model"
    kv_heads: Axis = None
    mlp: Axis = "model"
    experts: Axis = "model"
    vocab: Axis = "model"
    seq: Axis = None            # sequence parallelism for activations
    kv_seq: Axis = "model"      # decode KV-cache sequence sharding
    d_inner: Axis = "model"     # SSM / RG-LRU channel dim
    stack: Axis = None          # stacked-layer leading dim
    # concrete mesh: when set, constraints are NamedShardings (bare
    # PartitionSpecs are silently unusable without an ambient mesh)
    mesh: Optional[Mesh] = None

    def axis(self, logical: Optional[str]) -> Axis:
        if logical is None:
            return None
        try:
            return getattr(self, logical)
        except AttributeError:
            raise KeyError(f"unknown logical axis {logical!r}") from None

    def pspec(self, *logical: Optional[str]) -> P:
        return P(*(self.axis(l) for l in logical))

    def nsharding(self, *logical: Optional[str]):
        """NamedSharding when a mesh is attached, else None (tests)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))

    def spec_tree(self, logical_tree):
        """Map a pytree of logical-name tuples to PartitionSpecs."""
        return jax.tree.map(
            lambda names: self.pspec(*names), logical_tree,
            is_leaf=lambda x: isinstance(x, tuple))

    def shardings(self, logical_tree, mesh: Mesh):
        return jax.tree.map(
            lambda names: NamedSharding(mesh, self.pspec(*names)),
            logical_tree, is_leaf=lambda x: isinstance(x, tuple))


def _mesh_axes(mesh_or_axes) -> tuple:
    if isinstance(mesh_or_axes, Mesh):
        return tuple(mesh_or_axes.axis_names)
    return tuple(mesh_or_axes)


SINGLE_POD_RULES = MeshRules(batch="data")
MULTI_POD_RULES = MeshRules(batch=("pod", "data"))


def rules_for_mesh(mesh_or_axes, **overrides) -> MeshRules:
    axes = _mesh_axes(mesh_or_axes)
    base = MULTI_POD_RULES if "pod" in axes else SINGLE_POD_RULES
    if isinstance(mesh_or_axes, Mesh):
        overrides = dict(overrides, mesh=mesh_or_axes)
    return dataclasses.replace(base, **overrides) if overrides else base


def constrain(x, rules: MeshRules, *logical: Optional[str]):
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    sh = rules.nsharding(*logical)
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
