"""Sharding rules: logical axis names -> mesh axes (DP/FSDP/TP/EP/SP)."""

from .sharding import (MeshRules, SINGLE_POD_RULES, MULTI_POD_RULES,
                       rules_for_mesh, constrain)
