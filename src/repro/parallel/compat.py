"""Version-compat shims for jax APIs that moved between releases.

The repo targets current jax (``jax.shard_map``, ``jax.make_mesh`` with
``axis_types``) but must also run on the 0.4.x line baked into CI/test
containers, where ``shard_map`` still lives in ``jax.experimental`` (with
``check_rep``/``auto`` instead of ``check_vma``/``axis_names``) and
``jax.sharding.AxisType`` does not exist.  Every call site in the repo
goes through these two helpers instead of hand-rolling try/except.
"""

from __future__ import annotations

from typing import Optional, Set

import jax


def shard_map(f, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None):
    """``jax.shard_map(..., check_vma=False)`` on new jax;
    ``jax.experimental.shard_map.shard_map(..., check_rep=False)`` on old.

    ``axis_names``: mesh axes to map manually (new-API semantics); the
    remaining axes stay under automatic propagation.  ``None`` = all.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=auto)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types when supported (newer jax
    errors on mixed implicit/explicit use otherwise); plain mesh on old
    jax, where every axis is Auto already."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except ImportError:
        return jax.make_mesh(shape, axes)
