"""Resilient long-run SNN simulation driver (segmented + elastic).

DPSNN's production runs are long-lived distributed jobs resubmitted
across MPI geometries (companion scaling study, arXiv:1511.09325); this
driver gives the distributed SNN engine the same operational envelope:

  * the single ``n_steps`` ``lax.scan`` becomes fixed-size **segments**
    driven from the host -- one compiled program reused per segment,
    with the state donated segment-to-segment (no copy, no growth in
    peak memory with run length);
  * an ``AsyncCheckpointer`` snapshot between segments, labelled by the
    simulation step ``t`` (resume works across different segment
    sizes);
  * SIGTERM preemption, bounded-retry restore-and-replay and the
    straggler watchdog are inherited from the training runtime
    (``FaultTolerantLoop``);
  * **elastic re-tiling**: a run checkpointed on tiles ``(a, b)`` can
    resume on tiles ``(c, d)`` -- neuron state and the in-flight delay
    ring are permuted by global column id (``core.retile``) while the
    synapse tables are rebuilt deterministically for the new
    decomposition from the engine seed;
  * **spike recording** (``record_events=True``): the device-side
    recorder (``obs.record``) streams every spike as a ``(step, global
    neuron id)`` event into a bounded per-segment buffer, which the
    host spooler (``obs.spool``) drains asynchronously into sharded
    append-only logs under ``<ckpt_dir>/spool``.  Per-shard spool
    offsets ride in every checkpoint manifest, and every restore
    truncates the logs back to that frontier, so preemption/failure
    replay yields each event exactly once.  The spool is also the
    *only* per-step spike record the driver keeps: ``spike_counts()``
    reads it back (the former per-step host dict is gone -- it
    duplicated the spool and grew without bound on long runs);
  * **plasticity** (``dist_cfg.engine.stdp`` set): the STDP weight
    tables and pre/post traces ride in the scan carry
    (``state["plastic"]``, see ``core.dist_engine``), so every
    checkpoint snapshots the learned weights alongside the neuron
    state and a preempted plastic run resumes bit-identically.  Across
    an elastic retile the *realization itself* is relaid by global
    (pre, post) synapse id (``core.retile.retile_tables``) -- never
    re-sampled, which would silently discard all learning.  The
    checkpoint meta records the STDP parameters (a static checkpoint
    can never resume plastic, nor across an STDP-parameter change) and
    ``born_tiles``, the tiling the realization was sampled on, from
    which any later tiling's table layout is derived deterministically.

  * **ensembles** (``dist_cfg.ensemble_seeds`` set): the driver runs M
    member realizations through the one compiled segment function
    (state stacked on a member axis, see ``core.dist_engine``), drains
    each member's recorder rows into its own ``member_{m:03d}/`` spool
    stream, and carries the member seeds in the checkpoint meta --
    preempt→resume restores every member's carry and spool frontier
    exactly-once.  Elastic retiling of ensembles is refused (resume on
    the checkpointed tiling).

The tiling, grid, seed and connectivity law of the saved state ride
inside each checkpoint's manifest (atomic with the checkpoint), so a
resuming process detects a geometry change -- and refuses a silently
different model -- without guessing from array shapes.

Cumulative metric totals (spikes/events/dropped) are **global scalars**:
the manifest carries ``metric_base`` (totals lost to state zeroing at
an elastic retile) and ``metric_totals`` (base + current state sums),
and every total the driver reports adds the base back -- so totals are
identical whatever tiling history a run went through.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..checkpoint.store import (checkpoint_meta, latest_step,
                                refuse_meta_drift, restore_checkpoint)
from ..core.dist_engine import (DistConfig, SimInputs, abstract_dist_inputs,
                                build_dist_inverse_index, build_dist_tables,
                                dist_shardings, fold_plastic_tables,
                                init_dist_plastic_state, init_dist_state,
                                make_sim_fn)
from ..core.retile import (gather_synapse_stream, retile_config,
                           retile_plastic, retile_state, retile_tables)
from ..core.synapses import TableStorage, compress_tables
from ..obs.telemetry import NULL, Telemetry
from .driver import DriverConfig, FaultTolerantLoop, log

METRIC_KEYS = ("spikes", "events", "dropped")


def sim_fingerprint(dist_cfg: DistConfig, segment_steps: int, recorder,
                    storage) -> tuple:
    """Cache key for the compiled segment function.

    Everything that shapes the traced program is in the key; everything
    that only changes *values* is normalized out -- the seed (tables
    are data, not structure), the member seeds (only the ensemble width
    M is a shape), the state seed.  Two jobs differing only in seeds
    therefore share one compiled step when constructed with the same
    ``sim_cache`` dict -- the server's resident-mesh contract.
    """
    e = dataclasses.replace(dist_cfg.engine, seed=0, state_seed=None)
    seeds = dist_cfg.ensemble_seeds
    dc = dataclasses.replace(
        dist_cfg, engine=e,
        ensemble_seeds=None if seeds is None
        else tuple(range(len(seeds))))
    return (repr(dc), int(segment_steps), repr(recorder),
            repr(storage.meta() if storage is not None else None))


class SimDriver(FaultTolerantLoop):
    """Segmented, checkpointed distributed SNN simulation.

    ``run(n_steps)`` advances the simulation to step ``n_steps`` (rounded
    up to a whole segment) in segments of ``segment_steps``; the loop's
    step counter *is* the simulation time ``t``, so checkpoint labels
    and resume targets are sim steps, not segment indices.

    ``cfg.ckpt_every`` counts **segments** between checkpoints.
    ``allow_retile=True`` permits resuming a checkpoint written under a
    different tiling (state is relaid out by global column id; plastic
    weight tables by global synapse id).
    ``preempt_after_segments`` deterministically simulates a SIGTERM
    after that many segments (counted in this process) -- the driver
    checkpoints at the segment boundary and exits, exactly like the
    signal path.

    ``record_events=True`` turns on the spike observatory;
    ``record_capacity`` overrides the per-shard per-segment event
    buffer (default: the no-drop bound ``active_cap_local *
    segment_steps``; overflow is counted, never silent).  Recording is
    a pure observer -- spike trains are bit-identical with it on or
    off -- but for *complete* logs it must be enabled for the whole
    run: segments simulated with recording off are simply absent from
    the spool.
    """

    def __init__(self, cfg: DriverConfig, dist_cfg: DistConfig, mesh,
                 segment_steps: int,
                 allow_retile: bool = False,
                 fault_hook: Optional[Callable] = None,
                 preempt_after_segments: Optional[int] = None,
                 record_events: bool = False,
                 record_capacity: Optional[int] = None,
                 telemetry: Telemetry = NULL,
                 sim_cache: Optional[dict] = None,
                 job_meta: Optional[dict] = None):
        super().__init__(cfg, telemetry=telemetry)
        if segment_steps <= 0:
            raise ValueError(f"segment_steps={segment_steps} must be > 0")
        self.dist_cfg = dist_cfg
        self.mesh = mesh
        self.step_size = segment_steps
        self.allow_retile = allow_retile
        self.fault_hook = fault_hook
        self._preempt_after = preempt_after_segments
        self._segments_done = 0
        e = dist_cfg.engine
        self.plastic = e.stdp is not None
        self.n_members = dist_cfg.n_members
        self._job_meta = job_meta

        # ---- synapse tables ------------------------------------------
        # A plastic realization is *born* on one tiling and relaid to
        # every later one by global synapse id (re-sampling would build
        # a different network under the learned weights).  The birth
        # tiling rides in the checkpoint meta.
        self._born_tiles = dist_cfg.tiles
        if self.plastic:
            last0 = latest_step(cfg.ckpt_dir)
            if last0 is not None:
                born = checkpoint_meta(cfg.ckpt_dir, last0).get("born_tiles")
                if born:
                    self._born_tiles = tuple(born)
        self._birth_tables = None
        if self.plastic and self._born_tiles != dist_cfg.tiles:
            from ..core.synapses import materialized_table_bytes
            born_cfg = retile_config(dist_cfg, *self._born_tiles)
            birth, self.table_stats = build_dist_tables(born_cfg)
            self._birth_tables = jax.tree.map(np.asarray, birth)
            # relayed at the analytic caps, then compressed: the caps
            # derive from the realized occupancy, which the relay
            # preserves exactly, so any process resuming on this tiling
            # reconstructs the identical storage descriptor
            tables = compress_tables(retile_tables(
                self._birth_tables, born_cfg.engine.decomp,
                born_cfg.engine.spec(), e.decomp, e.spec()))
            ty, tx = dist_cfg.tiles
            self.table_stats = dict(
                self.table_stats,
                table_bytes_per_shard=materialized_table_bytes(
                    tables, ty * tx))
        else:
            tables, self.table_stats = build_dist_tables(dist_cfg)
            if self.plastic:
                self._birth_tables = jax.tree.map(np.asarray, tables)
        # the materialized (compressed) storage descriptor: everything
        # that sizes shapes from the spec -- shardings, the delivery
        # plan, plastic weight abstracts, checkpoint meta -- goes
        # through it
        self.storage = tables.storage
        self._state_sh, table_sh = dist_shardings(dist_cfg, mesh,
                                                  self.storage)
        self._tables_host = (jax.tree.map(np.asarray, tables)
                             if self.plastic else None)
        if self.plastic:
            # the plastic carry is the single live weight copy; the
            # resident tables keep only the int8 plastic mask
            tables = fold_plastic_tables(tables)
        self.tables = jax.device_put(tables, table_sh)
        self._inv_slots = None
        if self.plastic:
            slots, _ = build_dist_inverse_index(dist_cfg, self._tables_host)
            self._inv_slots = jax.device_put(
                slots, NamedSharding(mesh, dist_cfg.pspec(2)))
            # the birth-weight stream is constant over the driver's
            # lifetime; gather it once for plastic_summary's drift stats
            self._birth_stream = gather_synapse_stream(
                self._tables_host, e.decomp, e.spec())

        # cumulative totals not represented in the (possibly retiled)
        # device state -- see module docstring
        self._metric_base = {k: 0.0 for k in METRIC_KEYS}
        # previous segment's cumulative totals: per-segment *deltas* in
        # the telemetry stream come from here (reset on every restore,
        # so replayed segments report their own deltas, not the gap to
        # the abandoned timeline)
        self._prev_totals: Optional[Dict[str, float]] = None
        self.recorder = None
        self.spool = None
        self.recorder_dropped = 0
        if record_events:
            from ..obs.record import recorder_spec, stacked_gid_maps
            from ..obs.spool import SpikeSpooler
            d = e.decomp
            self.recorder = recorder_spec(e, segment_steps,
                                          capacity=record_capacity)
            self._gids = jax.device_put(
                jnp.asarray(stacked_gid_maps(d)),
                NamedSharding(mesh, dist_cfg.pspec(1)))
            header = {"grid": [d.grid.height, d.grid.width,
                               d.grid.n_per_column],
                      "law": e.law.kind, "seed": e.seed,
                      "dt_ms": e.lif.dt_ms,
                      "n_neurons": d.grid.n_neurons,
                      "recorder_capacity": self.recorder.capacity}
            if self.n_members is None:
                # the member spoolers carry their own state_seed; a
                # solo spool records it at the top so an ensemble
                # member's stream is comparable header-for-header
                header["state_seed"] = e.state_seed_value
            self.spool = SpikeSpooler(
                os.path.join(cfg.ckpt_dir, "spool"), dist_cfg.tiles,
                header=header, telemetry=telemetry,
                members=dist_cfg.ensemble_seeds)
        # the driver never consumes the per-step spike output (the
        # spool is the per-step record), so don't materialize it.
        # ``sim_cache``: a caller-owned dict (the job server's resident
        # compiled-mesh cache) mapping ``sim_fingerprint`` keys to the
        # jitted segment fn -- jobs differing only in seeds share one
        # compiled step.
        self._sim_key = sim_fingerprint(dist_cfg, segment_steps,
                                        self.recorder, self.storage)
        self._sim = None if sim_cache is None else sim_cache.get(
            self._sim_key)
        if self._sim is None:
            self._sim = make_sim_fn(dist_cfg, mesh, segment_steps,
                                    record_rate=False,
                                    recorder=self.recorder,
                                    storage=self.storage)
            if sim_cache is not None:
                sim_cache[self._sim_key] = self._sim
        self._sim_inputs = SimInputs(
            tables=self.tables, inv_slots=self._inv_slots,
            gids=self._gids if self.recorder is not None else None)

    # ---- checkpoint metadata (identity of the saved state) ------------
    def _meta(self) -> dict:
        from ..core.synapses import TABLE_REALIZATION_VERSION
        e = self.dist_cfg.engine
        d = e.decomp
        return {"tiles_y": d.tiles_y, "tiles_x": d.tiles_x,
                "grid": [d.grid.height, d.grid.width, d.grid.n_per_column],
                "law": e.law.kind, "radius": d.radius, "seed": e.seed,
                "state_seed": e.state_seed_value,
                "table_realization": TABLE_REALIZATION_VERSION,
                "storage": self.storage.meta(),
                # repro-lint: ignore[meta-drift] report-only: resume is
                # bit-identical across segment sizes by design
                "segment_steps": self.step_size,
                "stdp": (dataclasses.asdict(e.stdp)
                         if self.plastic else None),
                "ensemble_seeds": (None if self.n_members is None
                                   else list(self.dist_cfg.ensemble_seeds)),
                # repro-lint: ignore[meta-drift] report-only: full job
                # provenance (the typed SimJobSpec); identity fields are
                # refused individually above
                "job": self._job_meta,
                "born_tiles": (list(self._born_tiles)
                               if self.plastic else None),
                "metric_base": dict(self._metric_base)}

    def _save(self, step: int, state):
        # meta rides inside the checkpoint's manifest: atomic with the
        # checkpoint, so a crash can never publish meta describing a
        # tiling (or spool frontier) the newest on-disk checkpoint does
        # not have
        meta = self._meta()
        # repro-lint: ignore[meta-drift] report-only running totals; the
        # resumable base rides (and is validated via) 'metric_base'
        meta["metric_totals"] = self.metric_totals(state)
        if self.spool is not None:
            # the manifest's spool offsets must never reference bytes
            # that are not yet durable: a hard crash between manifest
            # publish and the spool worker's write would otherwise leave
            # logs permanently shorter than every manifest's frontier --
            # an unresumable run.  Drain the (small) spool queue first.
            with self.tel.span("ckpt.spool_sync", step=step):
                self.spool.wait()
            meta["spool_offsets"] = self.spool.offsets()
            meta["recorder_dropped"] = self.recorder_dropped
        self.ckpt.save(step, state, meta=meta)

    # ---- restore / init ----------------------------------------------
    def _restore_or_init(self):
        last = latest_step(self.cfg.ckpt_dir)
        self._prev_totals = None           # deltas restart per timeline
        if last is None:
            self._metric_base = {k: 0.0 for k in METRIC_KEYS}
            with self.tel.span("restore.init"):
                if self.spool is not None:
                    self.spool.truncate({})
                state = init_dist_state(self.dist_cfg)
                if self.plastic:
                    # from the host build tables: the device tables
                    # carry only the folded int8 mask, not the build
                    # weights
                    state["plastic"] = init_dist_plastic_state(
                        self.dist_cfg, self._tables_host)
                state = jax.device_put(state, self._state_sh)
            return 0, state
        d = self.dist_cfg.engine.decomp
        meta = checkpoint_meta(self.cfg.ckpt_dir, last)
        mine = self._meta()
        # plasticity identity first: the plastic weight tables live in
        # the checkpointed state, so a static checkpoint cannot resume
        # plastic (there are no tables to continue from), a plastic
        # checkpoint cannot resume static (the learned weights would be
        # silently replaced by the seed realization), and an STDP
        # parameter change mid-run is a different model
        theirs = meta.get("stdp")
        if theirs != mine["stdp"]:
            raise ValueError(
                f"checkpoint in {self.cfg.ckpt_dir} was written with "
                f"stdp={theirs} but the current config has "
                f"stdp={mine['stdp']} -- a plastic run resumes only a "
                "checkpoint with identical STDP parameters, and a "
                "static run only a static checkpoint")
        # the state relayout is only valid for the *same model*: grid,
        # connectivity law, synapse seed AND sampling-procedure version
        # must match -- same seed under a different table_realization
        # rebuilds a different network (keys absent from older
        # checkpoints are skipped: pre-versioning manifests)
        refuse_meta_drift(
            meta, mine,
            ("grid", "law", "radius", "seed", "state_seed",
             "ensemble_seeds", "table_realization"),
            self.cfg.ckpt_dir)
        base = meta.get("metric_base", {})
        self._metric_base = {k: float(base.get(k, 0.0))
                             for k in METRIC_KEYS}
        old_tiles = (meta.get("tiles_y", d.tiles_y),
                     meta.get("tiles_x", d.tiles_x))
        if old_tiles == (d.tiles_y, d.tiles_x):
            # same tiling => deterministically the same storage
            # descriptor; drift means the checkpointed bytes (weight
            # dtype, compressed caps) no longer describe this build --
            # refuse rather than reinterpret (keys absent from older
            # manifests are skipped by refuse_meta_drift)
            refuse_meta_drift(meta, mine, ("storage",), self.cfg.ckpt_dir)
            self.tel.event("resume", logger=log,
                           msg=f"resuming from sim step {last}",
                           step=last)
            with self.tel.span("restore.load", step=last):
                state = restore_checkpoint(
                    self.cfg.ckpt_dir, last,
                    abstract_dist_inputs(self.dist_cfg, self.storage)[0],
                    shardings=self._state_sh)
        else:
            if self.n_members is not None:
                raise ValueError(
                    f"checkpoint tiling {old_tiles} != configured "
                    f"{(d.tiles_y, d.tiles_x)} on an ensemble run: "
                    "elastic retiling of the stacked member axis is not "
                    "supported yet -- resume ensembles on the tiling "
                    "they were checkpointed under")
            if not self.allow_retile:
                raise ValueError(
                    f"checkpoint tiling {old_tiles} != configured "
                    f"{(d.tiles_y, d.tiles_x)}; pass allow_retile=True "
                    "(CLI: --retile) to relayout the state")
            self.tel.event(
                "resume", logger=log,
                msg=f"resuming from sim step {last} with retile "
                    f"{old_tiles} -> {(d.tiles_y, d.tiles_x)}",
                step=last, old_tiles=list(old_tiles),
                new_tiles=[d.tiles_y, d.tiles_x])
            with self.tel.span("restore.retile", step=last,
                               old_tiles=list(old_tiles),
                               new_tiles=[d.tiles_y, d.tiles_x]):
                old_cfg = retile_config(self.dist_cfg, *old_tiles)
                # the old tiling's storage descriptor (compressed caps,
                # weight dtype) sizes the checkpointed plastic weight
                # tiers; it rides in the manifest (any checkpoint new
                # enough to pass the table_realization gate carries it)
                old_storage = (TableStorage.from_meta(meta["storage"])
                               if meta.get("storage") is not None
                               else old_cfg.engine.spec().storage())
                host_state = restore_checkpoint(
                    self.cfg.ckpt_dir, last,
                    abstract_dist_inputs(old_cfg, old_storage)[0])
                # the relayout zeroes per-tile metrics: fold the
                # restored partial sums into the global base so totals
                # survive the retile exactly (whatever tiling we came
                # from)
                for k in METRIC_KEYS:
                    self._metric_base[k] += float(
                        np.sum(np.asarray(host_state["metrics"][k])))
                plastic_host = host_state.pop("plastic", None)
                state = retile_state(host_state, old_cfg.engine.decomp,
                                     d)
                if self.plastic:
                    # the checkpointed weights are laid out for the
                    # *old* tiling's structure (itself a deterministic
                    # relay of the birth realization); relay them
                    # onward by global synapse id
                    old_d = old_cfg.engine.decomp
                    old_spec = old_cfg.engine.spec()
                    if old_tiles == self._born_tiles:
                        old_tabs = self._birth_tables
                    else:
                        born_cfg = retile_config(self.dist_cfg,
                                                 *self._born_tiles)
                        # compressed exactly as the old process built
                        # them (the relay preserves per-row occupancy,
                        # so the realized caps -- and hence the
                        # checkpointed w shapes -- are reproduced
                        # deterministically)
                        old_tabs = compress_tables(retile_tables(
                            self._birth_tables, born_cfg.engine.decomp,
                            born_cfg.engine.spec(), old_d, old_spec))
                    state["plastic"] = retile_plastic(
                        plastic_host, old_tabs, old_d, old_spec, d,
                        self.dist_cfg.engine.spec(),
                        storage=self.storage)
                state = jax.device_put(state, self._state_sh)
        if self.spool is not None:
            # exactly-once: cut every log back to this checkpoint's
            # frontier; replayed segments re-append their events
            with self.tel.span("spool.truncate", step=last):
                self.spool.truncate(meta.get("spool_offsets", {}))
            self.recorder_dropped = int(meta.get("recorder_dropped", 0))
        return last, state

    # ---- one segment --------------------------------------------------
    def _step_once(self, state, step):
        if self.fault_hook:
            self.fault_hook(step)
        t0 = time.perf_counter()
        with self.tel.span("segment.compute", step=step):
            if self.recorder is not None:
                state, _, rec = self._sim(state, self._sim_inputs)
            else:
                state, _ = self._sim(state, self._sim_inputs)
            if self.tel.enabled:
                # fence so the span covers the device work it
                # dispatched, not just the host-side dispatch.  Pure
                # observer: the run loop blocks on this segment's
                # metrics immediately after anyway -- tracing only
                # moves the wait inside the span.
                jax.block_until_ready(state)
        d_rec_dropped = 0
        if self.recorder is not None:
            with self.tel.span("segment.spool_drain", step=step):
                d_rec_dropped = self._drain_recorder(rec, step)
        self._segments_done += 1
        if self._preempt_after is not None \
                and self._segments_done >= self._preempt_after:
            self.preempted = True
        totals = self.metric_totals(state)
        prev = self._prev_totals or {k: 0.0 for k in METRIC_KEYS}
        delta = {k: totals[k] - prev[k] for k in METRIC_KEYS}
        self._prev_totals = totals
        if delta["dropped"] > 0:
            # at most once per segment, with the segment's own delta
            # (the old run-level warning fired once and went silent
            # however much worse the overflow got)
            self.tel.event(
                "delivery_drops", level="warning", logger=log,
                msg=f"event-delivery compaction dropped "
                    f"{int(delta['dropped'])} spike(s) this segment "
                    f"({int(totals['dropped'])} total; active_cap "
                    "overflow) -- results undercount synaptic events; "
                    "raise EngineConfig.cap_headroom",
                step=step, dropped=int(delta["dropped"]),
                dropped_total=int(totals["dropped"]))
        wall = time.perf_counter() - t0
        self.tel.metrics(
            "segment", step=step, wall_s=wall,
            steps_per_s=self.step_size / max(wall, 1e-9),
            d_spikes=delta["spikes"], d_events=delta["events"],
            d_dropped=delta["dropped"],
            d_recorder_dropped=float(d_rec_dropped),
            spikes=totals["spikes"], events=totals["events"],
            dropped=totals["dropped"])
        metrics = {"sim_t": jnp.max(state["t"]),
                   "spikes": totals["spikes"], "events": totals["events"],
                   "dropped": totals["dropped"],
                   "d_spikes": delta["spikes"],
                   "d_events": delta["events"],
                   "d_dropped": delta["dropped"],
                   "d_recorder_dropped": float(d_rec_dropped)}
        return state, metrics

    def _drain_recorder(self, rec, step=None) -> int:
        """Spool one segment's event buffers (all shards, all ensemble
        members); returns the segment's recorder-overflow drop count."""
        rec_h = jax.device_get(rec)
        ty, tx = self.dist_cfg.tiles
        for y in range(ty):
            for x in range(tx):
                if self.n_members is None:
                    cnt = int(rec_h["count"][y, x])
                    self.spool.append(y, x, rec_h["step"][y, x, :cnt],
                                      rec_h["gid"][y, x, :cnt])
                    continue
                for m in range(self.n_members):
                    cnt = int(rec_h["count"][y, x, m])
                    self.spool.append(
                        y, x, rec_h["step"][y, x, m, :cnt],
                        rec_h["gid"][y, x, m, :cnt], member=m)
        seg_dropped = int(np.sum(rec_h["dropped"]))
        if seg_dropped:
            self.recorder_dropped += seg_dropped
            self.tel.event(
                "recorder_drops", level="warning", logger=log,
                msg=f"spike recorder dropped {seg_dropped} event(s) "
                    f"this segment ({self.recorder_dropped} total) -- "
                    "raise record_capacity (CLI: --record-cap) for "
                    "complete logs",
                step=step, dropped=seg_dropped,
                dropped_total=self.recorder_dropped)
        return seg_dropped

    # ---- host-side views ----------------------------------------------
    def metric_totals(self, state) -> Dict[str, float]:
        """Cumulative run totals: the manifest-carried base (history
        predating an elastic retile) plus the live state's per-tile
        partial sums.  Tiling-independent by construction."""
        return {k: self._metric_base[k]
                + float(np.asarray(jnp.sum(state["metrics"][k])))
                for k in METRIC_KEYS}

    def firing_rate_hz(self, state) -> float:
        """Mean rate over the whole run (active neurons), retile-proof:
        uses ``metric_totals`` rather than raw state sums."""
        t = int(np.asarray(jnp.max(state["t"])))
        n_active = float(np.asarray(jnp.sum(state["active"])))
        sim_sec = t * self.dist_cfg.engine.lif.dt_ms * 1e-3
        return self.metric_totals(state)["spikes"] \
            / max(n_active, 1.0) / max(sim_sec, 1e-9)

    def spike_counts(self, n_steps: Optional[int] = None,
                     member: Optional[int] = None) -> np.ndarray:
        """Global per-step spike counts, read back from the spooled
        spike logs (sim step order; the exactly-once truncation
        contract guarantees replayed segments appear once).  Covers the
        whole run recorded into this checkpoint directory -- including
        segments written by earlier processes of a resumed run.

        ``n_steps`` fixes the returned length (steps past the last
        spike would otherwise be trimmed).  Requires
        ``record_events=True``: the spool *is* the per-step record (the
        former per-step host dict duplicated it and grew unboundedly).
        Ensemble runs read one member's stream -- pass ``member``.
        """
        if self.spool is None:
            raise ValueError(
                "spike_counts() reads the spike spool; construct the "
                "driver with record_events=True")
        from ..obs.spool import RECORD_DTYPE, member_name, shard_events
        if (member is None) != (self.n_members is None):
            raise ValueError(
                f"spike_counts(member={member!r}) on a driver with "
                f"n_members={self.n_members!r}: pass a member index "
                "exactly when the run is an ensemble")
        self.spool.wait()
        d = self.spool.directory
        if member is not None:
            d = os.path.join(d, member_name(member))
        shards = list(shard_events(d).values())
        ev = (np.concatenate(shards) if shards
              else np.empty(0, RECORD_DTYPE))
        if n_steps is None:
            n_steps = int(ev["step"].max()) + 1 if len(ev) else 0
        return np.bincount(ev["step"], minlength=n_steps)[:n_steps] \
            .astype(np.float32)

    def plastic_summary(self, state, member: Optional[int] = None) -> dict:
        """Tiling-invariant digest of the live plastic tables.

        ``weight_checksum`` hashes every synapse's ``(pre_gid,
        post_gid, dslot, weight-bits)`` record in canonical (sorted)
        order, so two runs agree iff their learned weights are
        bit-identical per global synapse -- whatever tilings either
        went through.  Drift stats compare against the birth weights.
        Ensemble runs digest one member's carried weights -- pass
        ``member``.
        """
        if not self.plastic:
            raise ValueError("plastic_summary() needs a plastic engine "
                             "(EngineConfig.stdp set)")
        if (member is None) != (self.n_members is None):
            raise ValueError(
                f"plastic_summary(member={member!r}) on a driver with "
                f"n_members={self.n_members!r}: pass a member index "
                "exactly when the run is an ensemble")
        e = self.dist_cfg.engine
        d, spec = e.decomp, e.spec()
        pl = state["plastic"]
        pick = ((lambda w: np.asarray(w)) if member is None
                else (lambda w: np.asarray(w)[:, :, member]))
        live_tabs = {
            "local": dict(self._tables_host["local"],
                          w=pick(pl["w"][0])),
            "halo": [dict(t, w=pick(pw)) for t, pw in
                     zip(self._tables_host["halo"], pl["w"][1:])],
        }
        live = gather_synapse_stream(live_tabs, d, spec)
        birth = self._birth_stream        # same gather order as `live`
        w = np.ascontiguousarray(live["w"])
        wbits = w.view({2: np.uint16, 4: np.uint32,
                        8: np.uint64}[w.dtype.itemsize])
        order = np.lexsort((wbits, live["dslot"], live["post"],
                            live["pre"]))
        rec = np.column_stack([
            live["pre"][order], live["post"][order],
            live["dslot"][order].astype(np.int64),
            wbits[order].astype(np.int64)]).astype(np.int64)
        checksum = hashlib.sha256(
            np.ascontiguousarray(rec).tobytes()).hexdigest()
        mask = birth["w"] > 0
        return {
            "weight_checksum": checksum,
            "n_synapses": int(len(w)),
            "n_plastic": int(mask.sum()),
            "w_sum": float(w.sum()),
            "w_l1_delta": float(np.abs(w - birth["w"])[mask].sum()),
        }

    def compiled_step_cache_size(self) -> Optional[int]:
        """Compiled-program count of this driver's segment function
        (``None`` when the runtime lacks jit cache introspection).
        Stays 1 however many segments -- and, through a shared
        ``sim_cache``, however many same-shaped jobs -- ran through it:
        the one-compile contract the ensemble service asserts in CI."""
        return (self._sim._cache_size()
                if hasattr(self._sim, "_cache_size") else None)

    def run(self, n_steps: int):
        out = super().run(n_steps)
        if self.spool is not None:
            self.spool.wait()            # logs durable before we report
        return out
