"""Resilient long-run SNN simulation driver (segmented + elastic).

DPSNN's production runs are long-lived distributed jobs resubmitted
across MPI geometries (companion scaling study, arXiv:1511.09325); this
driver gives the distributed SNN engine the same operational envelope:

  * the single ``n_steps`` ``lax.scan`` becomes fixed-size **segments**
    driven from the host -- one compiled program reused per segment,
    with the state donated segment-to-segment (no copy, no growth in
    peak memory with run length);
  * an ``AsyncCheckpointer`` snapshot between segments, labelled by the
    simulation step ``t`` (resume works across different segment
    sizes);
  * SIGTERM preemption, bounded-retry restore-and-replay and the
    straggler watchdog are inherited from the training runtime
    (``FaultTolerantLoop``);
  * **elastic re-tiling**: a run checkpointed on tiles ``(a, b)`` can
    resume on tiles ``(c, d)`` -- neuron state and the in-flight delay
    ring are permuted by global column id (``core.retile``) while the
    synapse tables are rebuilt deterministically for the new
    decomposition from the engine seed.

The tiling, grid, seed and connectivity law of the saved state ride
inside each checkpoint's manifest (atomic with the checkpoint), so a
resuming process detects a geometry change -- and refuses a silently
different model -- without guessing from array shapes.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import numpy as np
import jax.numpy as jnp

from ..checkpoint.store import (checkpoint_meta, latest_step,
                                restore_checkpoint)
from ..core.dist_engine import (DistConfig, abstract_dist_inputs,
                                build_dist_tables, dist_shardings,
                                init_dist_state, make_sim_fn)
from ..core.retile import retile_config, retile_state
from .driver import DriverConfig, FaultTolerantLoop, log


class SimDriver(FaultTolerantLoop):
    """Segmented, checkpointed distributed SNN simulation.

    ``run(n_steps)`` advances the simulation to step ``n_steps`` (rounded
    up to a whole segment) in segments of ``segment_steps``; the loop's
    step counter *is* the simulation time ``t``, so checkpoint labels
    and resume targets are sim steps, not segment indices.

    ``cfg.ckpt_every`` counts **segments** between checkpoints.
    ``allow_retile=True`` permits resuming a checkpoint written under a
    different tiling (state is relaid out by global column id).
    ``preempt_after_segments`` deterministically simulates a SIGTERM
    after that many segments (counted in this process) -- the driver
    checkpoints at the segment boundary and exits, exactly like the
    signal path.
    """

    def __init__(self, cfg: DriverConfig, dist_cfg: DistConfig, mesh,
                 segment_steps: int, record_spikes: bool = True,
                 allow_retile: bool = False,
                 fault_hook: Optional[Callable] = None,
                 preempt_after_segments: Optional[int] = None):
        super().__init__(cfg)
        if segment_steps <= 0:
            raise ValueError(f"segment_steps={segment_steps} must be > 0")
        self.dist_cfg = dist_cfg
        self.mesh = mesh
        self.step_size = segment_steps
        self.record_spikes = record_spikes
        self.allow_retile = allow_retile
        self.fault_hook = fault_hook
        self._preempt_after = preempt_after_segments
        self._segments_done = 0
        self._state_sh, table_sh = dist_shardings(dist_cfg, mesh)
        tables, self.table_stats = build_dist_tables(dist_cfg)
        self.tables = jax.device_put(tables, table_sh)
        self._sim = make_sim_fn(dist_cfg, mesh, segment_steps)
        # per-step global spike counts keyed by segment start step:
        # replayed segments overwrite their slot instead of duplicating
        self._spikes: Dict[int, np.ndarray] = {}

    # ---- checkpoint metadata (identity of the saved state) ------------
    def _meta(self) -> dict:
        from ..core.synapses import TABLE_REALIZATION_VERSION
        e = self.dist_cfg.engine
        d = e.decomp
        return {"tiles_y": d.tiles_y, "tiles_x": d.tiles_x,
                "grid": [d.grid.height, d.grid.width, d.grid.n_per_column],
                "law": e.law.kind, "radius": d.radius, "seed": e.seed,
                "table_realization": TABLE_REALIZATION_VERSION,
                "segment_steps": self.step_size}

    def _save(self, step: int, state):
        # meta rides inside the checkpoint's manifest: atomic with the
        # checkpoint, so a crash can never publish meta describing a
        # tiling the newest on-disk checkpoint does not have
        self.ckpt.save(step, state, meta=self._meta())

    # ---- restore / init ----------------------------------------------
    def _restore_or_init(self):
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            state = jax.device_put(init_dist_state(self.dist_cfg),
                                   self._state_sh)
            return 0, state
        d = self.dist_cfg.engine.decomp
        meta = checkpoint_meta(self.cfg.ckpt_dir, last)
        mine = self._meta()
        # the state relayout is only valid for the *same model*: grid,
        # connectivity law, synapse seed AND sampling-procedure version
        # must match -- same seed under a different table_realization
        # rebuilds a different network (keys absent from older
        # checkpoints are skipped: pre-versioning manifests)
        for key in ("grid", "law", "radius", "seed", "table_realization"):
            if key in meta and meta[key] != mine[key]:
                raise ValueError(
                    f"checkpoint in {self.cfg.ckpt_dir} was written with "
                    f"{key}={meta[key]}, current config has "
                    f"{key}={mine[key]} -- resuming would silently "
                    "continue a different model")
        old_tiles = (meta.get("tiles_y", d.tiles_y),
                     meta.get("tiles_x", d.tiles_x))
        if old_tiles == (d.tiles_y, d.tiles_x):
            log.info("resuming from sim step %d", last)
            state = restore_checkpoint(
                self.cfg.ckpt_dir, last, abstract_dist_inputs(self.dist_cfg)[0],
                shardings=self._state_sh)
        else:
            if not self.allow_retile:
                raise ValueError(
                    f"checkpoint tiling {old_tiles} != configured "
                    f"{(d.tiles_y, d.tiles_x)}; pass allow_retile=True "
                    "(CLI: --retile) to relayout the state")
            log.info("resuming from sim step %d with retile %s -> %s",
                     last, old_tiles, (d.tiles_y, d.tiles_x))
            old_cfg = retile_config(self.dist_cfg, *old_tiles)
            host_state = restore_checkpoint(
                self.cfg.ckpt_dir, last, abstract_dist_inputs(old_cfg)[0])
            state = retile_state(host_state, old_cfg.engine.decomp, d)
            state = jax.device_put(state, self._state_sh)
        return last, state

    def _on_rewind(self, step: int):
        super()._on_rewind(step)
        self._spikes = {k: v for k, v in self._spikes.items() if k < step}

    # ---- one segment --------------------------------------------------
    def _step_once(self, state, step):
        if self.fault_hook:
            self.fault_hook(step)
        state, per_step = self._sim(state, self.tables)
        self._segments_done += 1
        if self._preempt_after is not None \
                and self._segments_done >= self._preempt_after:
            self.preempted = True
        if self.record_spikes:
            self._spikes[step] = np.asarray(per_step).sum(axis=(0, 1))
        m = state["metrics"]
        metrics = {"sim_t": jnp.max(state["t"]),
                   "spikes": jnp.sum(m["spikes"]),
                   "events": jnp.sum(m["events"]),
                   "dropped": jnp.sum(m["dropped"])}
        return state, metrics

    # ---- host-side views ----------------------------------------------
    def spike_counts(self) -> np.ndarray:
        """Global per-step spike counts recorded by this process, in sim
        step order (replayed segments appear once)."""
        if not self._spikes:
            return np.zeros((0,), np.float32)
        return np.concatenate(
            [self._spikes[k] for k in sorted(self._spikes)])
