"""Fault-tolerant host-side driver loops.

What a 1000-node run actually needs from the host-side loop:

  * **checkpoint/restart** -- periodic async checkpoints; on start, the
    driver resumes from the latest verified step (data pipeline is
    stateless-counter-based, so the stream realigns for free);
  * **failure retry** -- a failing step (device OOM, interconnect error,
    injected test fault) triggers restore-from-last-good and replay;
    bounded retries, exponential backoff;
  * **straggler watchdog** -- a per-step deadline derived from a moving
    median of step times; overruns are logged with the step fingerprint
    (on real pods this feeds the scheduler's hot-spare swap; here it is
    surfaced in driver metrics and tested by injection);
  * **preemption** -- SIGTERM flips a flag; the loop checkpoints at the
    next step boundary and exits cleanly (maintenance-event protocol);
  * **elastic restart** -- checkpoints restore onto a different mesh via
    resharding (see checkpoint.store), exercised in tests.

All of that machinery lives in ``FaultTolerantLoop`` and is shared by
the two concrete drivers: ``TrainDriver`` (LM training, unit = one
optimizer step) and ``runtime.sim_driver.SimDriver`` (long-run SNN
simulation, unit = one fixed-size scan segment).
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint.store import (AsyncCheckpointer, latest_step,
                                restore_checkpoint)
from ..obs.telemetry import NULL, Telemetry

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_retries: int = 3
    backoff_s: float = 1.0
    straggler_factor: float = 3.0    # deadline = factor * median step
    straggler_window: int = 20
    handle_sigterm: bool = True


class StragglerWatchdog:
    """Moving-median deadline; flags steps that exceed it.  Stalls are
    structured telemetry events (kind ``straggler``), so they land in
    the JSONL stream alongside the spans of the step that overran."""

    def __init__(self, factor: float, window: int,
                 telemetry: Telemetry = NULL):
        self.factor = factor
        self.window = window
        self.tel = telemetry
        self.times: list = []
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 5:
            deadline = self.factor * float(np.median(self.times))
            if dt > deadline:
                is_straggler = True
                self.flagged.append((step, dt, deadline))
                self.tel.event(
                    "straggler", level="warning", logger=log,
                    msg=f"straggler: step {step} took {dt:.3f}s "
                        f"(deadline {deadline:.3f}s)",
                    step=step, dt_s=dt, deadline_s=deadline)
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        return is_straggler


class FaultTolerantLoop:
    """Shared retry / watchdog / preemption / checkpoint machinery.

    Subclasses implement ``_restore_or_init() -> (start_step, state)``
    and ``_step_once(state, step) -> (state, metrics)`` and may override
    ``_save``.  ``step_size`` is the amount ``step`` advances per
    ``_step_once`` call (1 for training steps, ``segment_steps`` for the
    segmented sim driver, whose step counter is the sim time ``t``).
    """

    step_size: int = 1

    def __init__(self, cfg: DriverConfig, telemetry: Telemetry = NULL):
        self.cfg = cfg
        self.tel = telemetry
        self.watchdog = StragglerWatchdog(cfg.straggler_factor,
                                          cfg.straggler_window,
                                          telemetry=telemetry)
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep,
                                      telemetry=telemetry)
        self.preempted = False
        self.metrics_log: list = []
        if cfg.handle_sigterm:
            try:
                signal.signal(signal.SIGTERM, self._on_sigterm)
            except ValueError:
                pass                           # non-main thread (tests)

    def _on_sigterm(self, *_):
        self.tel.event(
            "preempt", level="warning", logger=log,
            msg="SIGTERM: checkpoint at next step boundary, then exit")
        self.preempted = True

    # ---- subclass API -------------------------------------------------
    def _restore_or_init(self):
        raise NotImplementedError

    def _step_once(self, state, step):
        raise NotImplementedError

    def _save(self, step: int, state):
        # AsyncCheckpointer.save snapshots the state with a device-side
        # buffer copy before returning, so the next (donating) step call
        # cannot invalidate what gets written; the device->host transfer
        # itself overlaps that next step on the writer thread.
        self.ckpt.save(step, state)

    def _on_rewind(self, step: int):
        """Drop per-step records from the abandoned timeline after a
        failure restore: replayed steps must appear exactly once in the
        logs (``metrics_log`` is exported as a machine-readable
        artifact)."""
        self.metrics_log = [m for m in self.metrics_log
                            if m["step"] < step]

    # ---- the loop -----------------------------------------------------
    def run(self, n_steps: int) -> Dict[str, Any]:
        start, state = self._restore_or_init()
        step = start
        retries = 0
        last_fail = -1
        while step < n_steps and not self.preempted:
            t0 = time.perf_counter()
            try:
                with self.tel.span("segment", step=step):
                    state, metrics = self._step_once(state, step)
                    jax.block_until_ready(metrics)
            except Exception as e:            # noqa: BLE001 - retry path
                # retries count consecutive failures of the SAME step
                # (replay successes must not reset the counter, or a
                # deterministic fault would retry forever)
                retries = retries + 1 if step == last_fail else 1
                last_fail = step
                self.tel.event(
                    "step_failure", level="warning", logger=log,
                    msg=f"step {step} failed ({e}); retry "
                        f"{retries}/{self.cfg.max_retries}",
                    step=step, retry=retries,
                    max_retries=self.cfg.max_retries, error=str(e))
                if retries > self.cfg.max_retries:
                    self.ckpt.wait()
                    raise
                time.sleep(self.cfg.backoff_s * 2 ** (retries - 1))
                try:
                    # drain in-flight async writes so the restore sees
                    # the newest checkpoint, not a mid-write directory
                    self.ckpt.wait()
                except Exception as ce:        # noqa: BLE001
                    # a failing writer must not abort the retry; the
                    # error stays set and surfaces at the final wait()
                    self.tel.event(
                        "ckpt_writer_error", level="warning", logger=log,
                        msg=f"checkpoint writer error during retry: {ce}",
                        step=step, error=str(ce))
                step, state = self._restore_or_init()
                self._on_rewind(step)
                continue
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            self.metrics_log.append(
                {"step": step, "dt": dt,
                 **{k: float(np.asarray(v)) for k, v in metrics.items()
                    if np.asarray(v).size == 1}})
            step += self.step_size
            if (step // self.step_size) % self.cfg.ckpt_every == 0 \
                    or self.preempted or step >= n_steps:
                self._save(step, state)
        self.ckpt.wait()
        return {"final_step": step, "state": state,
                "stragglers": self.watchdog.flagged,
                "metrics": self.metrics_log,
                "preempted": self.preempted}


class TrainDriver(FaultTolerantLoop):
    """Runs ``step_fn(state, batch) -> (state, metrics)`` with fault
    tolerance.  ``state`` is any pytree (params + opt state + counters);
    ``batch_fn(step) -> batch`` must be deterministic in ``step``.

    ``abstract_state``: optional pytree of ``ShapeDtypeStruct`` matching
    the state.  When restoring, the ``like`` tree only needs shapes and
    dtypes -- materializing a throwaway ``init_state_fn()`` state first
    would double peak memory right at restart.  Without it the shapes
    are derived via ``jax.eval_shape(init_state_fn)`` (no device
    allocation for traced init functions).
    """

    def __init__(self, cfg: DriverConfig, step_fn: Callable,
                 batch_fn: Callable, init_state_fn: Callable,
                 shardings=None,
                 fault_hook: Optional[Callable] = None,
                 abstract_state=None):
        super().__init__(cfg)
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.init_state_fn = init_state_fn
        self.shardings = shardings
        self.fault_hook = fault_hook          # tests inject failures here
        self.abstract_state = abstract_state

    # ------------------------------------------------------------------
    def _restore_or_init(self):
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return 0, self.init_state_fn()
        log.info("restoring from step %d", last)
        like = self.abstract_state
        if like is None:
            like = jax.eval_shape(self.init_state_fn)
        state = restore_checkpoint(self.cfg.ckpt_dir, last, like,
                                   shardings=self.shardings)
        return last, state

    def _step_once(self, state, step):
        if self.fault_hook:
            self.fault_hook(step)
        batch = self.batch_fn(step)
        return self.step_fn(state, batch)
