"""Fault-tolerant training runtime."""

from .driver import TrainDriver, DriverConfig, StragglerWatchdog
