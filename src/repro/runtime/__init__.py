"""Fault-tolerant runtime: training and long-run simulation drivers."""

from .driver import (TrainDriver, DriverConfig, FaultTolerantLoop,
                     StragglerWatchdog)
from .sim_driver import SimDriver, sim_fingerprint
from .jobs import JobError, SimJobSpec, build_sim_driver
