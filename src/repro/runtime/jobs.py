"""Typed simulation jobs: a frozen spec, a JSON wire format, a builder.

The simulation service (``launch.serve --arch sim``) and the CLI
launcher (``launch.sim``) both describe a run with the same value: a
frozen :class:`SimJobSpec`.  One spec is one job -- everything that
shapes the simulation (model geometry, laws, seeds, ensemble width,
schedule, observability) lives in the spec; everything operational
(mesh, telemetry sink, compiled-step cache) is passed to
:func:`build_sim_driver` at submission time.

Design points:

* **Frozen + JSON round-trip.**  ``SimJobSpec.from_json(spec.to_json())
  == spec`` exactly; the wire format is a flat JSON object, so specs
  can be POSTed to the job server, embedded in manifests, and diffed
  by eye.  ``__post_init__`` normalizes (lists -> tuples) and
  validates, so a spec that constructs is a spec that runs.

* **Provenance rides the manifest.**  ``job_meta()`` is stored under
  the checkpoint manifest's ``"job"`` key.  The *identity* fields that
  must never drift across a resume -- grid, law, seed, state seed,
  ensemble seeds -- are individually enforced by the driver's
  ``refuse_meta_drift`` check; the full spec is kept report-only
  because schedule fields (``t_steps``) legitimately change when a job
  is continued further.

* **Ensembles are first-class.**  ``seeds=(s0, s1, ...)`` runs M
  member realizations through one compiled step (see
  ``core.dist_engine``); member m is bit-identical to a solo run with
  ``state_seed=s_m``.  ``seeds=None`` is a plain solo run.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

from ..obs.telemetry import NULL, Telemetry

LAWS = ("gaussian", "exponential")


class JobError(RuntimeError):
    """A job spec that cannot be built/run as submitted (bad resume
    target, occupied checkpoint directory, ...)."""


@dataclasses.dataclass(frozen=True)
class SimJobSpec:
    """Everything that defines one simulation job.

    ``seed`` realizes the synapse tables; ``state_seed`` (default:
    follows ``seed``) the initial membrane state and per-step noise;
    ``seeds`` runs an ensemble of members, member m initialized as if
    ``state_seed=seeds[m]``, all sharing the one table realization.
    """

    ckpt_dir: str
    grid: int = 8
    n_per_column: int = 60
    law: str = "gaussian"
    seed: int = 0
    state_seed: Optional[int] = None
    seeds: Optional[Tuple[int, ...]] = None
    t_steps: int = 300
    segment_steps: int = 50
    tiles: Optional[Tuple[int, int]] = None
    ckpt_every: int = 1
    keep: int = 3
    record: bool = False
    record_cap: Optional[int] = None
    plastic: bool = False
    stdp: Optional[Dict[str, float]] = None
    resume: bool = False
    retile: bool = False
    preempt_after: Optional[int] = None

    def __post_init__(self):
        if self.law not in LAWS:
            raise ValueError(f"law={self.law!r}: expected one of {LAWS}")
        for name in ("grid", "n_per_column", "t_steps", "segment_steps",
                     "ckpt_every", "keep"):
            v = getattr(self, name)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(f"{name}={v!r} must be a positive int")
        if not self.ckpt_dir:
            raise ValueError("ckpt_dir must be a non-empty path")
        if self.seeds is not None:
            seeds = tuple(int(s) for s in self.seeds)
            if not seeds:
                raise ValueError("seeds=() -- an ensemble needs >= 1 "
                                 "member; use seeds=None for a solo run")
            object.__setattr__(self, "seeds", seeds)
            if self.state_seed is not None:
                raise ValueError("state_seed and seeds are mutually "
                                 "exclusive (member m's state seed IS "
                                 "seeds[m])")
        if self.tiles is not None:
            ty, tx = self.tiles
            object.__setattr__(self, "tiles", (int(ty), int(tx)))
        if self.stdp is not None:
            object.__setattr__(
                self, "stdp", {str(k): float(v)
                               for k, v in self.stdp.items()})
            if not self.plastic:
                raise ValueError("stdp overrides given without plastic="
                                 "True")

    @property
    def n_members(self) -> Optional[int]:
        return None if self.seeds is None else len(self.seeds)

    # ---- wire format --------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SimJobSpec":
        payload = json.loads(text)
        if not isinstance(payload, dict):
            raise ValueError(f"job spec must be a JSON object, got "
                             f"{type(payload).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(payload) - known)
        if unknown:
            raise ValueError(f"unknown job spec fields: {unknown}; "
                             f"known: {sorted(known)}")
        for key in ("seeds", "tiles"):
            if payload.get(key) is not None:
                payload[key] = tuple(payload[key])
        return cls(**payload)

    def job_meta(self) -> dict:
        """JSON-safe dict for the checkpoint manifest's ``job`` key."""
        return json.loads(self.to_json())


def dist_config_for(spec: SimJobSpec, tiles: Tuple[int, int]):
    """The engine-facing config a spec denotes on a concrete tiling."""
    from ..configs.snn import reduced_case
    from ..core.dist_engine import DistConfig
    from ..core.engine import EngineConfig
    from ..core.grid import ColumnGrid, TileDecomposition

    case = reduced_case(spec.law, grid=spec.grid,
                        n_per_column=spec.n_per_column)
    law = case.connectivity()
    dec = TileDecomposition(
        grid=ColumnGrid(*case.grid, case.n_per_column),
        tiles_y=tiles[0], tiles_x=tiles[1], radius=law.radius)
    stdp = None
    if spec.plastic:
        from ..core.stdp import STDPParams
        stdp = STDPParams(**(spec.stdp or {}))
    return DistConfig(
        engine=EngineConfig(decomp=dec, law=law, seed=spec.seed,
                            state_seed=spec.state_seed, stdp=stdp),
        ensemble_seeds=spec.seeds)


def build_sim_driver(spec: SimJobSpec, mesh=None,
                     telemetry: Telemetry = NULL,
                     sim_cache: Optional[dict] = None):
    """Construct the :class:`~repro.runtime.SimDriver` a spec denotes.

    ``mesh`` defaults to the host mesh (or a fresh mesh of
    ``spec.tiles`` devices when the spec pins a tiling).  Pass the same
    ``sim_cache`` dict across calls to share compiled segment functions
    between jobs that differ only in seeds -- the job server's
    resident-mesh contract (see ``sim_fingerprint``).

    Raises :class:`JobError` for specs that must not run: a fresh job
    aimed at an occupied checkpoint directory, or ``resume=True`` with
    nothing to resume.
    """
    from ..checkpoint.store import latest_step
    from ..launch.mesh import make_host_mesh
    from ..parallel.compat import make_mesh
    from .driver import DriverConfig
    from .sim_driver import SimDriver

    tiles = spec.tiles
    if mesh is None:
        if tiles is None:
            mesh = make_host_mesh()
        else:
            mesh = make_mesh(tiles, ("data", "model"))
    tiles = mesh.devices.shape
    last = latest_step(spec.ckpt_dir)
    if last is not None and not spec.resume:
        raise JobError(
            f"{spec.ckpt_dir} already holds a checkpoint at sim step "
            f"{last}; set resume=true to continue it or use a fresh "
            "ckpt_dir")
    if spec.resume and last is None:
        # a silent fresh start here would restart a multi-hour job from
        # step 0 while reporting success
        raise JobError(f"resume: no checkpoint found in {spec.ckpt_dir}")
    return SimDriver(
        DriverConfig(ckpt_dir=spec.ckpt_dir, ckpt_every=spec.ckpt_every,
                     keep=spec.keep),
        dist_config_for(spec, tiles), mesh,
        segment_steps=spec.segment_steps,
        allow_retile=spec.retile,
        preempt_after_segments=spec.preempt_after,
        record_events=spec.record,
        record_capacity=spec.record_cap,
        telemetry=telemetry,
        sim_cache=sim_cache,
        job_meta=spec.job_meta())
