"""Fault-tolerant checkpointing: atomic, hashed, keep-k, async, elastic."""

from .store import (save_checkpoint, restore_checkpoint, latest_step,
                    checkpoint_meta, AsyncCheckpointer)
