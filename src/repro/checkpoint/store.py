"""Checkpoint store: atomic sharded save/restore with elastic resharding.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json      # tree structure, shapes, dtypes, sha256s
        leaf_00000.npy ...

Fault-tolerance properties:
  * **atomic**: written to ``step_X.tmp-<pid>`` then ``os.rename``d --
    a crash mid-write never corrupts the latest checkpoint;
  * **verified**: every leaf carries a sha256 in the manifest, checked
    on restore (detects torn/bit-rotted files before they poison a run);
  * **keep-k**: old steps garbage-collected after a successful rename;
  * **elastic**: restore takes ``shardings`` for the *new* mesh -- leaves
    are loaded on host and ``jax.device_put`` resharded, so a job can
    come back on a different pod count / tiling than it crashed on;
  * **async**: ``AsyncCheckpointer.save`` takes a *device-side* snapshot
    (an async buffer copy) and returns; the device->host transfer and
    the file writes both happen on a daemon thread, so the blocking D2H
    overlaps the caller's next segment of compute (double-buffered
    segment handoff) instead of serializing with it.
"""

from __future__ import annotations

import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree, keep: int = 3,
                    meta: Optional[dict] = None) -> str:
    """``meta``: optional JSON-serializable dict stored inside the
    step's manifest -- atomic with the checkpoint itself (a sidecar
    file could describe a checkpoint that never got published)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp-{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = _tree_paths(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "meta": meta or {}, "leaves": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        with open(os.path.join(tmp, fn), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append({
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": digest,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                    # atomic publish
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp" not in d]
    return max(steps) if steps else None


def checkpoint_meta(directory: str, step: int) -> dict:
    """The ``meta`` dict stored with ``save_checkpoint`` (empty for
    checkpoints written without one)."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f).get("meta", {})


def refuse_meta_drift(meta: dict, mine: dict, keys, where: str):
    """Refuse to resume a checkpoint whose manifest meta disagrees with
    the current config on any of ``keys`` (keys absent from ``meta`` are
    skipped: pre-versioning manifests).  Shared by the drivers so every
    identity refusal carries the same actionable message.

    Analyzer-checked: repro-lint's ``meta-drift`` pass cross-references
    every meta key the sim driver writes against the keys validated
    here (or otherwise read on the restore path)."""
    for key in keys:
        if key in meta and meta[key] != mine[key]:
            raise ValueError(
                f"checkpoint in {where} was written with "
                f"{key}={meta[key]}, current config has "
                f"{key}={mine[key]} -- resuming would silently "
                "continue a different model")


def restore_checkpoint(directory: str, step: int, like,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the *current* mesh (elastic restore)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _tree_paths(like)
    if len(manifest["leaves"]) != len(flat_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, expected "
            f"{len(flat_like)} -- structure mismatch")
    leaves = []
    for meta, want in zip(manifest["leaves"], flat_like):
        fp = os.path.join(path, meta["file"])
        if verify:
            with open(fp, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != meta["sha256"]:
                raise IOError(f"checksum mismatch in {fp}")
        arr = np.load(fp)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{meta['file']}: shape {arr.shape} != "
                             f"expected {want.shape}")
        want_dtype = np.dtype(want.dtype)
        if arr.dtype != want_dtype:
            raise ValueError(
                f"{meta['file']}: dtype {arr.dtype} != expected "
                f"{want_dtype} -- a drifted dtype would silently "
                "recompile or corrupt the jitted step")
        leaves.append(arr)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


_THREAD_ASSERTS = False


def set_thread_asserts(enabled: bool):
    """Toggle the sanitizer's owning-thread assertion mode on every
    ``AsyncWriterThread`` (``--sanitize`` wires this on).  When on,
    subclasses' non-queue state mutations (``_assert_owner`` call
    sites: spool offset counters, checkpoint submission) raise if
    invoked off the constructing thread -- the PR 4 spool-offset race
    class, made loud instead of silently corrupting manifests."""
    global _THREAD_ASSERTS
    _THREAD_ASSERTS = enabled


def thread_asserts_enabled() -> bool:
    return _THREAD_ASSERTS


class AsyncWriterThread:
    """Daemon-thread work queue with deferred error surfacing.

    Shared writer machinery for everything that must stay off the hot
    path (checkpoints, spike-log spooling): ``_submit`` enqueues, the
    daemon thread calls ``_write(item)``, a failing write is latched and
    re-raised on the next ``_submit``/``wait`` (never swallowed),
    ``wait()`` drains pending work, ``close()`` shuts the thread down.

    Only the queue is thread-safe.  Everything else a subclass keeps
    (offset counters, snapshot buffers) is owned by the constructing
    thread; subclasses call ``_assert_owner`` before mutating such
    state, which raises under ``set_thread_asserts(True)``.
    """

    def __init__(self):
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._owner = threading.current_thread()
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _assert_owner(self, what: str):
        """Sanitizer hook: non-queue state is single-owner by contract."""
        if _THREAD_ASSERTS and threading.current_thread() is not self._owner:
            raise AssertionError(
                f"{type(self).__name__}.{what} called from thread "
                f"{threading.current_thread().name!r} but this writer's "
                f"non-queue state is owned by {self._owner.name!r} -- "
                "offsets/manifests would race (run without --sanitize "
                "only if you know the access is synchronized)")

    def _write(self, item):
        raise NotImplementedError

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(item)
            except BaseException as e:   # surfaced on next submit/wait
                self._err = e
            finally:
                self._q.task_done()

    def _submit(self, item):
        if self._err:
            raise self._err
        self._q.put(item)

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join()


class AsyncCheckpointer(AsyncWriterThread):
    """Daemon-thread writer; ``save`` returns once the *device-side*
    snapshot is dispatched -- the device->host transfer runs in
    ``_write`` on the worker thread, overlapped with whatever the
    caller computes next.  ``wait()`` drains pending writes (call
    before exit).

    ``telemetry``: optional ``obs.telemetry.Telemetry``.  The caller's
    snapshot cost (``ckpt.snapshot``, the device-side buffer copy) and
    the worker's D2H transfer (``ckpt.d2h``) / file write
    (``ckpt.write``) become separate spans on their own threads, so the
    double-buffered overlap with segment compute is visible in the
    Chrome trace instead of inferred."""

    def __init__(self, directory: str, keep: int = 3, telemetry=None):
        self.directory = directory
        self.keep = keep
        if telemetry is None:
            # imported lazily: obs.spool imports this module, so a
            # top-level obs.telemetry import would cycle
            from ..obs.telemetry import NULL as telemetry
        self.tel = telemetry
        super().__init__()

    def _write(self, item):
        # the D2H transfer happens here, on the worker: it runs
        # concurrently with the caller's next segment instead of
        # blocking save().  Fetched explicitly (save_checkpoint's
        # per-leaf device_get is a no-op on host arrays) so transfer
        # and file write land in separate spans.
        step, tree, meta = item
        with self.tel.span("ckpt.d2h", step=step):
            host = jax.device_get(tree)
        with self.tel.span("ckpt.write", step=step):
            save_checkpoint(self.directory, step, host, self.keep,
                            meta=meta)

    def save(self, step: int, tree, meta: Optional[dict] = None):
        self._assert_owner("save")
        # Buffer copy, not host transfer: the caller's very next step
        # typically *donates* the live state to the jitted segment, so
        # the snapshot must not alias it -- but it can stay on device
        # until the worker drains it (double-buffered handoff).
        with self.tel.span("ckpt.snapshot", step=step):
            snap = jax.tree.map(jnp.copy, tree)
        self._submit((step, snap, meta))
