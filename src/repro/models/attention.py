"""GQA attention: train/prefill (chunked online-softmax or Pallas flash)
and decode (KV cache, optionally ring-buffered for sliding windows).

GQA never materializes repeated KV: queries are reshaped to
``(B, Kv, group, S, D)`` so the head grouping is an einsum broadcast.

Sharding: q heads shard over "heads" (TP) when divisible, KV heads
replicate (small); decode KV caches shard their *sequence* dim over the
model axis ("kv_seq"), so decode attention becomes a flash-decoding
pattern -- per-shard partial softmax combined by the psum GSPMD inserts.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .config import ModelConfig
from .layers import _normal, apply_rmsnorm, apply_rope

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False):
    d, h, kv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {
        "wq": _normal(ks[0], (d, h, hd), sc, dtype),
        "wk": _normal(ks[1], (d, kv, hd), sc, dtype),
        "wv": _normal(ks[2], (d, kv, hd), sc, dtype),
        "wo": _normal(ks[3], (h, hd, d), 1.0 / math.sqrt(h * hd), dtype),
    }
    s = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", "kv_heads", None),
        "wv": ("fsdp", "kv_heads", None),
        "wo": ("heads", None, "fsdp"),
    }
    if cfg.qkv_bias and not cross:
        p.update(bq=jnp.zeros((h, hd), dtype), bk=jnp.zeros((kv, hd), dtype),
                 bv=jnp.zeros((kv, hd), dtype))
        s.update(bq=("heads", None), bk=("kv_heads", None),
                 bv=("kv_heads", None))
    if cfg.qk_norm:
        p.update(q_norm=jnp.zeros((hd,), dtype),
                 k_norm=jnp.zeros((hd,), dtype))
        s.update(q_norm=(None,), k_norm=(None,))
    return p, s


import dataclasses


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jnp.ndarray           # (B, S_cache, Kv, D)
    v: jnp.ndarray
    # static: sliding-window ring buffer flag (not a traced leaf)
    ring: bool = dataclasses.field(default=False,
                                   metadata=dict(static=True))


def _project_qkv(p, cfg: ModelConfig, x, kv_x, positions, kv_positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = apply_rmsnorm({"scale": p["q_norm"]}, q)
        k = apply_rmsnorm({"scale": p["k_norm"]}, k)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _grouped(q, n_kv: int):
    """(B, S, H, D) -> (B, Kv, group, S, D)."""
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh).transpose(0, 2, 3, 1, 4)


def _chunk_mask(q_pos, k_pos, k_valid, causal, window):
    mask = (k_pos < k_valid)[None, :] & jnp.ones(
        (q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
    return mask


def _c(x, spec):
    """Best-effort sharding constraint (no-op without a mesh).  The
    flash scan carries MUST be pinned: unconstrained zeros-inits let
    GSPMD resolve the loop state to fully replicated, silently turning
    sharded attention into per-device full-batch attention."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x


def _flash_fwd_impl(qg, kg, vg, cfgt):
    """qg: (B,Kv,G,Sq,D); kg/vg: (B,Kv,Sk,D).  Returns (out, L) with
    L = m + log(l) row statistics (the flash-backward residual)."""
    causal, window, scale, q_offset, cq, ck, k_valid, spec5, spec4 = cfgt
    b, kvh, g, sq, dh = qg.shape
    sk = kg.shape[2]
    nq, nk = sq // cq, sk // ck
    qg, kg, vg = _c(qg, spec5), _c(kg, spec4), _c(vg, spec4)

    def q_step(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=3)
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def k_step(carry, ki):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(kg, ki * ck, ck, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vg, ki * ck, ck, axis=2)
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            mask = _chunk_mask(q_pos, k_pos, k_valid, causal, window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new) * mask
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vc.astype(jnp.float32))
            return (_c(m_new, spec5), _c(l_new, spec5),
                    _c(acc_new, spec5)), None

        init = (_c(jnp.full((b, kvh, g, cq, 1), NEG_INF, jnp.float32),
                   spec5),
                _c(jnp.zeros((b, kvh, g, cq, 1), jnp.float32), spec5),
                _c(jnp.zeros((b, kvh, g, cq, dh), jnp.float32), spec5))
        (m, l, acc), _ = jax.lax.scan(k_step, init, jnp.arange(nk))
        safe = jnp.where(l > 0, l, 1.0)
        out_c = (acc / safe * (l > 0)).astype(qg.dtype)
        lse = jnp.where(l[..., 0] > 0, m[..., 0] + jnp.log(safe[..., 0]),
                        -NEG_INF)                       # dead rows: +1e30
        return None, (out_c, lse)

    _, (chunks, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(chunks, 0, 3).reshape(b, kvh, g, sq, dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kvh, g, sq)
    return _c(out, spec5), lse


def _flash(qg, kg, vg, cfgt):
    out, _ = _flash_fwd_impl(qg, kg, vg, cfgt)
    return out


def _flash_fwd(qg, kg, vg, cfgt):
    out, lse = _flash_fwd_impl(qg, kg, vg, cfgt)
    return out, (qg, kg, vg, out, lse)


def _flash_bwd(cfgt, res, dout):
    """Flash-attention backward: recompute s/p per chunk pair, never
    materialize (Sq, Sk).  O(Sk) f32 dk/dv accumulators."""
    causal, window, scale, q_offset, cq, ck, k_valid, spec5, spec4 = cfgt
    qg, kg, vg, out, lse = res
    b, kvh, g, sq, dh = qg.shape
    sk = kg.shape[2]
    nq, nk = sq // cq, sk // ck
    dout = _c(dout, spec5)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                            # (B,Kv,G,Sq)

    def q_step(carry, qi):
        dk, dv = carry
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * cq, cq, axis=3) \
            .astype(jnp.float32)
        doc = jax.lax.dynamic_slice_in_dim(dout, qi * cq, cq, axis=3) \
            .astype(jnp.float32)
        lc = jax.lax.dynamic_slice_in_dim(lse, qi * cq, cq, axis=3)
        dc = jax.lax.dynamic_slice_in_dim(delta, qi * cq, cq, axis=3)
        q_pos = q_offset + qi * cq + jnp.arange(cq)

        def k_step(inner, ki):
            dqc, dk, dv = inner
            kc = jax.lax.dynamic_slice_in_dim(kg, ki * ck, ck, axis=2) \
                .astype(jnp.float32)
            vc = jax.lax.dynamic_slice_in_dim(vg, ki * ck, ck, axis=2) \
                .astype(jnp.float32)
            k_pos = ki * ck + jnp.arange(ck)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qc, kc) * scale
            mask = _chunk_mask(q_pos, k_pos, k_valid, causal, window)
            p = jnp.exp(jnp.where(mask, s, NEG_INF) - lc[..., None])
            p = p * mask                                 # (B,Kv,G,cq,ck)
            dv_c = jnp.einsum("bkgqs,bkgqd->bksd", p, doc)
            dp = jnp.einsum("bkgqd,bksd->bkgqs", doc, vc)
            ds = p * (dp - dc[..., None]) * scale
            dq_new = dqc + jnp.einsum("bkgqs,bksd->bkgqd", ds, kc)
            dk_c = jnp.einsum("bkgqs,bkgqd->bksd", ds, qc)
            upd = lambda acc, c: _c(jax.lax.dynamic_update_slice_in_dim(
                acc, jax.lax.dynamic_slice_in_dim(
                    acc, ki * ck, ck, axis=2) + c, ki * ck, axis=2), spec4)
            return (_c(dq_new, spec5), upd(dk, dk_c), upd(dv, dv_c)), None

        init = (_c(jnp.zeros((b, kvh, g, cq, dh), jnp.float32), spec5),
                dk, dv)
        (dqc, dk, dv), _ = jax.lax.scan(k_step, init, jnp.arange(nk))
        return (dk, dv), dqc

    dk0 = _c(jnp.zeros((b, kvh, sk, dh), jnp.float32), spec4)
    dv0 = _c(jnp.zeros((b, kvh, sk, dh), jnp.float32), spec4)
    (dk, dv), dq_chunks = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dq_chunks, 0, 3).reshape(b, kvh, g, sq, dh)
    return (dq.astype(qg.dtype), dk.astype(kg.dtype), dv.astype(vg.dtype))


_flash = jax.custom_vjp(_flash, nondiff_argnums=(3,))
_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(q, k, v, *, causal, window, scale, q_offset=0,
                      chunk_q=1024, chunk_k=1024, k_valid=None,
                      spec5=None, spec4=None):
    """Online-softmax attention, O(chunk^2) memory in BOTH directions
    (flash-style custom VJP: backward recomputes per chunk pair).

    q: (B, Sq, H, D); k, v: (B, Sk, Kv, D).  Matches the flash kernel /
    ``kernels.ref.attention_ref`` semantics.  ``spec5``/``spec4`` pin the
    sharding of the (B, Kv, G, S, D) / (B, Kv, S, D) internals.
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    chunk_q = min(chunk_q, sq)
    chunk_k = min(chunk_k, sk)
    pad_q, pad_k = -sq % chunk_q, -sk % chunk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    k_valid = sk if k_valid is None else k_valid

    qg = _grouped(qp, kvh)                       # (B, Kv, g, Sq', D)
    kg = kp.transpose(0, 2, 1, 3)                # (B, Kv, Sk', D)
    vg = vp.transpose(0, 2, 1, 3)
    cfgt = (causal, window, scale, q_offset, chunk_q, chunk_k, k_valid,
            spec5, spec4)
    og = _flash(qg, kg, vg, cfgt)
    out = og.transpose(0, 3, 1, 2, 4).reshape(b, sq + pad_q, h, dh)
    return out[:, :sq]


def _full_attention(q, k, v, cfg: ModelConfig, *, causal, window,
                    q_offset=0, k_valid=None, rules: MeshRules = None):
    """Dispatch on cfg.attn_impl for the prefill/train path.

    KV is repeated to the query-head count first: with kv == h the
    grouped flash layout is (B, H, 1, S, D), whose head dim a plain
    PartitionSpec can shard (TP); the repeat materializes only each
    shard's own heads.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    h, kvh = q.shape[2], k.shape[2]
    if kvh != h:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    spec5 = spec4 = None
    if rules is not None:
        spec5 = rules.nsharding("batch", "heads", None, None, None)
        spec4 = rules.nsharding("batch", "heads", None, None)
    if cfg.attn_impl == "pallas":
        from ..kernels import ops as kops
        b, sq, h, dh = q.shape
        kvh = k.shape[2]
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], dh)
        vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], dh)
        of = kops.attention(qf, kf, vf, causal=causal, window=window,
                            scale=scale, q_offset=q_offset)
        return of.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
    if cfg.attn_impl == "naive":
        from ..kernels import ref as kref
        b, sq, h, dh = q.shape
        kvh = k.shape[2]
        qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
        kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, k.shape[1], dh)
        vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, v.shape[1], dh)
        of = kref.attention_ref(qf, kf, vf, causal=causal, window=window,
                                scale=scale, q_offset=q_offset)
        return of.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset,
                             chunk_q=cfg.attn_chunk_q,
                             chunk_k=cfg.attn_chunk_k, k_valid=k_valid,
                             spec5=spec5, spec4=spec4)


def _decode_attention(q, cache: KVCache, cur_len, window):
    """One-token attention over the cache.  q: (B, 1, H, D)."""
    b, _, h, dh = q.shape
    kvh = cache.k.shape[2]
    scale = 1.0 / math.sqrt(dh)
    qg = _grouped(q, kvh)                         # (B, Kv, g, 1, D)
    kc = cache.k.transpose(0, 2, 1, 3)            # (B, Kv, S, D)
    vc = cache.v.transpose(0, 2, 1, 3)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    pos = jnp.arange(cache.k.shape[1])
    if cache.ring:
        # ring cache holds the last W keys; all slots < min(len, W) valid
        mask = pos < jnp.minimum(cur_len, cache.k.shape[1])
    else:
        mask = pos < cur_len
        if window is not None:
            mask = mask & (pos >= cur_len - window)
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vc.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, dh).astype(q.dtype)


def apply_attention(p, cfg: ModelConfig, rules: MeshRules, x, positions, *,
                    causal=True, window=None, kv_x=None,
                    cache: Optional[KVCache] = None, cache_pos=None,
                    update_cache=True):
    """Returns (out (B,S,d), new_cache).

    Modes:
      * cache None:      full self/cross attention (train / prefill)
      * cache + update:  decode self-attention (append k,v at cache_pos)
      * cache, no update: decode cross-attention (static cache)
    """
    cross = kv_x is not None
    kv_src = kv_x if cross else x
    kv_positions = (jnp.arange(kv_src.shape[1])
                    if (cross or cache is None) else positions)
    q, k, v = _project_qkv(p, cfg, x, kv_src, positions, kv_positions)
    q = constrain(q, rules, "batch", None, "heads", None)

    new_cache = cache
    s_q = x.shape[1]
    if cache is None:
        out = _full_attention(q, k, v, cfg, causal=causal and not cross,
                              window=window, rules=rules)
    elif s_q > 1:
        # single-shot prefill: attend over the prompt itself (or the
        # encoder output, for cross-attention), then write the (last
        # window of) keys/values into the cache
        out = _full_attention(q, k, v, cfg, causal=causal and not cross,
                              window=window, rules=rules)
        if update_cache:
            w_cache = cache.k.shape[1]
            if cache.ring:
                take = min(w_cache, s_q)
                src_k, src_v = k[:, -take:], v[:, -take:]
                idx = (cache_pos + s_q - take
                       + jnp.arange(take)) % w_cache
                ck = cache.k.at[:, idx].set(src_k)
                cv = cache.v.at[:, idx].set(src_v)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, k, cache_pos, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, v, cache_pos, axis=1)
            ck = constrain(ck, rules, "batch", "kv_seq", None, None)
            cv = constrain(cv, rules, "batch", "kv_seq", None, None)
            new_cache = KVCache(ck, cv, cache.ring)
    else:
        if update_cache:
            idx = (cache_pos % cache.k.shape[1]) if cache.ring else cache_pos
            ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v, idx, axis=1)
            ck = constrain(ck, rules, "batch", "kv_seq", None, None)
            cv = constrain(cv, rules, "batch", "kv_seq", None, None)
            new_cache = KVCache(ck, cv, cache.ring)
            cur = cache_pos + s_q
        else:
            cur = cache.k.shape[1]
        out = _decode_attention(q, new_cache, cur, window)

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, rules, "batch", None, None), new_cache


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype,
               window: Optional[int] = None) -> KVCache:
    s = min(seq, window) if window else seq
    shape = (batch, s, cfg.n_kv_heads, cfg.resolved_head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   ring=window is not None and window < seq)


def abstract_cache(cfg: ModelConfig, batch: int, seq: int, dtype,
                   window: Optional[int] = None) -> KVCache:
    s = min(seq, window) if window else seq
    shape = (batch, s, cfg.n_kv_heads, cfg.resolved_head_dim)
    sd = jax.ShapeDtypeStruct(shape, dtype)
    return KVCache(sd, sd, ring=window is not None and window < seq)
