"""Mixture-of-Experts layer with sort-based, fixed-capacity dispatch.

The paper's event-delivery idiom (compact sparse events into
fixed-capacity buffers, scatter, process densely, combine) maps directly
onto MoE token routing -- the "events" are token->expert assignments:

  1. router top-k per token;
  2. flatten (T*k) assignments, stable-sort by expert id, rank-in-expert
     = position - segment start; assignments beyond ``capacity`` drop
     (exactly the synapse-table row clipping);
  3. scatter tokens into an (E, capacity, d) buffer -- with E sharded
     over "model" (EP) and capacity over "data", GSPMD lowers this to
     the all-to-all every MoE system hand-writes;
  4. dense per-expert batched matmuls (MXU-friendly);
  5. gather back, weight, sum over the k copies.

No dynamic shapes anywhere, so the 1T-param kimi-k2 config lowers from
ShapeDtypeStructs like everything else.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .config import ModelConfig
from .layers import _normal


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor
                    / cfg.n_experts)
    return max(256, -(-cap // 256) * 256)     # pad for (data-)shardability


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": _normal(ks[0], (d, e), 1 / math.sqrt(d), jnp.float32),
        "gate": _normal(ks[1], (e, d, f), 1 / math.sqrt(d), dtype),
        "up": _normal(ks[2], (e, d, f), 1 / math.sqrt(d), dtype),
        "down": _normal(ks[3], (e, f, d), 1 / math.sqrt(f), dtype),
    }
    s = {
        "router": ("fsdp", "experts"),
        "gate": ("experts", "fsdp", None),
        "up": ("experts", "fsdp", None),
        "down": ("experts", None, "fsdp"),
    }
    return p, s


def apply_moe(p, cfg: ModelConfig, rules: MeshRules, x) -> Tuple:
    """x: (B, S, d) -> (y, aux losses dict).

    Two implementations with identical semantics:
      * ``_apply_moe_ep`` (production): shard_map expert parallelism.
        Tokens never move -- every (data, model) shard routes its own
        tokens, serves its *own* E/model_size experts for them with a
        local fixed-capacity scatter (cap_loc = cap/dp per data shard),
        and the k expert contributions per token are summed with one
        psum over the model axis.  FSDP weight shards are all-gathered
        over "data" per layer.  This avoids GSPMD's catastrophic
        handling of big arbitrary-index scatters (a pjit-level dispatch
        materializes the full (E*cap, d) buffer replicated per device:
        ~37 GB for kimi-k2).
      * ``_apply_moe_dense`` (reference): pjit-level sort+scatter
        dispatch; used on meshless test rigs and as the oracle in the
        EP-equivalence test.
    """
    if rules.mesh is not None and rules.axis("experts") is not None:
        return _apply_moe_ep(p, cfg, rules, x)
    return _apply_moe_dense(p, cfg, rules, x)


def _topk_capacity_slots(probs, k: int, e: int, cap: int, e0=None,
                         e_span: int = 0):
    """Shared routing: top-k, renormalized weights, capacity-ranked
    slots.  With (e0, e_span): only experts in [e0, e0+e_span) get live
    slots (slot = (e-e0)*cap + rank), everything else -> dump slot."""
    t = probs.shape[0]
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k) - seg_start
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    if e0 is None:
        e0, e_span = 0, e
    local = (flat_e >= e0) & (flat_e < e0 + e_span) & (rank < cap)
    slot = jnp.where(local, (flat_e - e0) * cap + rank, e_span * cap)
    kept = rank < cap                       # kept globally (any shard)
    return top_w, flat_e, slot.astype(jnp.int32), kept


def _expert_ffn(ebuf, gate, up, down, act: str):
    a = jnp.einsum("ecd,edf->ecf", ebuf, gate)
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    h = a * jnp.einsum("ecd,edf->ecf", ebuf, up)
    return jnp.einsum("ecf,efd->ecd", h, down)


def _apply_moe_ep(p, cfg: ModelConfig, rules: MeshRules, x) -> Tuple:
    mesh = rules.mesh
    b, s, d = x.shape
    k, e = cfg.moe_top_k, cfg.n_experts
    batch_ax = rules.batch
    model_ax = rules.axis("experts")
    fsdp_ax = rules.fsdp
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in ((batch_ax,) if isinstance(batch_ax, str) else
              (batch_ax or ())):
        dp *= axes.get(a, 1)
    t_loc = (b // dp if b % dp == 0 else b) * s
    cap_loc = max(32, -(-math.ceil(t_loc * k * cfg.capacity_factor / e)
                        // 32) * 32)

    from jax.sharding import PartitionSpec as P
    x_spec = P(batch_ax, None, None)
    w_in_spec = P(model_ax, fsdp_ax, None)    # gate/up (E, d, f)
    w_out_spec = P(model_ax, None, fsdp_ax)   # down (E, f, d)
    r_spec = P(None, None)                    # router replicated (tiny)

    def body(xb, router, gate, up, down):
        bl, sl, _ = xb.shape
        tl = bl * sl
        xt = xb.reshape(tl, d)
        if fsdp_ax is not None:
            gate = jax.lax.all_gather(gate, fsdp_ax, axis=1, tiled=True)
            up = jax.lax.all_gather(up, fsdp_ax, axis=1, tiled=True)
            down = jax.lax.all_gather(down, fsdp_ax, axis=2, tiled=True)
        e_loc = gate.shape[0]
        e0 = jax.lax.axis_index(model_ax) * e_loc

        logits = xt.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, flat_e, slot, kept = _topk_capacity_slots(
            probs, k, e, cap_loc, e0=e0, e_span=e_loc)

        tok = jnp.repeat(jnp.arange(tl), k)
        buf = jnp.zeros((e_loc * cap_loc + 1, d), xb.dtype)
        buf = buf.at[slot].set(xt[tok], mode="drop")
        out = _expert_ffn(buf[:-1].reshape(e_loc, cap_loc, d),
                          gate, up, down, cfg.mlp_act)
        out_flat = jnp.concatenate(
            [out.reshape(e_loc * cap_loc, d),
             jnp.zeros((1, d), xb.dtype)], axis=0)
        gathered = out_flat[slot]            # dump slot -> zeros
        w = (top_w.reshape(-1)
             * (slot < e_loc * cap_loc))[:, None].astype(xb.dtype)
        y = jnp.sum((gathered * w).reshape(tl, k, d), axis=1)
        y = jax.lax.psum(y, model_ax)        # k experts live on k shards

        # aux losses (identical across model ranks; averaged over data)
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0 / (tl * k))
        lb = e * jnp.sum(me * ce)
        zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        dropped = 1.0 - jnp.sum(kept) / (tl * k)
        all_axes = tuple(mesh.axis_names)
        n_shards = 1
        for a in all_axes:
            n_shards *= axes.get(a, 1)
        aux = jnp.stack([lb, zl, dropped])
        aux = jax.lax.psum(aux, all_axes) / n_shards
        return y.reshape(bl, sl, d), aux

    from ..parallel.compat import shard_map
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, r_spec, w_in_spec, w_in_spec, w_out_spec),
        out_specs=(x_spec, P()))(
        x, p["router"], p["gate"], p["up"], p["down"])
    return y, {"load_balance": aux[0], "router_z": aux[1],
               "frac_dropped": aux[2]}


def _apply_moe_dense(p, cfg: ModelConfig, rules: MeshRules, x) -> Tuple:
    """Reference pjit-level dispatch (meshless tests, equivalence oracle)."""
    b, s, d = x.shape
    t = b * s
    k, e = cfg.moe_top_k, cfg.n_experts
    cap = moe_capacity(cfg, t)
    xt = x.reshape(t, d)

    # ---- routing ---------------------------------------------------------
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- fixed-capacity slot assignment (sort + segment rank) ------------
    flat_e = top_e.reshape(-1)                            # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k) - seg_start
    slot_sorted = jnp.where(rank_sorted < cap,
                            sorted_e * cap + rank_sorted, e * cap)
    slot = jnp.zeros((t * k,), jnp.int32).at[order].set(
        slot_sorted.astype(jnp.int32))

    # ---- dispatch: scatter tokens into the (E, cap, d) buffer ------------
    tok_of_assign = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[slot].set(xt[tok_of_assign], mode="drop",
                           unique_indices=False)
    ebuf = buf[:-1].reshape(e, cap, d)
    ebuf = constrain(ebuf, rules, "experts", "batch", None)

    # ---- dense per-expert FFN --------------------------------------------
    a = jnp.einsum("ecd,edf->ecf", ebuf, p["gate"])
    a = jax.nn.silu(a) if cfg.mlp_act == "silu" else jax.nn.gelu(a)
    h = a * jnp.einsum("ecd,edf->ecf", ebuf, p["up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["down"])
    out = constrain(out, rules, "experts", "batch", None)

    # ---- combine ----------------------------------------------------------
    out_flat = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = out_flat[slot]                             # (T*k, d)
    kept = (slot < e * cap).astype(jnp.float32)
    w = (top_w.reshape(-1) * kept)[:, None].astype(x.dtype)
    y = jnp.sum((gathered * w).reshape(t, k, d), axis=1)

    # ---- aux losses (switch-style load balance + router z-loss) ----------
    me = jnp.mean(probs, axis=0)                          # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(
        1.0 / (t * k))                                    # assignment frac
    lb = e * jnp.sum(me * ce)
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    frac_dropped = 1.0 - jnp.sum(kept) / (t * k)
    aux = {"load_balance": lb, "router_z": zl,
           "frac_dropped": frac_dropped}
    return y.reshape(b, s, d), aux
