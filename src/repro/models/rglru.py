"""RG-LRU recurrent block (recurrentgemma / Griffin).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  per-channel decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped Griffin-style: in-proj to (x, gate) branches, temporal conv on
the x branch, RG-LRU, then ``h * gelu(gate)`` and out-proj.  The
recurrence is diagonal, so it shares the chunked associative-scan
machinery with the mamba block (``ssm.diag_scan_chunk``) and shards its
width over the "model" axis with zero intra-scan collectives.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .config import ModelConfig
from .layers import _normal, apply_conv1d, init_conv1d
from .ssm import diag_scan_chunk

_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype):
    d, w, k = cfg.d_model, cfg.resolved_rglru_width, cfg.conv_k
    ks = jax.random.split(key, 5)
    conv_p, conv_s = init_conv1d(ks[0], w, k, dtype)
    # Lambda init so decay a^c in [0.9, 0.999] at r=1 (griffin appendix)
    u = jax.random.uniform(ks[1], (w,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))  # softplus^-1
    p = {
        "in_proj": _normal(ks[2], (d, 2 * w), 1 / math.sqrt(d), dtype),
        "conv": conv_p,
        "w_a": _normal(ks[3], (w, w), 1 / math.sqrt(w), dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": _normal(ks[4], (w, w), 1 / math.sqrt(w), dtype),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out_proj": _normal(jax.random.fold_in(key, 9), (w, d),
                            1 / math.sqrt(w), dtype),
    }
    s = {
        "in_proj": ("fsdp", "d_inner"), "conv": conv_s,
        "w_a": (None, "d_inner"), "b_a": ("d_inner",),
        "w_x": (None, "d_inner"), "b_x": ("d_inner",),
        "lam": ("d_inner",),
        "out_proj": ("d_inner", "fsdp"),
    }
    return p, s


def _gates(p, x_c):
    """log-decay and gated input for a chunk.  x_c: (B, C, w)."""
    xf = x_c.astype(jnp.float32)
    r = jax.nn.sigmoid((x_c @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((x_c @ p["w_x"]).astype(jnp.float32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * (i * xf)


def apply_rglru(p, cfg: ModelConfig, rules: MeshRules, x,
                state: Optional[dict] = None):
    """x: (B, S, d) -> (out, new_state).  state = {"conv", "h"}."""
    b, s, _ = x.shape
    w = cfg.resolved_rglru_width

    xz = x @ p["in_proj"]
    xz = constrain(xz, rules, "batch", None, "d_inner")
    x_in, gate = jnp.split(xz, 2, axis=-1)

    if state is not None:
        x_c, conv_state = apply_conv1d(p["conv"], x_in, state["conv"])
    else:
        x_c, conv_state = apply_conv1d(p["conv"], x_in), None

    if state is not None and s == 1:
        a, bx = _gates(p, x_c)
        h = a[:, 0] * state["h"] + bx[:, 0]
        y = h[:, None]
        new_state = {"conv": conv_state, "h": h}
    else:
        chunk = min(cfg.mamba_chunk, s)
        pad = -s % chunk
        xc_p = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0))) if pad else x_c
        nc = (s + pad) // chunk
        xs = xc_p.reshape(b, nc, chunk, w).transpose(1, 0, 2, 3)
        # padded tail positions must not advance the carried state
        valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)

        def step(h, inp):
            x_chunk, valid_c = inp
            a, bx = _gates(p, x_chunk)
            vc = valid_c[None, :, None]
            a = jnp.where(vc, a, 1.0)
            bx = jnp.where(vc, bx, 0.0)
            h_last, h_all = diag_scan_chunk(a, bx, h)
            return h_last, h_all

        h0 = jnp.zeros((b, w), jnp.float32) if state is None else state["h"]
        h_last, ys = jax.lax.scan(jax.checkpoint(step), h0, (xs, valid))
        y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, w)[:, :s]
        new_state = None if state is None else \
            {"conv": conv_state, "h": h_last}

    y = y.astype(x.dtype) * jax.nn.gelu(gate)
    out = y @ p["out_proj"]
    return constrain(out, rules, "batch", None, None), new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.resolved_rglru_width
    return {"conv": jnp.zeros((batch, cfg.conv_k - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


def abstract_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.resolved_rglru_width
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.conv_k - 1, w), dtype),
            "h": jax.ShapeDtypeStruct((batch, w), jnp.float32)}
