"""Mamba-1 selective SSM block (falcon-mamba-7b), TPU-adapted.

The GPU reference implementation is a fused CUDA selective-scan over the
whole sequence.  The TPU adaptation chunks the sequence: an outer
``lax.scan`` over chunks carries the (B, d_inner, N) state, and inside a
chunk the diagonal linear recurrence runs as a parallel
``associative_scan`` -- so the (B, chunk, d_inner, N) discretized tensors
exist only per-chunk (bounded HBM), while the MXU sees batched matmuls.
``d_inner`` shards over the "model" axis; the recurrence is elementwise
over channels so no collective is needed inside the scan.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .config import ModelConfig
from .layers import _normal, apply_conv1d, init_conv1d


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, b1 * a2 + b2


def diag_scan_chunk(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t within one chunk (axis 1), given carry.

    a, b: (B, C, ...); h0: (B, ...).  Returns (h_last, h_all).
    """
    prod, pref = jax.lax.associative_scan(_combine, (a, b), axis=1)
    h_all = prod * h0[:, None] + pref
    return h_all[:, -1], h_all


def init_mamba(key, cfg: ModelConfig, dtype):
    d, di = cfg.d_model, cfg.d_inner
    n, r, k = cfg.ssm_state, cfg.resolved_dt_rank, cfg.conv_k
    ks = jax.random.split(key, 6)
    conv_p, conv_s = init_conv1d(ks[0], di, k, dtype)
    # S4D-real initialization of A; dt bias sets softplus(dt) in
    # [1e-3, 1e-1] as in the mamba reference.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[1], (di,), jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    p = {
        "in_proj": _normal(ks[2], (d, 2 * di), 1 / math.sqrt(d), dtype),
        "conv": conv_p,
        "x_proj": _normal(ks[3], (di, r + 2 * n), 1 / math.sqrt(di), dtype),
        "dt_proj": _normal(ks[4], (r, di), 1 / math.sqrt(r), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _normal(ks[5], (di, d), 1 / math.sqrt(di), dtype),
    }
    s = {
        "in_proj": ("fsdp", "d_inner"), "conv": conv_s,
        "x_proj": ("d_inner", None), "dt_proj": (None, "d_inner"),
        "dt_bias": ("d_inner",), "a_log": ("d_inner", None),
        "d_skip": ("d_inner",), "out_proj": ("d_inner", "fsdp"),
    }
    return p, s


def _ssm_inputs(p, cfg: ModelConfig, x_c):
    """Per-position SSM tensors from the conv output (any seq length)."""
    n, r = cfg.ssm_state, cfg.resolved_dt_rank
    xdb = x_c @ p["x_proj"]
    dt_low, b_in, c_in = jnp.split(xdb, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_low @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    return dt, b_in.astype(jnp.float32), c_in.astype(jnp.float32)


def apply_mamba(p, cfg: ModelConfig, rules: MeshRules, x,
                state: Optional[dict] = None):
    """x: (B, S, d).  Returns (out, new_state).

    ``state`` = {"conv": (B, k-1, di), "ssm": (B, di, N)} for decode;
    None for train/prefill (zero initial state, no state returned).
    """
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    a_neg = -jnp.exp(p["a_log"])                          # (di, N)

    xz = x @ p["in_proj"]
    xz = constrain(xz, rules, "batch", None, "d_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)

    if state is not None:
        x_c, conv_state = apply_conv1d(p["conv"], x_in, state["conv"])
    else:
        x_c, conv_state = apply_conv1d(p["conv"], x_in), None
    x_c = jax.nn.silu(x_c)

    if state is not None and s == 1:
        # single-step decode: h' = exp(dt A) h + dt B x
        dt, b_in, c_in = _ssm_inputs(p, cfg, x_c)
        dt1, b1, c1, x1 = dt[:, 0], b_in[:, 0], c_in[:, 0], \
            x_c[:, 0].astype(jnp.float32)
        da = jnp.exp(dt1[:, :, None] * a_neg[None])       # (B, di, N)
        db = dt1[:, :, None] * b1[:, None, :] * x1[:, :, None]
        h = da * state["ssm"] + db
        y = jnp.einsum("bdn,bn->bd", h, c1)[:, None]
        new_state = {"conv": conv_state, "ssm": h}
    else:
        chunk = min(cfg.mamba_chunk, s)
        pad = -s % chunk
        xc_p = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0))) if pad else x_c
        nc = (s + pad) // chunk
        xs = xc_p.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
        # padded tail positions must not advance the carried state
        valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)

        def step(h, inp):
            x_chunk, valid_c = inp
            dt, b_in, c_in = _ssm_inputs(p, cfg, x_chunk)
            xf = x_chunk.astype(jnp.float32)
            a = jnp.exp(dt[..., None] * a_neg[None, None])     # (B,C,di,N)
            bx = dt[..., None] * b_in[:, :, None, :] * xf[..., None]
            vc = valid_c[None, :, None, None]
            a = jnp.where(vc, a, 1.0)
            bx = jnp.where(vc, bx, 0.0)
            h_last, h_all = diag_scan_chunk(a, bx, h)
            y = jnp.einsum("bcdn,bcn->bcd", h_all, c_in)
            return h_last, y

        h0 = jnp.zeros((b, di, n), jnp.float32) if state is None \
            else state["ssm"]
        # checkpoint per chunk: backward recomputes the (B,C,di,N)
        # discretized tensors instead of saving them for every chunk
        h_last, ys = jax.lax.scan(jax.checkpoint(step), h0, (xs, valid))
        y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, di)[:, :s]
        new_state = None if state is None else \
            {"conv": conv_state, "ssm": h_last}

    y = (y + p["d_skip"] * x_c.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return constrain(out, rules, "batch", None, None), new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_k - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def abstract_mamba_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_k - 1, cfg.d_inner),
                                     dtype),
        "ssm": jax.ShapeDtypeStruct((batch, cfg.d_inner, cfg.ssm_state),
                                    jnp.float32),
    }
