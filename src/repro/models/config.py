"""Model configuration shared by all 10 assigned architectures.

One dataclass drives the whole stack: dense GQA transformers, SSM
(mamba1), hybrid RG-LRU+local-attention (griffin), MoE, VLM backbones
with stub patch frontends, and encoder-decoder audio models with stub
conv frontends.  Per-arch instances live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # attention
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None    # sliding-window width (local attn)
    use_rope: bool = True

    # mlp
    mlp_act: str = "silu"           # swiglu ("silu") | geglu ("gelu")

    # norm / embedding
    rms_offset: bool = False        # gemma-style (1 + w) rmsnorm scale
    embed_scale: bool = False       # gemma: inputs *= sqrt(d_model)
    tie_embeddings: bool = True

    # moe
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ssm (mamba1)
    ssm_state: int = 16
    d_inner_mult: int = 2
    conv_k: int = 4
    dt_rank: Optional[int] = None   # default ceil(d_model / 16)

    # hybrid layer pattern, cycled over n_layers ("attn" | "rglru" | "mamba")
    pattern: Tuple[str, ...] = ("attn",)
    rglru_width: Optional[int] = None    # default d_model

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0            # stub frontend frames (whisper: 1500)
    cross_attn: bool = False

    # vlm (internvl)
    n_patches: int = 0              # stub patch-embedding count

    # numerics / implementation
    dtype: str = "bfloat16"
    vocab_pad_to: int = 256
    attn_impl: str = "chunked"      # chunked | pallas | naive
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 1024
    remat: bool = True
    loss_chunk: int = 1024
    scan_layers: bool = True
    mamba_chunk: int = 128

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or math.ceil(self.d_model / 16)

    @property
    def resolved_rglru_width(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """The per-layer block kind for all n_layers (pattern cycled)."""
        p = self.pattern
        base = "moe" if self.is_moe else None
        kinds = tuple(p[i % len(p)] for i in range(self.n_layers))
        if base == "moe":
            kinds = tuple("moe" if k == "attn" else k for k in kinds)
        return kinds

    @property
    def pattern_periods(self) -> Tuple[int, int]:
        """(full periods to scan, remainder layers unrolled)."""
        per = len(self.pattern)
        return self.n_layers // per, self.n_layers % per

    def param_count(self) -> int:
        """Exact parameter count (used by roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        h, kv = self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d
        if self.qkv_bias:
            attn += hd * (h + 2 * kv)
        if self.qk_norm:
            attn += 2 * hd
        dense_mlp = 3 * d * self.d_ff
        moe_mlp = (3 * d * self.d_ff * self.n_experts
                   + d * self.n_experts) if self.is_moe else 0
        mamba = 0
        if "mamba" in self.pattern:
            di, n, r = self.d_inner, self.ssm_state, self.resolved_dt_rank
            mamba = (d * 2 * di + di * self.conv_k + di * (r + 2 * n)
                     + r * di + di * n + di + di * d)
        rglru = 0
        if "rglru" in self.pattern:
            w = self.resolved_rglru_width
            rglru = 2 * d * w + 2 * w * self.conv_k + 2 * w * w // 1 \
                + 2 * w + w * d  # in-proj x2, conv, gates, Lambda, out
        total = 0
        for kind in self.layer_kinds:
            total += 2 * d  # pre-norms
            if kind == "attn":
                total += attn + dense_mlp
            elif kind == "moe":
                total += attn + moe_mlp
            elif kind == "mamba":
                total += mamba
            elif kind == "rglru":
                total += rglru + dense_mlp
        total += v * d              # embedding (+ tied head)
        if not self.tie_embeddings:
            total += v * d
        total += d                  # final norm
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_mlp + 2 * d)
            if self.cross_attn:
                total += self.n_layers * (attn + d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        inactive = (self.n_experts - self.moe_top_k) * 3 * d * self.d_ff
        return int(self.param_count() - self.n_layers * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
