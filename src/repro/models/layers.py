"""Primitive layers: params-as-pytrees, functional applies.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with tuples of *logical axis names* (see
``parallel.sharding.MeshRules``) -- the launcher turns them into
NamedShardings.  No framework dependency: plain dicts of jnp arrays.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Initializer = "callable"


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, logical: Tuple, dtype,
               bias: bool = False, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    s = {"w": logical}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (logical[-1],)
    return p, s


def apply_dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d: int, dtype, logical=(None,)):
    # norm scales are tiny: replicate
    return {"scale": jnp.zeros((d,), dtype)}, {"scale": logical}


def apply_rmsnorm(p, x, *, offset: bool = True, eps: float = 1e-6):
    """RMSNorm; ``offset=True`` uses the (1 + w) parametrization (so a
    zero-init scale is the identity -- gemma convention, harmless for
    all others since we init scales to zero)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = p["scale"].astype(jnp.float32)
    return (x * (1.0 + w if offset else w)).astype(dt)


def init_embedding(key, vocab: int, d: int, dtype):
    p = {"table": _normal(key, (vocab, d), 1.0, dtype)}
    return p, {"table": ("vocab", "fsdp")}


def apply_embedding(p, tokens, *, scale: bool = False):
    x = jnp.take(p["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def logits_from_embedding(p, x):
    """Tied LM head: x @ table^T (padded-vocab logits)."""
    return x @ p["table"].T


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "gate": _normal(k1, (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "up": _normal(k2, (d, d_ff), 1.0 / math.sqrt(d), dtype),
        "down": _normal(k3, (d_ff, d), 1.0 / math.sqrt(d_ff), dtype),
    }
    s = {"gate": ("fsdp", "mlp"), "up": ("fsdp", "mlp"),
         "down": ("mlp", "fsdp")}
    return p, s


def apply_mlp(p, x, act: str = "silu"):
    a = x @ p["gate"]
    a = jax.nn.silu(a) if act == "silu" else jax.nn.gelu(a)
    return (a * (x @ p["up"])) @ p["down"]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings (no params).

    ``positions``: int -> arange(int); or a (S,) position array (decode
    computes the embedding at the current offset directly, no table).
    """
    if isinstance(positions, int):
        positions = jnp.arange(positions, dtype=jnp.int32)
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Causal temporal conv (mamba / rglru frontline)
# ---------------------------------------------------------------------------

def init_conv1d(key, channels: int, k: int, dtype):
    p = {"w": _normal(key, (k, channels), 1.0 / math.sqrt(k), dtype),
         "b": jnp.zeros((channels,), dtype)}
    return p, {"w": (None, "d_inner"), "b": ("d_inner",)}


def apply_conv1d(p, x, state=None):
    """Depthwise causal conv along seq.  x: (B, S, C).

    ``state``: (B, k-1, C) carry of trailing inputs for decode; returns
    (y, new_state) when given, else y.
    """
    k = p["w"].shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)      # (B, k-1+S, C)
        new_state = window[:, -(k - 1):, :]
    else:
        window = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = None
    y = sum(window[:, i:i + x.shape[1], :] * p["w"][i]
            for i in range(k)) + p["b"]
    return (y, new_state) if state is not None else y
