"""Modality frontends -- STUBS per the assignment.

``[audio]`` / ``[vlm]`` cells specify the transformer backbone only; the
real conv/ViT towers are out of scope and ``input_specs()`` provides
precomputed frame/patch embeddings.  Each stub is a learned linear
adapter from the stub embedding width to d_model, so the interface (and
its sharding) is real even though the tower is not.
"""

from __future__ import annotations

import math

from .config import ModelConfig
from .layers import _normal, sinusoidal_positions

STUB_WIDTH = 256     # width of the precomputed embeddings fed by data


def init_frontend(key, cfg: ModelConfig, dtype):
    p = {"adapter": _normal(key, (STUB_WIDTH, cfg.d_model),
                            1 / math.sqrt(STUB_WIDTH), dtype)}
    return p, {"adapter": (None, "fsdp")}


def apply_audio_frontend(p, frames):
    """frames: (B, n_frames, STUB_WIDTH) precomputed conv features."""
    x = frames @ p["adapter"]
    pos = sinusoidal_positions(frames.shape[1], x.shape[-1]).astype(x.dtype)
    return x + pos[None]


def apply_patch_frontend(p, patches):
    """patches: (B, n_patches, STUB_WIDTH) precomputed ViT patch embeds."""
    return patches @ p["adapter"]
