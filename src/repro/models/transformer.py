"""Block assembly and full-model forward for all architecture families.

Layers are grouped by the config's ``pattern`` (e.g. griffin's
("rglru", "rglru", "attn")): full periods are *stacked* and driven by one
``lax.scan`` (constant compile time in depth), remainder layers are
unrolled.  Decode threads per-layer recurrent state / KV caches through
the same scan as xs/ys.

Block kinds:
  attn   -- pre-norm attention + pre-norm gated MLP
  moe    -- pre-norm attention + pre-norm MoE FFN
  mamba  -- pre-norm mamba mixer (no MLP; mamba1 convention)
  rglru  -- pre-norm RG-LRU mixer + pre-norm gated MLP
plus whisper's encoder stack and per-layer cross-attention.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .attention import (KVCache, abstract_cache, apply_attention,
                        init_attention, init_cache)
from .config import ModelConfig
from .frontends import (apply_audio_frontend, apply_patch_frontend,
                        init_frontend)
from .layers import (apply_embedding, apply_mlp, apply_rmsnorm,
                     init_embedding, init_mlp, init_rmsnorm,
                     logits_from_embedding, sinusoidal_positions)
from .moe import apply_moe, init_moe
from .rglru import (abstract_rglru_state, apply_rglru, init_rglru,
                    init_rglru_state)
from .ssm import (abstract_mamba_state, apply_mamba, init_mamba,
                  init_mamba_state)

AUX_KEYS = ("load_balance", "router_z", "frac_dropped")


def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, kind: str, dtype,
               cross: bool = False):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["ln1"], s["ln1"] = init_rmsnorm(d, dtype)
    if kind in ("attn", "moe"):
        p["attn"], s["attn"] = init_attention(ks[0], cfg, dtype)
        p["ln2"], s["ln2"] = init_rmsnorm(d, dtype)
        if kind == "moe":
            p["moe"], s["moe"] = init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"], s["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dtype)
    elif kind == "mamba":
        p["mamba"], s["mamba"] = init_mamba(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rglru"], s["rglru"] = init_rglru(ks[0], cfg, dtype)
        p["ln2"], s["ln2"] = init_rmsnorm(d, dtype)
        p["mlp"], s["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cross:
        p["ln_x"], s["ln_x"] = init_rmsnorm(d, dtype)
        p["cross"], s["cross"] = init_attention(ks[2], cfg, dtype,
                                                cross=True)
    return p, s


def apply_block(p, cfg: ModelConfig, rules: MeshRules, kind: str, x,
                positions, *, state=None, cache_pos=None, window=None,
                enc_out=None, cross_state=None, causal=True):
    """Returns (x, new_state, new_cross_state, aux)."""
    aux = _zero_aux()
    new_state, new_cross = state, cross_state
    h = apply_rmsnorm(p["ln1"], x)
    if kind in ("attn", "moe"):
        a, new_state = apply_attention(
            p["attn"], cfg, rules, h, positions, causal=causal,
            window=window, cache=state, cache_pos=cache_pos)
        x = x + a
        if "cross" in p and (enc_out is not None
                             or cross_state is not None):
            hx = apply_rmsnorm(p["ln_x"], x)
            if enc_out is not None:
                # train (no cache) or prefill (fills the cross cache)
                cx, new_cross = apply_attention(
                    p["cross"], cfg, rules, hx, positions, kv_x=enc_out,
                    cache=cross_state,
                    cache_pos=None if cross_state is None else
                    jnp.zeros((), jnp.int32))
            else:                          # decode: static cross cache
                cx, _ = apply_attention(p["cross"], cfg, rules, hx,
                                        positions, cache=cross_state,
                                        update_cache=False)
            x = x + cx
        h2 = apply_rmsnorm(p["ln2"], x)
        if kind == "moe":
            m, aux = apply_moe(p["moe"], cfg, rules, h2)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.mlp_act)
        x = x + m
    elif kind == "mamba":
        m, new_state = apply_mamba(p["mamba"], cfg, rules, h, state=state)
        x = x + m
    elif kind == "rglru":
        r, new_state = apply_rglru(p["rglru"], cfg, rules, h, state=state)
        x = x + r
        x = x + apply_mlp(p["mlp"], apply_rmsnorm(p["ln2"], x), cfg.mlp_act)
    x = constrain(x, rules, "batch", "seq", None)
    return x, new_state, new_cross, aux


def block_state_init(cfg: ModelConfig, kind: str, batch: int, seq: int,
                     dtype, abstract: bool = False):
    """Decode-time state for one block of the given kind (or None)."""
    win = cfg.window if kind in ("attn", "moe") and cfg.window else None
    if kind in ("attn", "moe"):
        fn = abstract_cache if abstract else init_cache
        return fn(cfg, batch, seq, dtype, window=win)
    if kind == "mamba":
        fn = abstract_mamba_state if abstract else init_mamba_state
        return fn(cfg, batch, dtype)
    if kind == "rglru":
        fn = abstract_rglru_state if abstract else init_rglru_state
        return fn(cfg, batch, dtype)
    return None


# ---------------------------------------------------------------------------
# Whole model: params
# ---------------------------------------------------------------------------

def _stack_trees(trees: List):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)


def init_model(key, cfg: ModelConfig):
    """Returns (params, specs).  Layer params of each period position are
    stacked (n_periods, ...); remainder layers unrolled in 'rest'."""
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 8)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}

    p["embed"], s["embed"] = init_embedding(keys[-1], cfg.padded_vocab,
                                            cfg.d_model, dtype)
    p["ln_f"], s["ln_f"] = init_rmsnorm(cfg.d_model, dtype)

    kinds = cfg.layer_kinds
    per = len(cfg.pattern)
    n_periods, n_rest = cfg.pattern_periods
    cross = cfg.cross_attn

    if cfg.scan_layers and n_periods > 1:
        blocks, bspecs = [], []
        for pos in range(per):
            kind = kinds[pos]
            layer_ps = []
            for i in range(n_periods):
                lp, ls = init_block(keys[i * per + pos], cfg, kind, dtype,
                                    cross=cross)
                layer_ps.append(lp)
            blocks.append(_stack_trees(layer_ps))
            bspecs.append(jax.tree.map(
                lambda names: ("stack",) + names, ls,
                is_leaf=lambda t: isinstance(t, tuple)))
        p["blocks"], s["blocks"] = blocks, bspecs
        rest_idx = range(n_periods * per, cfg.n_layers)
    else:
        p["blocks"], s["blocks"] = [], []
        rest_idx = range(cfg.n_layers)

    rest, rspecs = [], []
    for i in rest_idx:
        lp, ls = init_block(keys[i], cfg, kinds[i], dtype, cross=cross)
        rest.append(lp)
        rspecs.append(ls)
    p["rest"], s["rest"] = rest, rspecs

    if cfg.encoder_layers:
        enc, especs = [], []
        ek = jax.random.split(jax.random.fold_in(key, 101),
                              cfg.encoder_layers)
        for i in range(cfg.encoder_layers):
            lp, ls = init_block(ek[i], cfg, "attn", dtype)
            enc.append(lp)
            especs.append(ls)
        p["enc_blocks"] = _stack_trees(enc)
        s["enc_blocks"] = jax.tree.map(
            lambda names: ("stack",) + names, especs[0],
            is_leaf=lambda t: isinstance(t, tuple))
        p["enc_ln_f"], s["enc_ln_f"] = init_rmsnorm(cfg.d_model, dtype)

    if cfg.encoder_seq or cfg.n_patches:
        p["frontend"], s["frontend"] = init_frontend(
            jax.random.fold_in(key, 202), cfg, dtype)
    return p, s


def abstract_model(cfg: ModelConfig):
    """(ShapeDtypeStruct params, specs) without allocating anything."""
    box = {}

    def f(key):
        params, specs = init_model(key, cfg)
        box["specs"] = specs
        return params

    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, box["specs"]


# ---------------------------------------------------------------------------
# Whole model: forward
# ---------------------------------------------------------------------------

def _group_factors(n: int) -> tuple:
    """(G, K, R): n = G*K + R with K ~ sqrt(n) -- hierarchical remat."""
    if n < 4:
        return 0, 1, n
    k = max(2, int(round(n ** 0.5)))
    g = n // k
    return g, k, n - g * k


def _scan_blocks(p_blocks, cfg, rules, x, positions, states, cache_pos,
                 enc_out, cross_states, remat: bool):
    """Hierarchically-scanned stacked periods: outer scan over ~sqrt(L)
    checkpointed groups of ~sqrt(L) checkpointed periods.  Saved
    residuals drop from O(L) layer boundaries to O(sqrt(L)) group
    boundaries + O(sqrt(L)) transient inner boundaries -- without this,
    the 61-layer 1T config keeps a 13 GB/device activation stack alive.

    states: list per period position of stacked block states (or None).
    Returns (x, new_states, new_crosses, aux)."""
    per = len(cfg.pattern)
    kinds = cfg.layer_kinds

    def period_body(x, xs):
        ps, sts, cross_sts = xs
        new_sts, new_crosses, aux_acc = [], [], _zero_aux()
        for pos in range(per):
            kind = kinds[pos]
            win = cfg.window if kind in ("attn", "moe") else None
            x, ns, nc, aux = apply_block(
                ps[pos], cfg, rules, kind, x, positions,
                state=sts[pos], cache_pos=cache_pos, window=win,
                enc_out=enc_out,
                cross_state=cross_sts[pos] if cross_sts else None)
            new_sts.append(ns)
            new_crosses.append(nc)
            aux_acc = {k: aux_acc[k] + aux[k] for k in AUX_KEYS}
        return x, (new_sts, new_crosses, aux_acc)

    body = jax.checkpoint(period_body) if remat else period_body
    xs = (p_blocks, states, cross_states)
    n = jax.tree.leaves(p_blocks)[0].shape[0]
    g, k, r = _group_factors(n)

    ys_parts = []
    if g:
        head = jax.tree.map(
            lambda a: a[:g * k].reshape((g, k) + a.shape[1:]), xs)

        def group_body(x, xs_g):
            return jax.lax.scan(body, x, xs_g)

        gbody = jax.checkpoint(group_body) if remat else group_body
        x, ys_h = jax.lax.scan(gbody, x, head)
        ys_parts.append(jax.tree.map(
            lambda a: a.reshape((g * k,) + a.shape[2:]), ys_h))
    if r:
        tail = jax.tree.map(lambda a: a[g * k:], xs)
        x, ys_t = jax.lax.scan(body, x, tail)
        ys_parts.append(ys_t)
    ys = ys_parts[0] if len(ys_parts) == 1 else jax.tree.map(
        lambda *aa: jnp.concatenate(aa, axis=0), *ys_parts)
    new_states, new_crosses, auxes = ys
    aux = {key: jnp.sum(auxes[key]) for key in AUX_KEYS}
    return x, new_states, new_crosses, aux


def forward(p, cfg: ModelConfig, rules: MeshRules, batch: Dict, *,
            state=None, cache_pos=None):
    """Full forward.  Returns (logits, new_state, aux).

    batch keys: "tokens" (B, S) always; "frames" (audio), "patch_embeds"
    (vlm) when the family needs them.  state/cache_pos enable decode.
    ``state`` layout: {"blocks": [per period position stacked states],
    "rest": [...], "cross": [...]} -- see ``init_decode_state``.
    """
    tokens = batch["tokens"]
    b, s_tok = tokens.shape
    dtype = jnp.dtype(cfg.dtype)

    x = apply_embedding(p["embed"], tokens, scale=cfg.embed_scale)
    n_prefix = 0
    if cfg.n_patches and "patch_embeds" in batch:
        px = apply_patch_frontend(p["frontend"], batch["patch_embeds"])
        x = jnp.concatenate([px.astype(dtype), x], axis=1)
        n_prefix = px.shape[1]
    if not cfg.use_rope and cfg.encoder_layers:
        # whisper decoder: sinusoidal absolute positions (computed at the
        # live offsets -- no table, works at any decode position)
        pos0 = cache_pos if cache_pos is not None else 0
        pe = sinusoidal_positions(
            pos0 + jnp.arange(x.shape[1], dtype=jnp.int32), cfg.d_model)
        x = x + pe.astype(dtype)[None]
    x = constrain(x, rules, "batch", "seq", None)

    positions = (jnp.arange(x.shape[1], dtype=jnp.int32)
                 if cache_pos is None
                 else cache_pos + jnp.arange(x.shape[1], dtype=jnp.int32))

    # ---- encoder (whisper) ------------------------------------------------
    enc_out = None
    if cfg.encoder_layers and "frames" in batch:
        e = apply_audio_frontend(p["frontend"], batch["frames"])
        e = constrain(e.astype(dtype), rules, "batch", "seq", None)
        e_pos = jnp.arange(e.shape[1], dtype=jnp.int32)

        def enc_body(h, lp):
            h, _, _, _ = apply_block(lp, cfg, rules, "attn", h, e_pos,
                                     causal=False)
            return h, None

        body = jax.checkpoint(enc_body) if cfg.remat else enc_body
        e, _ = jax.lax.scan(body, e, p["enc_blocks"])
        enc_out = apply_rmsnorm(p["enc_ln_f"], e)

    # ---- decoder stack ------------------------------------------------------
    state = state or {}
    blocks_state = state.get("blocks")
    per = len(cfg.pattern)
    n_periods, _ = cfg.pattern_periods
    aux_total = _zero_aux()
    new_state = {"blocks": None, "rest": [], "cross": state.get("cross")}

    if p["blocks"]:
        sts = blocks_state if blocks_state is not None else [None] * per
        x, new_blocks, new_crosses, aux = _scan_blocks(
            p["blocks"], cfg, rules, x, positions, sts, cache_pos,
            enc_out, state.get("cross"), cfg.remat)
        new_state["blocks"] = new_blocks
        if state.get("cross") is not None:
            new_state["cross"] = new_crosses
        aux_total = {k: aux_total[k] + aux[k] for k in AUX_KEYS}
        kinds_rest = cfg.layer_kinds[n_periods * per:]
    else:
        kinds_rest = cfg.layer_kinds

    new_state["cross_rest"] = state.get("cross_rest")
    rest_states = state.get("rest") or [None] * len(p["rest"])
    cross_rest = state.get("cross_rest") or [None] * len(p["rest"])
    new_cross_rest = []
    for lp, kind, st, cst in zip(p["rest"], kinds_rest, rest_states,
                                 cross_rest):
        win = cfg.window if kind in ("attn", "moe") else None
        fn = jax.checkpoint(partial(
            apply_block, cfg=cfg, rules=rules, kind=kind,
            window=win)) if cfg.remat and cache_pos is None else partial(
            apply_block, cfg=cfg, rules=rules, kind=kind, window=win)
        x, ns, nc, aux = fn(lp, x=x, positions=positions, state=st,
                            cache_pos=cache_pos, enc_out=enc_out,
                            cross_state=cst)
        new_state["rest"].append(ns)
        new_cross_rest.append(nc)
        aux_total = {k: aux_total[k] + aux[k] for k in AUX_KEYS}
    if state.get("cross_rest") is not None:
        new_state["cross_rest"] = new_cross_rest

    x = apply_rmsnorm(p["ln_f"], x)
    if n_prefix and cache_pos is None:
        x = x[:, n_prefix:]
    return x, new_state, aux_total


def logits(p, x):
    return logits_from_embedding(p["embed"], x)


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      abstract: bool = False) -> Dict:
    dtype = jnp.dtype(cfg.dtype)
    per = len(cfg.pattern)
    n_periods, _ = cfg.pattern_periods
    kinds = cfg.layer_kinds
    scan = cfg.scan_layers and n_periods > 1

    def stacked(kind):
        one = block_state_init(cfg, kind, batch, max_len, dtype, abstract)
        if one is None:
            return None
        if abstract:
            return jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((n_periods,) + l.shape,
                                               l.dtype), one)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_periods,) + l.shape),
            one)

    st: Dict[str, Any] = {"blocks": None, "rest": [], "cross": None}
    if scan:
        st["blocks"] = [stacked(kinds[pos]) for pos in range(per)]
        rest_kinds = kinds[n_periods * per:]
    else:
        rest_kinds = kinds
    st["rest"] = [block_state_init(cfg, k, batch, max_len, dtype, abstract)
                  for k in rest_kinds]
    if cfg.cross_attn and cfg.encoder_seq:
        shape = (batch, cfg.encoder_seq, cfg.n_kv_heads,
                 cfg.resolved_head_dim)
        mk = (lambda: jax.ShapeDtypeStruct(shape, dtype)) if abstract \
            else (lambda: jnp.zeros(shape, dtype))
        ccs = [KVCache(mk(), mk(), False) for _ in range(cfg.n_layers)]
        if scan:
            st["cross"] = [jax.tree.map(
                lambda *ls: (jax.ShapeDtypeStruct(
                    (n_periods,) + ls[0].shape, ls[0].dtype) if abstract
                    else jnp.stack(ls)),
                *[ccs[i * per + pos] for i in range(n_periods)])
                for pos in range(per)]
            st["cross_rest"] = ccs[n_periods * per:]
        else:
            st["cross_rest"] = ccs
    return st
