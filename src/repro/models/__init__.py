"""LM-family model stack covering all 10 assigned architectures."""

from .config import ModelConfig, ShapeConfig, SHAPES
from .model import (loss_fn, make_train_step, make_eval_step, make_prefill,
                    make_serve_step, input_specs, abstract_params,
                    abstract_decode_state)
from .transformer import init_model, abstract_model, forward, init_decode_state
