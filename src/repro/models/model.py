"""Public model API: init / loss / train_step / prefill / decode.

Everything here is jit-friendly and abstract-input-friendly: the
multi-pod dry-run lowers ``make_train_step(...)`` / ``make_serve_step``
from ShapeDtypeStructs without allocating parameters.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..parallel.sharding import MeshRules, constrain
from .config import ModelConfig, ShapeConfig
from .transformer import (abstract_model, forward, init_decode_state,
                          logits as lm_logits)


# ---------------------------------------------------------------------------
# Loss (chunked over the sequence -- never materializes (B, S, V) logits)
# ---------------------------------------------------------------------------

def chunked_xent(p, cfg: ModelConfig, x, labels, rules: MeshRules):
    """x: (B, S, d) final hidden; labels: (B, S) int32, -1 = masked.

    Returns (sum_nll, n_valid).  Scans seq chunks; each chunk computes
    (B, C, V) logits, its xent, and drops them.
    """
    b, s, d = x.shape
    chunk = min(cfg.loss_chunk, s)
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        # checkpointed: backward recomputes this chunk's (B, C, Vpad)
        # logits instead of saving all nc of them (the classic blowup)
        nll, n = carry
        xc, lc = inp
        lg = lm_logits(p, xc).astype(jnp.float32)          # (B, C, Vpad)
        lg = constrain(lg, rules, "batch", None, "vocab")
        lse = jax.nn.logsumexp(lg, axis=-1)
        valid = lc >= 0
        lab = jnp.where(valid, lc, 0)
        picked = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
        nll_c = jnp.where(valid, lse - picked, 0.0)
        return (nll + jnp.sum(nll_c), n + jnp.sum(valid)), None

    (nll, n), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                      jnp.zeros((), jnp.int32)), (xs, ls))
    return nll, n


def loss_fn(params, cfg: ModelConfig, rules: MeshRules, batch: Dict):
    x, _, aux = forward(params, cfg, rules, batch)
    nll, n = chunked_xent(params, cfg, x, batch["labels"], rules)
    loss = nll / jnp.maximum(n, 1)
    if cfg.is_moe:
        loss = loss + cfg.router_aux_coef * aux["load_balance"] \
            + 1e-4 * aux["router_z"]
    metrics = {"nll": nll, "tokens": n, **aux}
    return loss, metrics


# ---------------------------------------------------------------------------
# Train / serve step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, rules: MeshRules, optimizer,
                    microbatches: int = 1, param_shardings=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, out).

    ``optimizer``: repro.optim.Optimizer.  ``microbatches`` > 1 splits the
    global batch and accumulates grads with a scan (memory knob).
    ``param_shardings``: NamedSharding tree pinning the grad-accumulator
    scan carry -- without it GSPMD may replicate the carry, which at the
    1T-param scale is ~130 GB/device of phantom state.
    """

    def pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, rules, batch)

    def step(params, opt_state, batch):
        if microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, b_i):
                gsum, lsum = carry
                (l, m), g = grads_of(params, b_i)
                return (pin(jax.tree.map(jnp.add, gsum, g)), lsum + l), m

            zeros = pin(jax.tree.map(jnp.zeros_like, params))
            (gsum, lsum), ms = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], ms)
        else:
            (loss, metrics), grads = grads_of(params, batch)
        params, opt_state, gnorm = optimizer.update(params, grads,
                                                    opt_state)
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out

    return step


def make_eval_step(cfg: ModelConfig, rules: MeshRules):
    def step(params, batch):
        return loss_fn(params, cfg, rules, batch)
    return step


def make_compressed_pod_train_step(cfg: ModelConfig, rules: MeshRules,
                                   optimizer):
    """Train step with int8+error-feedback gradient sync across pods.

    Distributed-optimization trick for the 2x16x16 mesh: the intra-pod
    gradient reduction stays exact (fast ICI), but the pod-to-pod hop --
    the slow data-center link -- carries int8 blocks (4x fewer bytes
    than f32).  Implemented as a partial-manual shard_map over the
    "pod" axis only: inside, each pod runs the normal auto-sharded
    loss/grad over its ("data","model") sub-mesh, then the compressed
    psum crosses pods with a per-leaf error-feedback residual carried in
    the optimizer-adjacent state.

    step(params, opt_state, residuals, batch)
      -> (params, opt_state, residuals, out)
    """
    import dataclasses as dc
    from ..optim.compression import CompressedAllReduce

    mesh = rules.mesh
    assert mesh is not None and "pod" in mesh.axis_names
    inner_rules = dc.replace(rules, batch="data")   # per-pod rules
    car = CompressedAllReduce(axis="pod")

    def pod_body(params, opt_state, residuals, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, inner_rules, batch)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(residuals)
        synced, new_r = [], []
        for g, r in zip(flat_g, flat_r):
            s, nr = car(g.astype(jnp.float32), r)
            synced.append(s.astype(g.dtype))
            new_r.append(nr)
        grads = jax.tree.unflatten(tdef, synced)
        residuals = jax.tree.unflatten(tdef, new_r)
        loss = jax.lax.pmean(loss, "pod")
        params, opt_state, gnorm = optimizer.update(params, grads,
                                                    opt_state)
        out = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, residuals, out

    from jax.sharding import PartitionSpec as P

    def step(params, opt_state, residuals, batch):
        b_specs = jax.tree.map(
            lambda x: P(*(("pod",) + (None,) * (x.ndim - 1))), batch)
        # prefix specs: P() = replicated across pods (manual axis only;
        # data/model sharding stays under automatic propagation)
        from ..parallel.compat import shard_map
        return shard_map(
            pod_body, mesh=mesh,
            in_specs=(P(), P(), P(), b_specs),
            out_specs=(P(), P(), P(), P()),
            axis_names={"pod"})(
            params, opt_state, residuals, batch)

    return step


def make_prefill(cfg: ModelConfig, rules: MeshRules):
    """prefill(params, batch) -> (last-position logits, decode state).

    Runs the full forward on the prompt while *writing* the KV caches /
    recurrent states, so decode can continue from ``pos = prompt_len``.
    """

    def prefill(params, batch, state):
        tokens = batch["tokens"]
        x, new_state, _ = forward(params, cfg, rules, batch, state=state,
                                  cache_pos=jnp.zeros((), jnp.int32))
        lg = lm_logits(params, x[:, -1:, :])
        return lg, new_state

    return prefill


def make_serve_step(cfg: ModelConfig, rules: MeshRules):
    """serve_step(params, state, token, pos) -> (logits, state).

    One decode step: token (B, 1) given a populated cache at ``pos``.
    This is what the decode_* / long_* dry-run cells lower.
    """

    def serve_step(params, state, token, pos):
        batch = {"tokens": token}
        x, new_state, _ = forward(params, cfg, rules, batch, state=state,
                                  cache_pos=pos)
        lg = lm_logits(params, x)
        lg = constrain(lg, rules, "batch", None, "vocab")
        return lg, new_state

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs for the dry-run
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    b = shape.global_batch
    sd = jax.ShapeDtypeStruct
    dtype = jnp.dtype(cfg.dtype)
    from .frontends import STUB_WIDTH

    if shape.kind == "decode":
        return {"token": sd((b, 1), jnp.int32)}

    s = shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.n_patches:
        specs["patch_embeds"] = sd((b, cfg.n_patches, STUB_WIDTH), dtype)
        s = s - cfg.n_patches       # patches count toward the cell's seq
    if cfg.encoder_seq:
        specs["frames"] = sd((b, cfg.encoder_seq, STUB_WIDTH), dtype)
    specs["tokens"] = sd((b, s), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = sd((b, s), jnp.int32)
    return specs


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    return init_decode_state(cfg, shape.global_batch, shape.seq_len,
                             abstract=True)


def abstract_params(cfg: ModelConfig):
    return abstract_model(cfg)


def decode_state_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Logical-axis names for every decode-state leaf.

    KV caches shard (batch, kv_seq); recurrent states shard their
    channel dim; whisper cross caches shard batch only (1500 frames is
    not model-axis divisible and is tiny).  Stacked-layer leading dims
    get the "stack" logical axis (replicated).
    """
    state = abstract_decode_state(cfg, shape)

    def names(path, leaf):
        keys = [str(getattr(p, "name", getattr(p, "key", getattr(
            p, "idx", "")))) for p in path]
        stacked = any(k in ("blocks", "cross") for k in keys)
        prefix = ("stack",) if stacked else ()
        cross = any("cross" in k for k in keys)
        last = keys[-1] if keys else ""
        nd = len(leaf.shape) - len(prefix)
        if last in ("k", "v"):
            if cross:
                return prefix + ("batch",) + (None,) * (nd - 1)
            return prefix + ("batch", "kv_seq") + (None,) * (nd - 2)
        if last == "conv":
            return prefix + ("batch", None, "d_inner")
        if last == "ssm":
            return prefix + ("batch", "d_inner", None)
        if last == "h":
            return prefix + ("batch", "d_inner")
        return prefix + ("batch",) + (None,) * (nd - 1)

    return jax.tree_util.tree_map_with_path(names, state)
