"""Roofline tooling (loop-aware HLO cost analysis + hardware model)
and runtime-trace exporters (Chrome trace / JSONL / jax.profiler)."""

from .hlo_analysis import analyze_hlo, Costs
from .roofline import (HW, roofline_terms, model_flops, RooflineReport)
from .trace import (jax_profiler_trace, to_chrome_trace,
                    write_chrome_trace, write_jsonl)
