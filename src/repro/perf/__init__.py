"""Roofline tooling: loop-aware HLO cost analysis + hardware model."""

from .hlo_analysis import analyze_hlo, Costs
from .roofline import (HW, roofline_terms, model_flops, RooflineReport)
