"""Trace exporters for the runtime telemetry stream.

Thin, dependency-free views over ``repro.obs.telemetry`` records:

  * **Chrome trace** (``chrome://tracing`` / Perfetto / speedscope):
    every span becomes a complete ``"ph": "X"`` duration event on its
    emitting thread, so the async checkpoint writer's D2H/file-write
    lanes render *under* the main thread's segment lane and the
    double-buffered overlap is visible instead of inferred; structured
    events become instant (``"ph": "i"``) markers.
  * **JSONL**: the raw record stream (``Telemetry.flush_jsonl`` is the
    incremental writer; ``write_jsonl`` here is the one-shot export for
    already-collected record lists).
  * **jax.profiler wrapper**: the opt-in deep profile
    (``repro.launch.sim --trace-dir``) capturing XLA/TFRT internals --
    heavyweight, so it is a separate flag from the always-cheap span
    tracer.
"""

from __future__ import annotations

import contextlib
import json
import os
from typing import List, Optional

from ..obs.telemetry import FORMAT, Telemetry

__all__ = ["to_chrome_trace", "write_chrome_trace", "write_jsonl",
           "jax_profiler_trace"]


def to_chrome_trace(records: List[dict], pid: Optional[int] = None) -> dict:
    """Convert telemetry records to the Chrome Trace Event JSON format.

    Spans map to complete events (``ph: "X"``; microsecond ``ts`` /
    ``dur`` relative to the tracer epoch), events to instant markers
    scoped to their thread, and each thread gets a ``thread_name``
    metadata event so the viewer shows ``MainThread`` vs the writer
    daemons by name.
    """
    pid = os.getpid() if pid is None else pid
    trace_events: List[dict] = []
    thread_names = {}
    for rec in records:
        if rec.get("type") == "span":
            thread_names.setdefault(rec["tid"], rec["thread"])
            args = dict(rec.get("attrs", {}))
            if rec.get("parent"):
                args["parent"] = rec["parent"]
            args["depth"] = rec["depth"]
            trace_events.append({
                "name": rec["name"], "cat": "span", "ph": "X",
                "ts": rec["t0"] * 1e6, "dur": rec["dur"] * 1e6,
                "pid": pid, "tid": rec["tid"], "args": args,
            })
        elif rec.get("type") in ("event", "metrics"):
            payload = {k: v for k, v in rec.items()
                       if k not in ("type", "kind", "t")}
            trace_events.append({
                "name": rec["kind"], "cat": rec["type"], "ph": "i",
                "ts": rec["t"] * 1e6, "pid": pid, "tid": 0, "s": "p",
                "args": payload,
            })
    for tid, name in sorted(thread_names.items()):
        trace_events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name},
        })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms",
            "otherData": {"format": FORMAT}}


def write_chrome_trace(source, path: str) -> str:
    """Write a Chrome trace JSON for ``source`` (a ``Telemetry`` tracer
    or a raw record list); returns ``path``.  Load it in
    ``chrome://tracing`` or https://ui.perfetto.dev."""
    records = source.records() if isinstance(source, Telemetry) else source
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(to_chrome_trace(records), f, indent=1)
    return path


def write_jsonl(source, path: str) -> int:
    """One-shot JSONL export (header + every record).  For incremental
    exactly-once appends during a run use ``Telemetry.flush_jsonl``."""
    if isinstance(source, Telemetry):
        return source.flush_jsonl(path)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps({"type": "header", "format": FORMAT,
                            "pid": os.getpid()}) + "\n")
        for rec in source:
            f.write(json.dumps(rec) + "\n")
    return len(source)


@contextlib.contextmanager
def jax_profiler_trace(trace_dir: Optional[str]):
    """Opt-in ``jax.profiler.trace`` wrapper (``--trace-dir``).

    ``None`` is a no-op, so call sites wrap unconditionally.  The
    profile (TensorBoard / Perfetto protobuf under ``trace_dir``)
    captures device/XLA internals the host-side span tracer cannot see;
    it is heavyweight, so it stays separate from the always-cheap spans.
    """
    if trace_dir is None:
        yield
        return
    import jax
    os.makedirs(trace_dir, exist_ok=True)
    with jax.profiler.trace(trace_dir):
        yield
