"""Kernelized-attention memory credit.

The dry-run lowers attention in its pure-jnp chunked form, whose
(cq, ck) score/probability chunks round-trip HBM -- that is what the
analyzer (correctly) counts.  On the TPU target the validated Pallas
flash kernel (kernels/flash_attention.py) keeps those chunks in VMEM:
HBM traffic reduces to the q/k/v/out streams (+ L stats).

This module computes the per-device HBM bytes of those intermediate
chunks analytically, so the roofline can report both:

    memory_s (as compiled)       -- jnp-chunked lowering
    memory_s (flash kernel)      -- minus the VMEM-resident traffic

The credit is exact arithmetic over the same chunk loop the code runs:
per (q-chunk, k-chunk) pair the jnp path materializes s, mask-select, p
(f32, cq x ck) on the forward, and s, p, dp, ds on the backward, for
every (batch, head) slice on the device; the kernel writes none of them.
"""

from __future__ import annotations

from ..models.config import ModelConfig, ShapeConfig


def _attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for k in cfg.layer_kinds if k in ("attn", "moe")) + \
        cfg.encoder_layers + (cfg.n_layers if cfg.cross_attn else 0)


def chunk_traffic_bytes(cfg: ModelConfig, shape: ShapeConfig,
                        chips: int = 256, model_axis: int = 16,
                        microbatches: int = 1) -> float:
    """Per-device HBM bytes of attention (cq, ck) intermediates."""
    if shape.is_decode:
        return 0.0                       # decode path has no chunk loop
    s = shape.seq_len
    if cfg.window:
        s_k_eff = min(cfg.window * 2, s)     # block-sparse liveness
    else:
        s_k_eff = s
    cq = min(cfg.attn_chunk_q, s)
    ck = min(cfg.attn_chunk_k, s)
    nq = -(-s // cq)
    nk = -(-s_k_eff // ck) if not cfg.window else -(-s_k_eff // ck)
    # causal: ~half the (q, k) pairs are live
    live_pairs = nq * nk / 2 if not cfg.window else nq * min(nk, 3)

    b_local = max(shape.global_batch // (chips // model_axis), 1)
    b_local = max(b_local // microbatches, 1)
    heads_sharded = cfg.n_heads % model_axis == 0
    h_local = cfg.n_heads // model_axis if heads_sharded else cfg.n_heads

    chunk_bytes = cq * ck * 4.0              # one f32 (cq, ck) tensor
    # forward: s + p (2 tensors, write+read each -> 4 passes);
    # backward: s, p, dp, ds (4 tensors -> 8 passes);
    # + remat replays forward once inside jax.checkpoint (4 more)
    passes = 4 + (8 + 4 if shape.kind == "train" else 0)
    per_layer = live_pairs * b_local * h_local * chunk_bytes * passes
    return per_layer * _attn_layers(cfg) * microbatches
