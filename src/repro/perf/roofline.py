"""Roofline terms from the dry-run's compiled artifact.

Per (arch x shape x mesh), using TPU v5e-class constants:

    compute    = device_FLOPs / peak_FLOP/s          (197e12 bf16)
    memory     = device_bytes / HBM_bw               (819e9 B/s)
    collective = device_collective_wire_bytes / ICI  (50e9 B/s per link)

``device_*`` come from the loop-aware HLO analysis of the partitioned
module (per-device program), so term = global / (chips x per-chip-rate)
whenever work is balanced.  The dominant term is the bottleneck; the
perf loop drives it down.  MODEL_FLOPS (6*N*D train / 2*N*D prefill /
2*N_active*B decode) over HLO dot-FLOPs measures how much compiled
compute is *useful* -- remat and redundancy show up here.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..models.config import ModelConfig, ShapeConfig
from .hlo_analysis import Costs


@dataclasses.dataclass(frozen=True)
class HW:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs for the cell (6ND / 2ND / 2NB convention)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops: float                     # per-device
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    peak_bytes_per_device: Optional[float] = None
    notes: tuple = ()

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound on step time."""
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def useful_frac(self) -> float:
        """MODEL_FLOPS / global HLO dot FLOPs."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline this step achieves: useful
        model FLOPs over (step-time x peak), per chip."""
        denom = self.step_time_s * self.chips
        if denom <= 0:
            return 0.0
        return self.model_flops / denom / HW().peak_flops

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 useful_frac=self.useful_frac,
                 roofline_frac=self.roofline_frac)
        return d


def roofline_terms(arch: str, shape: str, mesh_name: str, chips: int,
                   costs: Costs, mflops: float,
                   peak_bytes: Optional[float] = None,
                   hw: HW = HW()) -> RooflineReport:
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        compute_s=costs.flops / hw.peak_flops,
        memory_s=costs.bytes / hw.hbm_bw,
        collective_s=costs.coll_bytes / hw.ici_bw,
        model_flops=mflops,
        hlo_flops=costs.flops, hlo_bytes=costs.bytes,
        coll_bytes=costs.coll_bytes, coll_by_kind=dict(costs.coll_by_kind),
        peak_bytes_per_device=peak_bytes,
        notes=tuple(costs.notes[:8]))
