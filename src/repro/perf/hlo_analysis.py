"""Loop-aware cost analysis of compiled (SPMD-partitioned) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body
**once**, but the whole framework leans on ``lax.scan`` (layer stacks,
attention chunking, loss chunking, SSM chunking), so XLA's own numbers
under-count by orders of magnitude.  XLA does annotate every counted
loop with ``backend_config={"known_trip_count":{"n":"N"}}`` -- this
module parses the HLO text, walks the call graph (fusions, while
bodies, to_apply reducers), and weights every computation by the product
of enclosing trip counts.

Outputs per-device totals:
  * ``flops``        -- dots counted exactly from shapes + contracting
                        dims; elementwise ops approximated as 1 flop per
                        output element;
  * ``bytes``        -- operand + result bytes at fusion boundaries
                        (mirrors XLA's "bytes accessed" convention);
  * ``coll_bytes``   -- wire bytes of collectives, with standard ring
                        cost conventions: all-gather/all-to-all
                        (s-1)/s x result, all-reduce 2(s-1)/s x result,
                        reduce-scatter (s-1) x result, permute 1 x;
  * ``coll_by_kind`` -- breakdown for the roofline's collective term.

This is a structural estimator, not a simulator: it is used for
*relative* hillclimbing deltas and absolute roofline terms at the
+/-10% level, which the dry-run workflow needs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# async variants: <op>-start carries the cost, <op>-done is free
_COLLECTIVE_STARTS = tuple(c + "-start" for c in COLLECTIVES)

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "logistic", "negate",
    "abs", "floor", "ceil", "round-nearest-afz", "select", "compare",
    "and", "or", "not", "xor", "clamp", "sine", "cosine", "expm1",
    "log1p", "sign", "convert", "reduce", "exponential-minus-one",
}

# ops a TPU fusion absorbs: no HBM traffic of their own -- reads resolve
# through them to the nearest materialized producer
_FUSABLE_OPS = _ELEMENTWISE_FLOP_OPS | {
    "broadcast", "copy", "transpose", "pad", "slice", "reverse", "iota",
    "concatenate", "bitcast-convert", "reduce-precision", "tan", "erf",
    "cbrt", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "popcnt", "clz", "real", "imag", "is-finite", "atan2", "rem",
}

_ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "rng-state",
    "opt-barrier", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "all-to-all-done", "reduce-scatter-done",
    "copy-start", "copy-done", "send", "send-done", "recv", "recv-done",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    notes: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "Costs", weight: float = 1.0):
        self.flops += other.flops * weight
        self.bytes += other.bytes * weight
        self.coll_bytes += other.coll_bytes * weight
        self.dot_flops += other.dot_flops * weight
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * weight
        self.notes.extend(n for n in other.notes if n not in self.notes)


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_shape(s: str) -> Optional[Shape]:
    m = _SHAPE_RE.match(s.strip())
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return Shape(m.group(1), dims)


def _parse_shapes(s: str) -> List[Shape]:
    """Parse 'f32[2,3]{1,0}' or '(f32[2], s32[])' into shapes."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        if m.group(1) in _DTYPE_BYTES or m.group(1) in (
                "f32", "bf16", "s32"):
            out.append(Shape(m.group(1), dims))
    return out


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    shapes: List[Shape]             # result shape(s)
    operands: List[str]             # %names
    attrs: str                      # raw attr tail

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_elems(self) -> int:
        return sum(s.elems for s in self.shapes)


_COMP_HEADER = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*)$")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _split_rhs(rhs: str) -> Tuple[str, str, str, str]:
    """rhs -> (shape_str, op, operand_str, attr_str)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                end = i
                break
        shape_str, rest = rhs[:end + 1], rhs[end + 1:].strip()
    else:
        sp = rhs.find(" ")
        shape_str, rest = rhs[:sp], rhs[sp + 1:]
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return shape_str, rest.split("(")[0], "", ""
    op = m.group(1)
    depth, start = 0, m.end() - 1
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            break
    return shape_str, op, rest[start + 1:i], rest[i + 1:]


def parse_computations(txt: str) -> Dict[str, dict]:
    """Line-based: computation headers start at column 0 (instructions
    are indented); params may contain nested tuple-typed parens."""
    comps: Dict[str, dict] = {}
    cur: Optional[dict] = None
    for line in txt.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HEADER.match(line.strip())
            if m:
                params: Dict[str, Shape] = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*([\w\[\],]+)",
                                      m.group(3)):
                    sh = _parse_shape(pm.group(2))
                    if sh:
                        params[pm.group(1)] = sh
                cur = {"params": params, "instrs": [],
                       "entry": bool(m.group(1))}
                comps[m.group(2)] = cur
            else:
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        shape_str, op, opnd, attrs = _split_rhs(m.group(2))
        cur["instrs"].append(Instruction(
            name=m.group(1), op=op, shapes=_parse_shapes(shape_str),
            operands=_OPERAND.findall(opnd), attrs=attrs))
    return comps


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 2


def _dot_flops(instr: Instruction, shapes_of) -> float:
    out = instr.shapes[0]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    lhs_sh = shapes_of(instr.operands[0]) if instr.operands else None
    if lhs_sh is None or not m:
        return 2.0 * out.elems        # degraded estimate
    contract = 1
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(lhs_sh.dims):
            contract *= lhs_sh.dims[d]
    return 2.0 * out.elems * contract


def _trip_count(attrs: str) -> Optional[int]:
    m = re.search(r'known_trip_count[="\{:]+n[":]+(\d+)', attrs)
    return int(m.group(1)) if m else None


def analyze_hlo(txt: str) -> Costs:
    comps = parse_computations(txt)
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    memo: Dict[Tuple[str, bool], Costs] = {}

    def called_names(attrs: str) -> Dict[str, str]:
        out = {}
        for key in ("calls", "condition", "body", "to_apply",
                    "branch_computations"):
            m = re.search(key + r"=\{?%?([\w\.\-]+)", attrs)
            if m:
                out[key] = m.group(1)
        return out

    def comp_cost(name: str, boundary_only: bool = False) -> Costs:
        key = (name, boundary_only)
        if key in memo:
            return memo[key]
        memo[key] = Costs()              # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        table: Dict[str, Shape] = dict(comp["params"])
        producer: Dict[str, Instruction] = {}
        for ins in comp["instrs"]:
            if ins.shapes:
                table[ins.name] = ins.shapes[0]
            producer[ins.name] = ins

        def shapes_of(op_name):
            return table.get(op_name)

        def resolved_bytes(op_name, depth=0) -> float:
            """Read cost of an operand on the TPU target: fusable
            elementwise/layout chains (incl. the f32 shadows XLA:CPU's
            bf16 legalization inserts) resolve to the bytes of the
            nearest *materialized* ancestor."""
            ins = producer.get(op_name)
            if ins is None:              # computation parameter
                sh = table.get(op_name)
                return sh.bytes if sh else 0.0
            if ins.op in _FUSABLE_OPS and depth < 24:
                if ins.operands:
                    return max((resolved_bytes(o, depth + 1)
                                for o in ins.operands[:3]), default=0.0)
                return 0.0
            if ins.op in ("dynamic-slice",):
                return ins.out_bytes
            return ins.out_bytes if ins.shapes else 0.0

        total = Costs()
        for ins in comp["instrs"]:
            op = ins.op
            if op in _ZERO_COST_OPS:
                continue
            called = called_names(ins.attrs)
            operand_bytes = sum(resolved_bytes(o) for o in ins.operands
                                if o in table)
            if op == "while":
                trips = _trip_count(ins.attrs) or 1
                if _trip_count(ins.attrs) is None:
                    total.notes.append(f"while {ins.name}: unknown trip "
                                       "count, weighted 1")
                body = comp_cost(called.get("body", ""), False)
                total.add(body, trips)
                continue
            if op in COLLECTIVES or op in _COLLECTIVE_STARTS:
                kind = op.replace("-start", "")
                # wire bytes at the *pre-legalization* width: resolve
                # through converts (TPU moves bf16, CPU-HLO shows f32)
                size = min(float(ins.out_bytes) if ins.shapes else 0.0,
                           operand_bytes
                           or (float(ins.out_bytes) if ins.shapes else 0.0))
                if kind == "all-gather":
                    size = float(ins.out_bytes) if ins.shapes else 0.0
                g = _group_size(ins.attrs)
                if kind == "all-reduce":
                    wire = 2.0 * size * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = size * (g - 1)
                elif kind == "collective-permute":
                    wire = size
                else:                    # all-gather / all-to-all
                    wire = size * (g - 1) / g
                total.coll_bytes += wire
                total.coll_by_kind[kind] = \
                    total.coll_by_kind.get(kind, 0.0) + wire
                total.bytes += size + operand_bytes
                continue
            if op == "fusion":
                inner = comp_cost(called.get("calls", ""), True)
                total.flops += inner.flops
                total.dot_flops += inner.dot_flops
                total.bytes += ins.out_bytes + operand_bytes
                continue
            if op in ("call", "conditional", "sort", "map", "scatter",
                      "reduce", "reduce-window", "select-and-scatter"):
                for cn in called.values():
                    inner = comp_cost(cn, True)
                    total.flops += inner.flops * max(ins.out_elems, 1) \
                        if op in ("map",) else inner.flops
                    total.dot_flops += inner.dot_flops
                total.bytes += ins.out_bytes + operand_bytes
                total.flops += ins.out_elems
                continue
            if op == "dynamic-slice":
                # reads only the slice; do not charge the full operand
                total.bytes += 2 * ins.out_bytes
                continue
            if op == "dynamic-update-slice":
                # in-place region write: charge the update region r/w
                upd = (table.get(ins.operands[1])
                       if len(ins.operands) > 1 else None)
                total.bytes += 2 * (upd.bytes if upd else ins.out_bytes)
                continue
            if op == "dot":
                f = _dot_flops(ins, shapes_of)
                total.flops += f
                total.dot_flops += f
                total.bytes += ins.out_bytes + operand_bytes
                continue
            if op == "convolution":
                # depthwise/pointwise convs in the stubs; approximate
                total.flops += 2.0 * ins.out_elems
                total.bytes += ins.out_bytes + operand_bytes
                continue
            if op == "custom-call":
                total.notes.append(f"custom-call: {ins.attrs[:60]}")
                total.bytes += ins.out_bytes + operand_bytes
                continue
            if op == "gather":
                total.bytes += 2 * ins.out_bytes
                continue
            # elementwise & layout ops: flops yes, bytes no (they fuse
            # into their materializing consumers on the TPU target)
            if op in _ELEMENTWISE_FLOP_OPS:
                total.flops += ins.out_elems
        memo[key] = total
        return total

    if entry is None:
        return Costs(notes=["no entry computation found"])
    return comp_cost(entry, False)
