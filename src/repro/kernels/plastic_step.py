"""One-launch plastic step: event delivery + LTD in a single Pallas call.

The plastic step used to make two full passes over the synapse tables:
the Pallas delivery kernel read every gathered event entry's weight into
the delayed-current ring, then the XLA STDP pass gathered the *same*
event rows again to apply the LTD (pre-spike) depression.  This module
fuses the two: one launch streams the lane-packed entry blocks once,
accumulating the ring contribution AND writing the depressed weights to
an output stream that the host scatters back over the event rows.

Division of labour (bitwise-equivalence argument in ``_ltd_math``):

  * **in kernel** -- delivery (identical math to
    ``synaptic_accum._accum_kernel``) plus LTD: every gathered entry is
    touched exactly once, so ``w_out = w + (-a_minus * x_post[tgt]) *
    mask`` rides the same stream read.
  * **in XLA, after the launch** -- LTP through the target-major
    inverse index (its access pattern is unrelated to the entry
    stream), the final [0, w_max] clamp, and the trace increments:
    ``core.stdp.stdp_ltp_finalize``, the *same* code the two-pass
    reference path runs.

Kernel geometry (vs. the delivery-only kernel): the grid is a single
``(n_blocks,)`` axis of ``ENTRY_BLOCK = 16384``-entry blocks and the
ring is **fully resident** -- interpret-mode profiling showed per-grid-
step overhead, not per-entry arithmetic, dominating the plastic step
(a skipped block still costs ~0.4 ms on CPU), so fewer/larger grid
steps win.  Event-proportional cost is recovered *inside* the block:
the body is a static loop over ``CHUNK = 4096``-entry chunks, each
guarded by a scalar-prefetched liveness flag (live = ``w != 0`` or
``mask != 0``; a weight can decay to exactly 0 while still plastic, and
skipping it would drop its LTD).  CHUNK equals the delivery kernel's
ENTRY_BLOCK, so the ring contribution reduces over the *same* 4096-
entry groups in the same order -- the float32 accumulation grouping the
kernel-vs-XLA bit-identity tests already pin down.

The resident ring caps the supported shard size: ``n_local`` padded to
``N_ALIGN`` must stay within ``RING_N_MAX`` (covers the committed
acceptance configs -- 8x8x60 pads to 4096 -- and any shard up to 8192
local neurons; at d_ring=8 the (CHUNK, d_ring * RING_N_MAX / LANES)
one-hot row factor is 8 MiB and the whole working set ~10.8 MiB,
inside the ~16 MiB VMEM core -- the ``pallas-geometry`` repro-lint
pass re-derives this bound from the module constants).  Larger shards
fall back to the two-pass path -- ``fused_supported`` is the routing
predicate -- which is bit-identical, just slower.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .synaptic_accum import (LANES, _ceil_to, _gather_entries, _pad_flat,
                             compact_events)

ENTRY_SUBLANES = 128        # sublanes per entry block (vs 32 for delivery)
ENTRY_BLOCK = ENTRY_SUBLANES * LANES   # 16384 entries per grid step
CHUNK = 4096                # entries per in-body liveness-gated chunk
N_ALIGN = 8 * LANES         # ring width alignment (sublane-tiled x_post)
RING_N_MAX = 8192           # max padded n_local the resident ring holds

_CSUB = CHUNK // LANES      # sublanes per chunk
_NCHUNK = ENTRY_BLOCK // CHUNK


def packed_total(entries: int) -> int:
    """Padded length of the fused plastic launch's entry stream."""
    return _ceil_to(max(entries, ENTRY_BLOCK), ENTRY_BLOCK)


def fused_supported(n_local: int) -> bool:
    """Whether the one-launch plastic step covers this shard size (the
    resident ring must fit); callers route to the two-pass path when
    not -- a pure perf fallback, both paths are bit-identical."""
    return _ceil_to(max(n_local, N_ALIGN), N_ALIGN) <= RING_N_MAX


def _plastic_kernel(neg_a_minus: float, d_ring: int,
                    meta_ref, blk_ref, chk_ref,
                    tgt_ref, w_ref, d_ref, m_ref, ring_ref, xpost_ref,
                    out_ring_ref, out_w_ref):
    """One entry-block grid step of the fused delivery + LTD pass.

    meta_ref:     scalar prefetch [t_slot]
    blk/chk_ref:  scalar prefetch liveness -- per entry block and per
                  CHUNK-entry chunk (count of live entries; 0 skips)
    tgt/w/d/m:    (ENTRY_SUBLANES, LANES) lane-packed entry block
                  (target id, weight, delay slot, plastic mask)
    ring/xpost:   full-resident (d_ring, n_pad) ring and the decayed
                  post-trace repacked (n_pad / LANES, LANES)
    out_ring:     (d_ring, n_pad) accumulator, resident across blocks
    out_w:        (ENTRY_SUBLANES, LANES) depressed-weight stream
    """
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        out_ring_ref[...] = ring_ref[...]

    # Unconditional: every entry's weight comes back (updated or not),
    # so the host-side scatter of event rows needs no liveness mask.
    out_w_ref[...] = w_ref[...]

    n_pad = out_ring_ref.shape[1]
    n_hi = n_pad // LANES
    t0 = meta_ref[0]

    @pl.when(blk_ref[e] > 0)
    def _block():
        for c in range(_NCHUNK):
            @pl.when(chk_ref[e * _NCHUNK + c] > 0)
            def _chunk(c=c):
                sl = slice(c * _CSUB, (c + 1) * _CSUB)
                tgt = tgt_ref[sl, :].reshape(CHUNK, 1)
                w = w_ref[sl, :].reshape(CHUNK, 1)
                mask = m_ref[sl, :].reshape(CHUNK, 1)
                slots = (t0 + d_ref[sl, :].reshape(CHUNK, 1)) % d_ring
                hi = jnp.floor_divide(tgt, LANES)             # sublane grp
                lo = tgt - hi * LANES                         # lane
                oh_lane = lo == jax.lax.broadcasted_iota(
                    jnp.int32, (CHUNK, LANES), 1)
                # -- delivery: identical two-level one-hot contraction
                # (and 4096-entry reduction grouping) to the delivery
                # kernel; padding entries carry w == 0 and contribute
                # an exact +0.0.
                rid = slots * n_hi + hi                       # (slot, hi)
                oh_row = rid == jax.lax.broadcasted_iota(
                    jnp.int32, (CHUNK, d_ring * n_hi), 1)
                contrib = jax.lax.dot_general(
                    oh_row.astype(jnp.float32),
                    jnp.where(oh_lane, w, 0.0),
                    dimension_numbers=(((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)       # (R, LANES)
                out_ring_ref[...] += contrib.reshape(d_ring, n_pad)
                # -- LTD: exact one-hot gather of x_post[tgt] (x_post
                # >= 0 and the row sum has a single nonzero term, so
                # the reduction is bitwise the gathered value), then
                # the reference's association ((-a_minus) * x) * mask.
                # mask == 0 (non-plastic + padding) yields dw = -0.0,
                # and w + (-0.0) == w bitwise for every float32 w.
                oh_hi = hi == jax.lax.broadcasted_iota(
                    jnp.int32, (CHUNK, n_hi), 1)
                xrows = jax.lax.dot_general(
                    oh_hi.astype(jnp.float32), xpost_ref[...],
                    dimension_numbers=(((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)       # (CHUNK, L)
                xg = jnp.sum(jnp.where(oh_lane, xrows, 0.0), axis=1,
                             keepdims=True)                   # (CHUNK, 1)
                dw = (neg_a_minus * xg) * mask
                out_w_ref[sl, :] += dw.reshape(_CSUB, LANES)


def _chunk_liveness(w_e, m_e):
    """Per-block / per-chunk live-entry counts for the skip flags.

    Live = ``(w != 0) | (mask != 0)``: zero-weight zero-mask entries are
    delivery no-ops (+0.0 contribution) AND LTD no-ops (dw = -0.0), so
    skipping a chunk of them is bitwise free; a plastic entry whose
    weight decayed to exactly 0 keeps mask = 1 and stays live.
    """
    live = jnp.logical_or(w_e != 0.0, m_e != 0.0)
    chk = jnp.sum(live.reshape(-1, CHUNK), axis=1).astype(jnp.int32)
    blk = jnp.sum(chk.reshape(-1, _NCHUNK), axis=1).astype(jnp.int32)
    return blk, chk


def plastic_delivery_ltd(tiers: Sequence[Tuple[dict, jnp.ndarray, int]],
                         masks: Sequence[jnp.ndarray],
                         x_post_decayed: jnp.ndarray,
                         i_ring, t_slot, d_ring: int, neg_a_minus: float,
                         *, plan=None, interpret: bool = True):
    """Fused delivery + LTD over every tier in ONE kernel launch.

    ``tiers``: [(tables, spikes_src, active_cap)] with ``tables["w"]``
    the *live* (carry) float32 weights; ``masks``: per-tier float32
    plastic masks; ``x_post_decayed``: the (n_local,) post-synaptic
    trace *after* this step's decay (the value the reference LTD
    reads); ``neg_a_minus``: ``-params.a_minus``.  ``plan``: per-tier
    ``TierPlan`` list (validated, sizes the per-tier entry slices).

    Returns ``(ring, new_w, n_events, n_dropped)`` where ``new_w[i]``
    is tier i's full weight array with the LTD update scattered over
    this step's event rows -- bitwise equal to the reference
    ``stdp_step`` LTD phase (the full-tier ``where(mask > 0, ...)`` /
    ``clip(None, w_max)`` it applies are no-ops under the w <= w_max
    invariant ``check_weight_invariant`` enforces at init).
    """
    assert i_ring.shape[0] == d_ring
    if plan is not None and len(plan) != len(tiers):
        raise ValueError(f"delivery plan has {len(plan)} tiers, "
                         f"got {len(tiers)}")
    parts_t: List[jnp.ndarray] = []
    parts_w: List[jnp.ndarray] = []
    parts_d: List[jnp.ndarray] = []
    parts_m: List[jnp.ndarray] = []
    idxs: List[jnp.ndarray] = []
    offsets: List[int] = []
    n_events = jnp.zeros((), jnp.int32)
    n_dropped = jnp.zeros((), jnp.int32)
    off = 0
    for ti, (tables, spikes_src, active_cap) in enumerate(tiers):
        n_rows, cap = tables["tgt"].shape[0] - 1, tables["tgt"].shape[1]
        if plan is not None:
            p = plan[ti]
            if (p.rows, p.cap, p.active_cap) != (n_rows, cap, active_cap):
                raise ValueError(
                    f"tier {ti} does not match its delivery plan: tables "
                    f"are rows={n_rows} cap={cap} active_cap={active_cap}, "
                    f"plan says rows={p.rows} cap={p.cap} "
                    f"active_cap={p.active_cap}")
        idx, n_spk = compact_events(spikes_src, n_rows, active_cap)
        te, we, de = _gather_entries(tables, idx)
        me = masks[ti][idx].astype(jnp.float32).ravel()
        e_pad = (plan[ti].entries_padded if plan is not None
                 else _ceil_to(te.shape[0], LANES))
        te, we, de = _pad_flat(te, we, de, e_pad)
        me = jnp.pad(me, (0, e_pad - me.shape[0]))
        parts_t.append(te)
        parts_w.append(we)
        parts_d.append(de)
        parts_m.append(me)
        idxs.append(idx)
        offsets.append(off)
        off += e_pad
        n_events = n_events + jnp.sum(tables["nnz"][idx]).astype(jnp.int32)
        n_dropped = n_dropped + jnp.maximum(
            n_spk - active_cap, 0).astype(jnp.int32)

    total = packed_total(off)
    tgt_e, w_e, d_e = _pad_flat(jnp.concatenate(parts_t),
                                jnp.concatenate(parts_w),
                                jnp.concatenate(parts_d), total)
    m_e = jnp.pad(jnp.concatenate(parts_m), (0, total - off))

    d_r, n_local = i_ring.shape
    n_pad = _ceil_to(max(n_local, N_ALIGN), N_ALIGN)
    if n_pad > RING_N_MAX:
        raise ValueError(
            f"n_local={n_local} pads to {n_pad} > RING_N_MAX="
            f"{RING_N_MAX}: the resident-ring plastic kernel does not "
            "cover this shard size -- route through fused_supported()")
    n_hi = n_pad // LANES
    ring_p = jnp.pad(i_ring, ((0, 0), (0, n_pad - n_local)))
    xpost_p = jnp.pad(x_post_decayed.astype(jnp.float32),
                      (0, n_pad - n_local)).reshape(n_hi, LANES)
    blk, chk = _chunk_liveness(w_e, m_e)
    meta = jnp.asarray([t_slot], jnp.int32).reshape(1)
    n_blocks = total // ENTRY_BLOCK

    def packed(x, dt):
        return x.astype(dt).reshape(-1, LANES)

    entry_spec = pl.BlockSpec((ENTRY_SUBLANES, LANES),
                              lambda e, m, bl, ck: (e, 0))
    ring_spec = pl.BlockSpec((d_r, n_pad), lambda e, m, bl, ck: (0, 0))
    xpost_spec = pl.BlockSpec((n_hi, LANES), lambda e, m, bl, ck: (0, 0))
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3, grid=(n_blocks,),
        in_specs=[entry_spec, entry_spec, entry_spec, entry_spec,
                  ring_spec, xpost_spec],
        out_specs=[ring_spec, entry_spec])
    kernel = functools.partial(_plastic_kernel, neg_a_minus, d_r)
    ring_out, w_out = pl.pallas_call(
        kernel,
        grid_spec=gspec,
        out_shape=[jax.ShapeDtypeStruct((d_r, n_pad), jnp.float32),
                   jax.ShapeDtypeStruct((total // LANES, LANES),
                                        jnp.float32)],
        interpret=interpret,
    )(meta, blk, chk, packed(tgt_e, jnp.int32), packed(w_e, jnp.float32),
      packed(d_e, jnp.int32), packed(m_e, jnp.float32), ring_p, xpost_p)

    w_flat = w_out.reshape(-1)
    new_w = []
    for (tables, _, active_cap), idx, off_t in zip(tiers, idxs, offsets):
        cap = tables["tgt"].shape[1]
        rows_w = jax.lax.dynamic_slice(
            w_flat, (off_t,), (active_cap * cap,)).reshape(active_cap, cap)
        # scatter-SET over the compacted (unique) event rows; duplicate
        # sink fills all write the sink row's unchanged 0.0
        new_w.append(tables["w"].at[idx].set(
            rows_w.astype(tables["w"].dtype)))
    return ring_out[:, :n_local], new_w, n_events, n_dropped
