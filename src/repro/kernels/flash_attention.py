"""Blocked online-softmax (flash) attention as a Pallas TPU kernel.

Targets the 32k-token prefill cells: O(Sq*Sk) compute on the MXU with
O(block) VMEM -- never materializing the (Sq, Sk) score matrix in HBM.
Supports causal masking, sliding-window masking (recurrentgemma's local
attention -- the 1-D analogue of the paper's distance-cutoff stencil),
GQA head grouping via the kv ``index_map`` (no KV repetition in memory),
and a static ``q_offset`` for chunked/decode use.

Grid: (B*H, nQ, nK) with the kv loop innermost; the output block's
index_map ignores the k axis, so the same (Bq, D) accumulator is
revisited across k steps with (m, l, acc) running stats in VMEM scratch.
Causally dead (q, k) block pairs still stream their KV block but skip the
matmul via ``pl.when`` -- block-sparsity on compute, which is what the
MXU actually cares about.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, q_offset, block_q, block_k, n_k,
            k_valid):
    _, qi, ki = (pl.program_id(0), pl.program_id(1), pl.program_id(2))

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    # block-level liveness: any (q, k) pair in this tile unmasked?
    q_last, q_first = q_pos[-1], q_pos[0]
    k_first, k_last = k_pos[0], k_pos[-1]
    live = k_first < k_valid
    if causal:
        live = jnp.logical_and(live, k_first <= q_last)
    if window is not None:
        live = jnp.logical_and(live, q_first - k_last < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (Bq, D)
        k = k_ref[0].astype(jnp.float32)            # (Bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (k_pos < k_valid)[None, :] & jnp.ones(
            (block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (Bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, ...] = (acc_ref[...] / safe * (l > 0.0)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    q_offset=0, block_q=128, block_k=128,
                    interpret: bool = True):
    """q: (BH, Sq, D); k, v: (BH_kv, Sk, D); BH % BH_kv == 0 (GQA).

    Returns (BH, Sq, D) in q.dtype.  Matches ``ref.attention_ref``.
    """
    bh, sq, d = q.shape
    bh_kv, sk, _ = k.shape
    assert bh % bh_kv == 0
    group = bh // bh_kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = -sq % block_q
    pad_k = -sk % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    n_q = (sq + pad_q) // block_q
    n_k = (sk + pad_k) // block_k

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_k=n_k,
        k_valid=sk)

    q_spec = pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, d),
                           lambda b, i, j: (b // group, j, 0))
    out = pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((bh, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, 1), jnp.float32),
            _VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
