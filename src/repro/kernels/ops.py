"""Jit'd public wrappers around the Pallas kernels.

Backend switch: on TPU the kernels run compiled (``interpret=False``); on
CPU (this container, and any test environment) they run in interpret
mode, which executes the kernel bodies with jnp ops -- bit-identical
semantics, same BlockSpec tiling, no Mosaic.  ``impl='ref'`` routes to
the pure-jnp oracles (used by the dry-run so the lowered HLO stays clean
for roofline accounting).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .lif_step import lif_step_pallas
from .synaptic_accum import synaptic_accum_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lif_kwargs(params) -> dict:
    return dict(leak_decay=params.leak_decay, sfa_decay=params.sfa_decay,
                g_sfa=params.g_sfa, dt_ms=params.dt_ms,
                v_rest=params.v_rest_mv, v_reset=params.v_reset_mv,
                theta=params.theta_mv, alpha_c=params.alpha_c,
                refrac_steps=params.refrac_steps)


def lif_step(state: dict, i_total, params, active=None):
    """Kernel-backed drop-in for ``core.neuron.lif_sfa_step``."""
    a = active if active is not None else jnp.ones_like(state["v"],
                                                        dtype=bool)
    v, c, r, spk = lif_step_pallas(
        state["v"], state["c"], state["refrac"], i_total, a,
        interpret=_interpret(), **_lif_kwargs(params))
    return {"v": v, "c": c, "refrac": r}, spk


def lif_step_ref(state: dict, i_total, params, active=None):
    a = active if active is not None else jnp.ones_like(state["v"],
                                                        dtype=bool)
    v, c, r, spk = ref.lif_step_ref(
        state["v"], state["c"], state["refrac"], i_total, a,
        **_lif_kwargs(params))
    return {"v": v, "c": c, "refrac": r}, spk


def synaptic_accum_events(tables: dict, spikes_src, i_ring, t_slot,
                          d_ring: int, active_cap: int):
    """Kernel-backed drop-in for ``core.synapses.deliver_events``."""
    tgt, w, dslot, nnz = (tables["tgt"], tables["w"], tables["dslot"],
                          tables["nnz"])
    n_rows = tgt.shape[0] - 1
    spk = spikes_src[:n_rows]
    (idx,) = jnp.nonzero(spk > 0, size=active_cap, fill_value=n_rows)
    i_ring = synaptic_accum_pallas(idx, t_slot, tgt, w, dslot, i_ring,
                                   interpret=_interpret())
    n_spikes = jnp.sum(spk > 0)
    n_events = jnp.sum(nnz[idx])
    n_dropped = jnp.maximum(n_spikes - active_cap, 0)
    return i_ring, n_events, n_dropped


def attention(q, k, v, *, causal=True, window=None, scale=None, q_offset=0,
              impl: str = "auto", block_q: int = 128, block_k: int = 128):
    """Multi-head attention with GQA; impl in {auto, pallas, ref}.

    'auto' = pallas (compiled on TPU, interpreted elsewhere).
    """
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  q_offset=q_offset, block_q=block_q, block_k=block_k,
                  interpret=_interpret())
