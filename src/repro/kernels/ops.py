"""Jit'd public wrappers around the Pallas kernels.

Backend switch: on TPU the kernels run compiled (``interpret=False``); on
CPU (this container, and any test environment) they run in interpret
mode, which executes the kernel bodies with jnp ops -- bit-identical
semantics, same BlockSpec tiling, no Mosaic.  ``impl='ref'`` routes to
the pure-jnp oracles (used by the dry-run so the lowered HLO stays clean
for roofline accounting).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash
from .lif_step import lif_step_pallas
from .plastic_step import plastic_delivery_ltd as _plastic_ltd
from .spike_compact import spike_compact_pallas
from .synaptic_accum import (event_delivery, event_delivery_banded as
                             _delivery_banded)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lif_kwargs(params) -> dict:
    return dict(leak_decay=params.leak_decay, sfa_decay=params.sfa_decay,
                g_sfa=params.g_sfa, dt_ms=params.dt_ms,
                v_rest=params.v_rest_mv, v_reset=params.v_reset_mv,
                theta=params.theta_mv, alpha_c=params.alpha_c,
                refrac_steps=params.refrac_steps)


def lif_step(state: dict, i_total, params, active=None):
    """Kernel-backed drop-in for ``core.neuron.lif_sfa_step``."""
    a = active if active is not None else jnp.ones_like(state["v"],
                                                        dtype=bool)
    v, c, r, spk = lif_step_pallas(
        state["v"], state["c"], state["refrac"], i_total, a,
        interpret=_interpret(), **_lif_kwargs(params))
    return {"v": v, "c": c, "refrac": r}, spk


def lif_step_ref(state: dict, i_total, params, active=None):
    a = active if active is not None else jnp.ones_like(state["v"],
                                                        dtype=bool)
    v, c, r, spk = ref.lif_step_ref(
        state["v"], state["c"], state["refrac"], i_total, a,
        **_lif_kwargs(params))
    return {"v": v, "c": c, "refrac": r}, spk


def synaptic_accum_events(tables: dict, spikes_src, i_ring, t_slot,
                          d_ring: int, active_cap: int):
    """Kernel-backed drop-in for ``core.synapses.deliver_events``.

    Fused pipeline: compaction -> event gather -> blocked Pallas
    scatter-add (see ``kernels.synaptic_accum``)."""
    return event_delivery(tables, spikes_src, i_ring, t_slot, d_ring,
                          active_cap, interpret=_interpret())


def synaptic_accum_banded(tiers, i_ring, t_slot, d_ring: int, plan=None):
    """Fused multi-tier (local + halo-band) delivery in ONE lane-packed
    kernel launch across every ring tile.  ``tiers``: [(tables, spikes,
    active_cap)]; ``plan``: optional ``SynapseTableSpec.delivery_plan()``
    the tables are validated against.  Returns (ring, n_events,
    n_dropped) summed over tiers."""
    return _delivery_banded(tiers, i_ring, t_slot, d_ring, plan=plan,
                            interpret=_interpret())


def plastic_step_banded(tiers, masks, x_post_decayed, i_ring, t_slot,
                        d_ring: int, neg_a_minus: float, plan=None):
    """One-launch plastic step: multi-tier delivery + in-kernel LTD.

    Same entry stream and reduction grouping as
    ``synaptic_accum_banded`` plus a per-entry weight update
    (``w += (-a_minus) * x_post[tgt] * mask``) written back in the same
    launch.  ``x_post_decayed`` must be the post trace *after* this
    step's decay, *before* its spike increment.  Returns
    (ring, new_w_tiers, n_events, n_dropped)."""
    return _plastic_ltd(tiers, masks, x_post_decayed, i_ring, t_slot,
                        d_ring, neg_a_minus, plan=plan,
                        interpret=_interpret())


def spike_compact(spikes, n_rows: int, active_cap: int):
    """Kernel-backed drop-in for ``synaptic_accum.compact_events``: the
    ascending spiking-row index list (sink-padded) plus the uncapped
    spike count.  Feeds the spike observatory's device-side recorder."""
    return spike_compact_pallas(spikes, n_rows, active_cap,
                                interpret=_interpret())


def attention(q, k, v, *, causal=True, window=None, scale=None, q_offset=0,
              impl: str = "auto", block_q: int = 128, block_k: int = 128):
    """Multi-head attention with GQA; impl in {auto, pallas, ref}.

    'auto' = pallas (compiled on TPU, interpreted elsewhere).
    """
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  q_offset=q_offset, block_q=block_q, block_k=block_k,
                  interpret=_interpret())
