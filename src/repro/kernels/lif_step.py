"""Fused LIF+SFA neuron update as a Pallas TPU kernel.

The neuron update is the per-step *memory-bound* stage: every state array
(v, c, refrac) plus the input current must stream HBM->VMEM->HBM exactly
once.  Unfused, XLA can end up re-reading state between the where-chains;
the kernel guarantees the single-pass roofline: 24 B/neuron/step
(3 x f32 state read + write) amortized across the chain of selects.

Layout: the flat (n,) neuron arrays are padded and viewed as (rows, 128)
lanes -- 128 is the TPU lane width; blocks of (block_rows, 128) keep the
VMEM working set (6 arrays x block) around 1.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 512


def _kernel(v_ref, c_ref, r_ref, i_ref, a_ref,
            vo_ref, co_ref, ro_ref, so_ref, *,
            leak_decay, sfa_decay, g_sfa, dt_ms, v_rest, v_reset, theta,
            alpha_c, refrac_steps):
    v = v_ref[...]
    c = c_ref[...]
    r = r_ref[...]
    i = i_ref[...]
    a = a_ref[...]
    refractory = r > 0
    v_int = v_rest + (v - v_rest) * leak_decay + i - g_sfa * c * dt_ms
    v_new = jnp.where(refractory, v_reset, v_int)
    spiked = jnp.logical_and(v_new >= theta, a)
    spk = spiked.astype(jnp.float32)
    vo_ref[...] = jnp.where(spiked, v_reset, v_new).astype(v.dtype)
    co_ref[...] = (c * sfa_decay + alpha_c * spk).astype(c.dtype)
    ro_ref[...] = jnp.where(spiked, jnp.int32(refrac_steps),
                            jnp.maximum(r - 1, 0)).astype(jnp.int32)
    so_ref[...] = spk


def lif_step_pallas(v, c, refrac, i_total, active, *, leak_decay, sfa_decay,
                    g_sfa, dt_ms, v_rest, v_reset, theta, alpha_c,
                    refrac_steps, block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = True):
    """Fused update on flat (n,) state arrays.  Returns (v, c, refrac, spk)."""
    n = v.shape[0]
    # clamp the block to the problem: small nets (tests, reduced grids)
    # must not pad up to a full 512x128 production block
    block_rows = min(block_rows, max(-(-n // LANES), 8))
    blk = block_rows * LANES
    n_pad = -n % blk

    def pad2d(x, fill=0):
        x = jnp.pad(x, (0, n_pad), constant_values=fill)
        return x.reshape(-1, LANES)

    args = (pad2d(v), pad2d(c), pad2d(refrac), pad2d(i_total),
            pad2d(active))
    rows = args[0].shape[0]
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    kern = functools.partial(
        _kernel, leak_decay=leak_decay, sfa_decay=sfa_decay, g_sfa=g_sfa,
        dt_ms=dt_ms, v_rest=v_rest, v_reset=v_reset, theta=theta,
        alpha_c=alpha_c, refrac_steps=refrac_steps)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec] * 5,
        out_specs=[spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), v.dtype),
            jax.ShapeDtypeStruct((rows, LANES), c.dtype),
            jax.ShapeDtypeStruct((rows, LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return tuple(x.reshape(-1)[:n] for x in out)
