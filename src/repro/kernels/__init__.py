"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle in ref.py and a jit'd wrapper in ops.py:

  * lif_step        -- fused memory-bound neuron update
  * synaptic_accum  -- fused event-delivery pipeline (the paper's hot
                       loop): spike compaction -> event gather ->
                       lane-packed (E/128, 128) entry blocks -> two-level
                       one-hot MXU scatter-add into the VMEM-resident
                       delay ring, with per-(ring-tile, entry-block)
                       skipping; ``event_delivery_banded`` delivers the
                       local tier plus every halo fan-out band in one
                       launch
  * flash_attention -- blocked online-softmax attention (LM prefill)
"""

from . import ops, ref
