"""Pallas TPU kernels for the compute hot-spots, each with a pure-jnp
oracle in ref.py and a jit'd wrapper in ops.py:

  * lif_step        -- fused memory-bound neuron update
  * synaptic_accum  -- event gather -> VMEM scatter-add (the paper's hot loop)
  * flash_attention -- blocked online-softmax attention (LM prefill)
"""

from . import ops, ref
