"""Spike compaction as a Pallas TPU kernel (device-side spike recording).

The spike observatory's device-side recorder needs, every step, the
ascending list of spiking-neuron indices -- the same compaction the
event-delivery pipeline performs with ``jnp.nonzero`` (see
``compact_events`` in ``synaptic_accum.py``).  This module provides that
compaction as a Pallas kernel so the recording path can ride the same
``use_kernels="auto"`` routing as delivery: compiled on TPU, interpreted
elsewhere, with ``compact_events`` as the bit-identical XLA fallback.

TPU shape of the problem (a stream compaction):

  * the spike mask is streamed in ``CHUNK = 8 x 128`` blocks; a running
    spike count in SMEM scratch carries the output base offset from
    chunk to chunk (the grid is sequential on TPU);
  * within a chunk, each live entry's output position is ``base +
    inclusive_cumsum(mask) - 1``; the scatter to that position is a
    one-hot MXU matmul -- ``(1, CHUNK) x (CHUNK, OUT_TILE)`` -- exactly
    the scatter-as-matmul idiom of the delivery kernel;
  * the output index list is tiled ``OUT_TILE`` wide on an outer grid
    dimension, so the one-hot factor stays ~2 MiB regardless of the
    compaction capacity; every output tile re-streams the chunks
    (recomputing the cheap cumsum) and keeps only positions in its
    window;
  * the last chunk of each output-tile pass rewrites the accumulated
    ``index + 1`` values to the ``compact_events`` contract: ascending
    spiking indices in the first ``min(count, cap)`` slots, the sink row
    ``n_rows`` everywhere else, and the (uncapped) spike count as a
    scalar output.

Indices ride the MXU as f32, exact for ``n_rows < 2**24`` -- far above
any per-shard neuron count this repo targets (full-scale DPSNN shards
are ~1e4 neurons).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
SUBLANES = 8
CHUNK = SUBLANES * LANES       # spike-mask entries consumed per grid step
OUT_TILE = 512                 # output index slots per outer grid step


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _compact_kernel(mask_ref, out_ref, cnt_ref, base_ref, *, cap: int,
                    n_rows: int):
    """One (output-tile, chunk) grid step of the stream compaction.

    mask_ref: (SUBLANES, LANES) spike-mask chunk (f32, >0 == spiking)
    out_ref:  (1, OUT_TILE) index-list tile, resident across chunks
    cnt_ref:  (1, 1) SMEM -- total (uncapped) spike count
    base_ref: (1,) SMEM scratch -- running count across chunks
    """
    o = pl.program_id(0)
    c = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(c == 0)
    def _reset():
        out_ref[...] = jnp.zeros_like(out_ref)
        base_ref[0] = 0

    live = (mask_ref[...] > 0.0).reshape(1, CHUNK)
    base = base_ref[0]
    incl = jnp.cumsum(live.astype(jnp.int32), axis=1)
    pos = base + incl - 1                                  # (1, CHUNK)
    gidx = c * CHUNK + jax.lax.broadcasted_iota(jnp.int32, (1, CHUNK), 1)
    rel = pos - o * OUT_TILE                               # this tile's frame
    ok = jnp.logical_and(live, jnp.logical_and(pos < cap, jnp.logical_and(
        rel >= 0, rel < OUT_TILE)))
    # scatter-as-matmul: out[p] += (gidx + 1) one-hotted to column rel
    oh = rel.reshape(CHUNK, 1) == jax.lax.broadcasted_iota(
        jnp.int32, (CHUNK, OUT_TILE), 1)
    oh = jnp.where(ok.reshape(CHUNK, 1), oh, False)
    contrib = jax.lax.dot_general(
        (gidx + 1).astype(jnp.float32), oh.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (1, OUT_TILE)
    out_ref[...] += contrib.astype(jnp.int32)
    new_base = base + jnp.sum(live.astype(jnp.int32))
    base_ref[0] = new_base

    @pl.when(c == n_chunks - 1)
    def _finalize():
        k = jnp.minimum(new_base, cap)
        iota_abs = o * OUT_TILE + jax.lax.broadcasted_iota(
            jnp.int32, (1, OUT_TILE), 1)
        out_ref[...] = jnp.where(iota_abs < k, out_ref[...] - 1, n_rows)
        cnt_ref[0, 0] = new_base


def spike_compact_pallas(spikes, n_rows: int, active_cap: int, *,
                         interpret: bool = True):
    """Kernel-backed drop-in for ``synaptic_accum.compact_events``.

    ``spikes``: (>= n_rows,) spike vector (>0 == spiking).  Returns
    ``(idx, count)``: ``idx`` (active_cap,) int32 holds the ascending
    indices of the first ``min(count, active_cap)`` spiking rows, padded
    with the sink row ``n_rows``; ``count`` is the uncapped spike count
    (callers derive drops as ``max(count - active_cap, 0)``).
    """
    spk = spikes[:n_rows].astype(jnp.float32)
    n_pad = _ceil_to(max(n_rows, CHUNK), CHUNK)
    spk = jnp.pad(spk, (0, n_pad - n_rows))
    cap_pad = _ceil_to(max(active_cap, OUT_TILE), OUT_TILE)
    n_chunks = n_pad // CHUNK
    n_out = cap_pad // OUT_TILE

    mask_spec = pl.BlockSpec((SUBLANES, LANES), lambda o, c: (c, 0))
    out_spec = pl.BlockSpec((1, OUT_TILE), lambda o, c: (0, o))
    cnt_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    idx, cnt = pl.pallas_call(
        functools.partial(_compact_kernel, cap=active_cap, n_rows=n_rows),
        grid=(n_out, n_chunks),
        in_specs=[mask_spec],
        out_specs=(out_spec, cnt_spec),
        out_shape=(jax.ShapeDtypeStruct((1, cap_pad), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(spk.reshape(-1, LANES))
    return idx[0, :active_cap], cnt[0, 0]
