"""Event-driven synaptic delivery as a fused Pallas TPU pipeline.

This is DPSNN's hot loop: deliver every spike through its synapse-table
row into the delayed-current ring.  The per-step sequence

    spike compaction -> event-index prefetch -> row gather -> ring
    scatter-add

is fused into one kernel-layer entry point (``event_delivery`` /
``event_delivery_banded``) so the engines never stitch the stages
together themselves.  The TPU shape of the problem:

  * **compaction** (``jnp.nonzero`` with a static ``active_cap``) puts
    the spiking rows first and pads with the all-zero sink row, so the
    valid synapse entries form a prefix of each tier's gathered event
    list.
  * **gather** streams only the event rows' (tgt, w, dslot) triples out
    of the synapse tables; the flattened entry list is what the kernel
    consumes, so tiers with different row capacities (the geometric halo
    fan-out bands) concatenate into ONE kernel launch per step instead
    of one launch per band.
  * **lane packing**: the flat entry stream is repacked to
    ``(E / LANES, LANES)`` so each grid step consumes an
    ``(ENTRY_SUBLANES, LANES)`` block -- ``ENTRY_BLOCK = 4096`` entries
    with every vector lane live, where the previous layout fed ``(E, 1)``
    columns that used 1 of 128 lanes.
  * **scatter-add** runs as a *two-level* one-hot contraction on the
    MXU.  The target id is factored as ``tgt = i * tile_n + a * LANES +
    b`` (ring tile, sublane group, lane); the ring tile ``i`` is a grid
    dimension, and within a tile the contribution is

        out[d, a * LANES + b] = sum_e w[e] * [slot[e] == d]
                                           * [hi[e] == a] * [lo[e] == b]

    computed as one ``(blk, R) x (blk, LANES)`` matmul with
    ``R = d_ring * tile_n / LANES``: the left factor one-hots the fused
    (slot, sublane-group) row id, the right factor carries ``w`` through
    a lane one-hot.  That shrinks the per-block one-hot footprint from
    ``(blk, TILE_N)`` (8 MiB at the old sizes) to two ``(blk, 128)``-ish
    factors while keeping the same per-entry MXU flops.
  * **block skipping** is per (ring tile, entry block): scalar-prefetched
    per-block [first, last] target-tile windows (min/max of the live
    ``w != 0`` entries) let ``pl.when`` skip a block on every tile it
    does not touch -- all-padding blocks carry an empty window and are
    skipped everywhere, so runtime stays proportional to spikes x
    fan-out (synaptic events, the paper's cost unit) and a block whose
    targets live in ring tile 0 is no longer streamed through every
    other tile.
  * the grid is 2-D ``(n_tiles, n_blocks)`` with the entry-block
    dimension innermost: each ``(d_ring, tile_n)`` ring tile stays
    VMEM-resident while every entry block streams past it (the former
    host-level per-tile ``dynamic_slice`` loop is gone).

Interpret mode (CPU) executes the identical BlockSpec tiling and kernel
body with jnp ops, so tests exercise the same code path that compiles
on TPU.  (TPU-hardware validation of the lane-packed layout is a
ROADMAP item; the in-kernel ``(S, L) -> (S*L, 1)`` relayouts are the
part Mosaic is most likely to want reworked.)
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128               # vector lane width: packed entry minor dim
ENTRY_SUBLANES = 32       # sublanes per entry block
ENTRY_BLOCK = ENTRY_SUBLANES * LANES   # synapse entries per grid step
TILE_N = 4096             # max ring-tile width (lane dim, multiple of 128)

# Sized so the largest kernel intermediates -- the (ENTRY_BLOCK, R) one-
# hot row factor (4 MiB f32 at d_ring=8 / TILE_N=4096, R = d_ring *
# TILE_N / LANES = 256) and the (ENTRY_BLOCK, LANES) lane factor
# (2 MiB) -- plus their bool precursors, the resident ring tile and the
# entry blocks stay inside a ~16 MiB VMEM core.  (CPU-interpret sweep
# at the committed 8x8x60 benchmark: {SUB=32,TN=2048}: 7.3/12.1 s per
# 60 steps gaussian/exponential, {64,2048}: 4.9/6.9, {32,4096}:
# 3.5/5.7, {64,4096}: 2.6/3.7 but ~18 MiB of intermediates -- {32,4096}
# is the best point that still fits compiled VMEM.)

_FAR = 2 ** 30            # min-reduction sentinel for non-live entries


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def packed_total(entries: int) -> int:
    """Total padded length of a lane-packed entry stream holding
    ``entries`` entries -- the single source of truth for the launch's
    block count, shared with ``SynapseTableSpec.entry_geometry()``."""
    return _ceil_to(max(entries, ENTRY_BLOCK), ENTRY_BLOCK)


def _accum_kernel(meta_ref, tmin_ref, tmax_ref, tgt_ref, w_ref, d_ref,
                  ring_ref, out_ref):
    """One (ring-tile, entry-block) grid step of the two-level scatter.

    meta_ref:    scalar prefetch [t_slot]
    tmin/tmax:   scalar prefetch (n_entry_blocks,) -- first/last ring
                 tile targeted by the block's live (w != 0) entries;
                 all-padding blocks carry an empty window (tmin > tmax)
    tgt/w/d:     (ENTRY_SUBLANES, LANES) lane-packed entry block
    ring/out:    (d_ring, tile_n) -- the accumulator tile, resident
                 across the inner (entry-block) grid dimension
    """
    i = pl.program_id(0)              # ring tile
    e = pl.program_id(1)              # entry block

    @pl.when(e == 0)
    def _init():
        out_ref[...] = ring_ref[...]

    @pl.when(jnp.logical_and(tmin_ref[e] <= i, i <= tmax_ref[e]))
    def _accum():
        d_ring, tile_n = out_ref.shape
        n_hi = tile_n // LANES
        blk = tgt_ref.shape[0] * tgt_ref.shape[1]
        t0 = meta_ref[0]
        tgt = tgt_ref[...].reshape(blk, 1) - i * tile_n   # this tile's frame
        w = w_ref[...].reshape(blk, 1).astype(jnp.float32)
        slots = (t0 + d_ref[...].reshape(blk, 1)) % d_ring
        # Out-of-tile entries must be zeroed through w: their fused row
        # id below may alias a live (slot, hi) pair, and a zero weight
        # is the one thing that is harmless under aliasing.
        in_tile = jnp.logical_and(tgt >= 0, tgt < tile_n)
        w = jnp.where(in_tile, w, 0.0)
        hi = jnp.floor_divide(tgt, LANES)                 # sublane group
        lo = tgt - hi * LANES                             # lane
        rid = slots * n_hi + hi                           # fused (slot, hi)
        oh_row = rid == jax.lax.broadcasted_iota(
            jnp.int32, (blk, d_ring * n_hi), 1)
        oh_lane = lo == jax.lax.broadcasted_iota(
            jnp.int32, (blk, LANES), 1)
        contrib = jax.lax.dot_general(
            oh_row.astype(jnp.float32), jnp.where(oh_lane, w, 0.0),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (R, LANES)
        # (d * n_hi + a, b) -> (d, a * LANES + b): row-major reshape.
        out_ref[...] += contrib.reshape(d_ring, tile_n)


def _block_tile_windows(tgt_e, w_e, tile_n: int):
    """Per-entry-block [first, last] ring-tile windows over live entries.

    Live means ``w != 0``: gathered padding (sink rows, intra-row cap
    padding, lane/block padding) all carry zero weights, and a zero
    weight contributes exactly nothing to the scatter, so excluding it
    from the window is semantically free.  All-padding blocks come back
    with tmin > tmax and are skipped on every tile.
    """
    n_blocks = tgt_e.shape[0] // ENTRY_BLOCK
    tgt_b = tgt_e.reshape(n_blocks, ENTRY_BLOCK)
    live = w_e.reshape(n_blocks, ENTRY_BLOCK) != 0.0
    tmin = jnp.min(jnp.where(live, tgt_b, _FAR), axis=1) // tile_n
    tmax = jnp.max(jnp.where(live, tgt_b, -1), axis=1) // tile_n
    return tmin.astype(jnp.int32), tmax.astype(jnp.int32)


def _scatter_entries(tgt_e, w_e, d_e, ring, t_slot, *,
                     interpret: bool):
    """Two-level blocked scatter of a lane-packed entry stream into the
    tiled ring.

    tgt_e/w_e/d_e: flat (E,) with E a multiple of ENTRY_BLOCK; padding
    entries must carry w == 0.  One pallas_call covers every
    (ring tile, entry block) pair on a 2-D grid.
    """
    d_ring, n_local = ring.shape
    n_pad = _ceil_to(max(n_local, LANES), LANES)
    tile_n = min(TILE_N, n_pad)
    n_tiles = -(-n_pad // tile_n)
    n_pad = n_tiles * tile_n
    ring_p = jnp.pad(ring, ((0, 0), (0, n_pad - n_local)))
    tmin, tmax = _block_tile_windows(tgt_e, w_e, tile_n)
    meta = jnp.asarray([t_slot], jnp.int32).reshape(1)
    n_blocks = tgt_e.shape[0] // ENTRY_BLOCK

    def packed(x, dt):
        return x.astype(dt).reshape(-1, LANES)

    entry_spec = pl.BlockSpec((ENTRY_SUBLANES, LANES),
                              lambda i, e, m, lo, hi: (e, 0))
    ring_spec = pl.BlockSpec((d_ring, tile_n),
                             lambda i, e, m, lo, hi: (0, i))
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3, grid=(n_tiles, n_blocks),
        in_specs=[entry_spec, entry_spec, entry_spec, ring_spec],
        out_specs=ring_spec)
    out = pl.pallas_call(
        _accum_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((d_ring, n_pad), jnp.float32),
        interpret=interpret,
    )(meta, tmin, tmax, packed(tgt_e, jnp.int32), packed(w_e, jnp.float32),
      packed(d_e, jnp.int32), ring_p)
    return out[:, :n_local]


# ---------------------------------------------------------------------------
# Stage 1: compaction
# ---------------------------------------------------------------------------

def compact_events(spikes_src, n_rows: int, active_cap: int):
    """Spiking-row compaction: ascending row indices of the (at most
    ``active_cap``) spiking sources, padded with the sink row ``n_rows``.

    Returns (idx, n_spikes) -- ``idx`` is the event-index list; real
    events occupy a prefix because ``nonzero`` is order-preserving.
    """
    spk = spikes_src[:n_rows]
    (idx,) = jnp.nonzero(spk > 0, size=active_cap, fill_value=n_rows)
    return idx.astype(jnp.int32), jnp.sum(spk > 0)


# ---------------------------------------------------------------------------
# Stage 2+3: gather event rows and flatten to entry streams
# ---------------------------------------------------------------------------

def _gather_entries(tables: dict, idx):
    """Gather event rows and flatten row-major to (A * cap,) streams."""
    rows_t = tables["tgt"][idx]
    rows_w = tables["w"][idx].astype(jnp.float32)
    rows_d = tables["dslot"][idx].astype(jnp.int32)
    return rows_t.astype(jnp.int32).ravel(), rows_w.ravel(), rows_d.ravel()


def _pad_flat(te, we, de, n: int):
    pad = n - te.shape[0]
    if pad:
        te = jnp.pad(te, (0, pad))
        we = jnp.pad(we, (0, pad))
        de = jnp.pad(de, (0, pad))
    return te, we, de


# ---------------------------------------------------------------------------
# Fused entry points
# ---------------------------------------------------------------------------

def event_delivery(tables: dict, spikes_src, i_ring, t_slot,
                   d_ring: int, active_cap: int, *,
                   interpret: bool = True):
    """Fused single-tier delivery.  Drop-in for
    ``core.synapses.deliver_events``: returns (ring, n_events, n_dropped).
    """
    return event_delivery_banded([(tables, spikes_src, active_cap)],
                                 i_ring, t_slot, d_ring,
                                 interpret=interpret)


def event_delivery_banded(tiers: Sequence[Tuple[dict, jnp.ndarray, int]],
                          i_ring, t_slot, d_ring: int, *,
                          plan=None,
                          interpret: bool = True):
    """Fused multi-tier delivery: ONE kernel launch for the local table
    plus every halo fan-out band across every ring tile.

    ``tiers``: sequence of (tables, spikes_src, active_cap); each tier's
    tables may have a different row capacity (the banded-halo layout) --
    entry flattening makes the concatenation capacity-agnostic.
    ``plan``: optional per-tier ``TierPlan`` sequence from
    ``SynapseTableSpec.delivery_plan()``; when given, the tables are
    validated against it (the typed spec contract the engines compile
    against) and its lane-padded ``entries_padded`` sizes the per-tier
    slice of the packed entry stream.  For compressed tables, pass the
    plan derived from the tables' ``storage`` descriptor.
    Returns (ring, n_events, n_dropped) summed over tiers.
    """
    assert i_ring.shape[0] == d_ring
    if plan is not None and len(plan) != len(tiers):
        raise ValueError(f"delivery plan has {len(plan)} tiers, "
                         f"got {len(tiers)}")
    parts_t: List[jnp.ndarray] = []
    parts_w: List[jnp.ndarray] = []
    parts_d: List[jnp.ndarray] = []
    n_events = jnp.zeros((), jnp.int32)
    n_dropped = jnp.zeros((), jnp.int32)
    for ti, (tables, spikes_src, active_cap) in enumerate(tiers):
        n_rows, cap = tables["tgt"].shape[0] - 1, tables["tgt"].shape[1]
        if plan is not None:
            p = plan[ti]
            if (p.rows, p.cap, p.active_cap) != (n_rows, cap, active_cap):
                raise ValueError(
                    f"tier {ti} does not match its delivery plan: tables "
                    f"are rows={n_rows} cap={cap} active_cap={active_cap}, "
                    f"plan says rows={p.rows} cap={p.cap} "
                    f"active_cap={p.active_cap}")
        idx, n_spk = compact_events(spikes_src, n_rows, active_cap)
        te, we, de = _gather_entries(tables, idx)
        e_pad = (plan[ti].entries_padded if plan is not None
                 else _ceil_to(te.shape[0], LANES))
        te, we, de = _pad_flat(te, we, de, e_pad)
        parts_t.append(te)
        parts_w.append(we)
        parts_d.append(de)
        n_events = n_events + jnp.sum(tables["nnz"][idx]).astype(jnp.int32)
        n_dropped = n_dropped + jnp.maximum(
            n_spk - active_cap, 0).astype(jnp.int32)

    tgt_e = jnp.concatenate(parts_t)
    w_e = jnp.concatenate(parts_w)
    d_e = jnp.concatenate(parts_d)
    tgt_e, w_e, d_e = _pad_flat(tgt_e, w_e, d_e,
                                packed_total(tgt_e.shape[0]))
    ring = _scatter_entries(tgt_e, w_e, d_e, i_ring, t_slot,
                            interpret=interpret)
    return ring, n_events, n_dropped


# ---------------------------------------------------------------------------
# Legacy single-call API (kept for the kernel sweep tests)
# ---------------------------------------------------------------------------

def synaptic_accum_pallas(idx, t_slot, tgt, w, dslot, ring, *,
                          interpret: bool = True):
    """Deliver event rows ``idx`` (A,) through the tables into ``ring``.

    Equivalent to ``ref.synaptic_accum_ref``.  ``dslot`` int8/int32;
    ``ring`` (D, n_local) f32 -- returned updated.  Unlike
    ``event_delivery`` this takes a pre-compacted index list; callers
    may pass arbitrary, unsorted indices -- block skipping is purely
    data-driven (live-entry tile windows), so it still applies.
    """
    tables = {"tgt": tgt, "w": w, "dslot": dslot}
    te, we, de = _gather_entries(tables, idx.astype(jnp.int32))
    te, we, de = _pad_flat(te, we, de, packed_total(te.shape[0]))
    return _scatter_entries(te, we, de, ring, t_slot, interpret=interpret)
