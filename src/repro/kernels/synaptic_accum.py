"""Event-driven synaptic delivery as a fused Pallas TPU pipeline.

This is DPSNN's hot loop: deliver every spike through its synapse-table
row into the delayed-current ring.  The per-step sequence

    spike compaction -> event-index prefetch -> row gather -> ring
    scatter-add

is fused into one kernel-layer entry point (``event_delivery`` /
``event_delivery_banded``) so the engines never stitch the stages
together themselves.  The TPU shape of the problem:

  * **compaction** (``jnp.nonzero`` with a static ``active_cap``) puts
    the spiking rows first and pads with the all-zero sink row, so the
    valid synapse entries form a prefix of each tier's gathered event
    list.  The per-block validity mask derived from the spike count is
    scalar-prefetched, letting the kernel *skip* all-padding blocks with
    ``pl.when`` -- runtime stays proportional to spikes x fan-out
    (synaptic events, the paper's cost unit), not to the compaction
    head-room.
  * **gather** streams only the event rows' (tgt, w, dslot) triples out
    of the synapse tables; the flattened entry list is what the kernel
    consumes, so tiers with different row capacities (the geometric halo
    fan-out bands) concatenate into ONE kernel launch per step instead
    of one launch per band.
  * **scatter-add** runs as a blocked one-hot matmul on the MXU:
    ``contrib[d, n] = sum_e w[e] * [slot[e] == d] * [tgt[e] == n]``.
    TPU has no vector scatter; a serialized per-entry RMW loop is
    byte-accurate but leaves the MXU idle and is orders of magnitude
    slower under ``interpret=True``.  The one-hot contraction is the
    classic TPU scatter-as-matmul: (ENTRY_BLOCK, D) x (ENTRY_BLOCK, N)
    one-hots contracted over the entry axis, accumulated into the
    VMEM-resident ring block that is revisited across grid steps.
  * the ring accumulator is tiled ``(D, TILE_N)`` so production tile
    sizes (n_local ~ 45k) never exceed VMEM; each ring tile stays
    resident while every entry block streams past it (targets are
    shifted per tile, so out-of-tile entries match no one-hot column
    and contribute nothing).

Interpret mode (CPU) executes the identical BlockSpec tiling and kernel
body with jnp ops, so tests exercise the same code path that compiles
on TPU.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Sized so the per-block one-hot target matrix -- the largest kernel
# intermediate, (ENTRY_BLOCK, TILE_N) f32 = 8 MiB -- plus its bool
# precursor (2 MiB), the resident ring tile and the entry blocks stay
# inside a ~16 MiB VMEM core.
ENTRY_BLOCK = 1024        # synapse entries per grid step (sublane dim)
TILE_N = 2048             # max ring-tile width (lane dim, multiple of 128)
LANES = 128


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _accum_kernel(meta_ref, blkmask_ref, tgt_ref, w_ref, d_ref,
                  ring_ref, out_ref):
    """One entry-block grid step of the fused scatter-add.

    meta_ref:    scalar prefetch [t_slot]
    blkmask_ref: scalar prefetch (n_entry_blocks,) -- 1 where the block
                 overlaps valid (non-padding) entries
    tgt/w/d:     (ENTRY_BLOCK, 1) flattened gathered synapse entries,
                 targets already shifted into this ring tile's frame
    ring/out:    (d_ring, tile_n) -- the revisited accumulator tile
    """
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = ring_ref[...]

    @pl.when(blkmask_ref[e] > 0)
    def _accum():
        d_ring, tile_n = out_ref.shape
        blk = tgt_ref.shape[0]
        t0 = meta_ref[0]
        slots = (t0 + d_ref[...]) % d_ring                    # (blk, 1)
        oh_slot = slots == jax.lax.broadcasted_iota(
            jnp.int32, (blk, d_ring), 1)
        oh_tgt = tgt_ref[...] == jax.lax.broadcasted_iota(
            jnp.int32, (blk, tile_n), 1)
        wslot = jnp.where(oh_slot, w_ref[...].astype(jnp.float32), 0.0)
        contrib = jax.lax.dot_general(
            wslot, oh_tgt.astype(jnp.float32),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        out_ref[...] += contrib


def _scatter_tile(meta, blk_mask, tgt_t, w_e, d_e, tile, *,
                  interpret: bool):
    """Run the entry-block grid against one resident ring tile."""
    d_ring, tile_n = tile.shape
    n_blocks = tgt_t.shape[0] // ENTRY_BLOCK
    entry_spec = pl.BlockSpec((ENTRY_BLOCK, 1), lambda e, m, bm: (e, 0))
    ring_spec = pl.BlockSpec((d_ring, tile_n), lambda e, m, bm: (0, 0))
    gspec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2, grid=(n_blocks,),
        in_specs=[entry_spec, entry_spec, entry_spec, ring_spec],
        out_specs=ring_spec)
    return pl.pallas_call(
        _accum_kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((d_ring, tile_n), jnp.float32),
        interpret=interpret,
    )(meta, blk_mask, tgt_t, w_e, d_e, tile)


def _scatter_entries(tgt_e, w_e, d_e, blk_mask, ring, t_slot, *,
                     interpret: bool):
    """Blocked scatter of flat entry lists into the (tiled) ring.

    tgt_e/w_e/d_e: (E, 1) with E a multiple of ENTRY_BLOCK; padding
    entries must carry w == 0.  ``blk_mask``: (E // ENTRY_BLOCK,) int32.
    """
    d_ring, n_local = ring.shape
    n_pad = _ceil_to(max(n_local, LANES), LANES)
    tile_n = min(TILE_N, n_pad)
    n_tiles = -(-n_pad // tile_n)
    n_pad = n_tiles * tile_n
    ring_p = jnp.pad(ring, ((0, 0), (0, n_pad - n_local)))
    meta = jnp.asarray([t_slot], jnp.int32).reshape(1)
    out = ring_p
    for i in range(n_tiles):
        tile = jax.lax.dynamic_slice(out, (0, i * tile_n),
                                     (d_ring, tile_n))
        new_tile = _scatter_tile(meta, blk_mask,
                                 tgt_e - jnp.int32(i * tile_n),
                                 w_e, d_e, tile, interpret=interpret)
        out = jax.lax.dynamic_update_slice(out, new_tile, (0, i * tile_n))
    return out[:, :n_local]


# ---------------------------------------------------------------------------
# Stage 1: compaction
# ---------------------------------------------------------------------------

def compact_events(spikes_src, n_rows: int, active_cap: int):
    """Spiking-row compaction: ascending row indices of the (at most
    ``active_cap``) spiking sources, padded with the sink row ``n_rows``.

    Returns (idx, n_spikes) -- ``idx`` is the event-index list; real
    events occupy a prefix because ``nonzero`` is order-preserving.
    """
    spk = spikes_src[:n_rows]
    (idx,) = jnp.nonzero(spk > 0, size=active_cap, fill_value=n_rows)
    return idx.astype(jnp.int32), jnp.sum(spk > 0)


# ---------------------------------------------------------------------------
# Stage 2+3: gather event rows and flatten to entry lists
# ---------------------------------------------------------------------------

def _gather_entries(tables: dict, idx):
    """Gather event rows and flatten to (A * cap, 1) entry columns."""
    rows_t = tables["tgt"][idx]
    rows_w = tables["w"][idx].astype(jnp.float32)
    rows_d = tables["dslot"][idx].astype(jnp.int32)

    def flat(x):
        return x.reshape(-1, 1)

    return flat(rows_t.astype(jnp.int32)), flat(rows_w), flat(rows_d)


# ---------------------------------------------------------------------------
# Fused entry points
# ---------------------------------------------------------------------------

def event_delivery(tables: dict, spikes_src, i_ring, t_slot,
                   d_ring: int, active_cap: int, *,
                   interpret: bool = True):
    """Fused single-tier delivery.  Drop-in for
    ``core.synapses.deliver_events``: returns (ring, n_events, n_dropped).
    """
    return event_delivery_banded([(tables, spikes_src, active_cap)],
                                 i_ring, t_slot, d_ring,
                                 interpret=interpret)


def event_delivery_banded(tiers: Sequence[Tuple[dict, jnp.ndarray, int]],
                          i_ring, t_slot, d_ring: int, *,
                          interpret: bool = True):
    """Fused multi-tier delivery: ONE kernel launch (per ring tile) for
    the local table plus every halo fan-out band.

    ``tiers``: sequence of (tables, spikes_src, active_cap); each tier's
    tables may have a different row capacity (the banded-halo layout) --
    entry flattening makes the concatenation capacity-agnostic.
    Returns (ring, n_events, n_dropped) summed over tiers.
    """
    assert i_ring.shape[0] == d_ring
    parts_t: List[jnp.ndarray] = []
    parts_w: List[jnp.ndarray] = []
    parts_d: List[jnp.ndarray] = []
    spans = []                 # (offset, cap, valid_rows) per tier
    n_events = jnp.zeros((), jnp.int32)
    n_dropped = jnp.zeros((), jnp.int32)
    offset = 0
    for tables, spikes_src, active_cap in tiers:
        n_rows, cap = tables["tgt"].shape[0] - 1, tables["tgt"].shape[1]
        idx, n_spk = compact_events(spikes_src, n_rows, active_cap)
        te, we, de = _gather_entries(tables, idx)
        parts_t.append(te)
        parts_w.append(we)
        parts_d.append(de)
        valid_rows = jnp.minimum(n_spk.astype(jnp.int32),
                                 jnp.int32(active_cap))
        spans.append((offset, cap, valid_rows))
        offset += te.shape[0]
        n_events = n_events + jnp.sum(tables["nnz"][idx]).astype(jnp.int32)
        n_dropped = n_dropped + jnp.maximum(
            n_spk - active_cap, 0).astype(jnp.int32)

    e_tot = _ceil_to(max(offset, ENTRY_BLOCK), ENTRY_BLOCK)
    pad = e_tot - offset
    tgt_e = jnp.concatenate(parts_t)
    w_e = jnp.concatenate(parts_w)
    d_e = jnp.concatenate(parts_d)
    if pad:
        tgt_e = jnp.pad(tgt_e, ((0, pad), (0, 0)))
        w_e = jnp.pad(w_e, ((0, pad), (0, 0)))
        d_e = jnp.pad(d_e, ((0, pad), (0, 0)))

    # Valid-entry ranges: tier t occupies [off, off + valid_rows * cap).
    # A block participates iff it overlaps any tier's range; all-padding
    # blocks are skipped in-kernel (runtime ~ synaptic events).
    n_blocks = e_tot // ENTRY_BLOCK
    starts = jnp.arange(n_blocks, dtype=jnp.int32) * ENTRY_BLOCK
    ends = starts + ENTRY_BLOCK
    mask = jnp.zeros((n_blocks,), jnp.bool_)
    for off, cap, valid_rows in spans:
        hi = jnp.int32(off) + valid_rows * jnp.int32(cap)
        mask = mask | ((starts < hi) & (ends > off))

    ring = _scatter_entries(tgt_e, w_e, d_e, mask.astype(jnp.int32),
                            i_ring, t_slot, interpret=interpret)
    return ring, n_events, n_dropped


# ---------------------------------------------------------------------------
# Legacy single-call API (kept for the kernel sweep tests)
# ---------------------------------------------------------------------------

def synaptic_accum_pallas(idx, t_slot, tgt, w, dslot, ring, *,
                          interpret: bool = True):
    """Deliver event rows ``idx`` (A,) through the tables into ``ring``.

    Equivalent to ``ref.synaptic_accum_ref``.  ``dslot`` int8/int32;
    ``ring`` (D, n_local) f32 -- returned updated.  Unlike
    ``event_delivery`` this takes a pre-compacted index list and cannot
    skip padding blocks (callers may pass arbitrary, unsorted indices).
    """
    tables = {"tgt": tgt, "w": w, "dslot": dslot}
    te, we, de = _gather_entries(tables, idx.astype(jnp.int32))
    offset = te.shape[0]
    e_tot = _ceil_to(max(offset, ENTRY_BLOCK), ENTRY_BLOCK)
    pad = e_tot - offset
    if pad:
        te = jnp.pad(te, ((0, pad), (0, 0)))
        we = jnp.pad(we, ((0, pad), (0, 0)))
        de = jnp.pad(de, ((0, pad), (0, 0)))
    mask = jnp.ones((e_tot // ENTRY_BLOCK,), jnp.int32)
    return _scatter_entries(te, we, de, mask, ring, t_slot,
                            interpret=interpret)
