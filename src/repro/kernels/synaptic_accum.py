"""Event-driven synaptic accumulation as a Pallas TPU kernel.

This is DPSNN's hot loop: deliver every spike through its synapse-table
row into the delayed-current ring.  The TPU shape of the problem:

  * the *event list* (compacted spiking-row indices) is tiny and known
    before the grid runs -> **scalar prefetch**: the grid is one step per
    event, and each step's input block is the event's table row, selected
    by a dynamic ``index_map`` reading the prefetched index vector.  Rows
    of non-events point at the all-zero sink row (last row), so padding
    is harmless.
  * the ring accumulator (D x n_local f32) fits VMEM for production tile
    sizes (e.g. 6x6 columns x 1240 neurons x 8 slots ~ 1.4 MB), so the
    scatter-add runs at VMEM latency, not HBM -- the key win over a
    naive XLA scatter that round-trips HBM per event row.
  * within a row the scatter is serialized (TPU has no vector scatter);
    the sequential ``fori_loop`` over the row's ``cap`` entries is the
    honest cost model -- one VMEM RMW per synaptic event, which is what
    "cost per synaptic event" means on this hardware.

The output block index_map is constant, so the accumulator block is
*revisited* across grid steps; step 0 initializes it from the input ring.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, tslot_ref, tgt_ref, w_ref, d_ref, ring_ref, out_ref):
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = ring_ref[...]

    d_ring = out_ref.shape[0]
    cap = tgt_ref.shape[1]
    t0 = tslot_ref[0]

    def body(k, _):
        t = tgt_ref[0, k]
        wv = w_ref[0, k].astype(jnp.float32)
        slot = (t0 + d_ref[0, k].astype(jnp.int32)) % d_ring
        cur = pl.load(out_ref, (pl.dslice(slot, 1), pl.dslice(t, 1)))
        pl.store(out_ref, (pl.dslice(slot, 1), pl.dslice(t, 1)), cur + wv)
        return 0

    jax.lax.fori_loop(0, cap, body, 0)


def synaptic_accum_pallas(idx, t_slot, tgt, w, dslot, ring, *,
                          interpret: bool = True):
    """Deliver event rows ``idx`` (A,) through the tables into ``ring``.

    Equivalent to ``ref.synaptic_accum_ref``.  ``dslot`` int8/int32;
    ``ring`` (D, n_local) f32 -- returned updated.
    """
    a = idx.shape[0]
    rows, cap = tgt.shape
    d_ringn, n_local = ring.shape
    t_arr = jnp.asarray([t_slot], jnp.int32)
    row_spec = pl.BlockSpec((1, cap), lambda e, idx_r, ts_r: (idx_r[e], 0))
    ring_spec = pl.BlockSpec((d_ringn, n_local), lambda e, idx_r, ts_r: (0, 0))
    grid_spec = pl.GridSpec(grid=(a,),
                            in_specs=[row_spec, row_spec, row_spec,
                                      ring_spec],
                            out_specs=ring_spec)
    try:
        from jax.experimental.pallas import tpu as pltpu
        gspec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(a,),
            in_specs=[row_spec, row_spec, row_spec, ring_spec],
            out_specs=ring_spec)
    except Exception:  # pragma: no cover - older API fallback
        gspec = grid_spec
    out = pl.pallas_call(
        _kernel,
        grid_spec=gspec,
        out_shape=jax.ShapeDtypeStruct((d_ringn, n_local), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), t_arr, tgt, w, dslot.astype(jnp.int32), ring)
    return out
