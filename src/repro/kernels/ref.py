"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: each kernel's sweep test asserts
``assert_allclose(kernel(x), ref(x))`` over shapes and dtypes.  They are
also the fallback implementation path on backends without Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# LIF + SFA fused neuron update (kernels/lif_step.py)
# ---------------------------------------------------------------------------

def lif_step_ref(v, c, refrac, i_total, active, *, leak_decay, sfa_decay,
                 g_sfa, dt_ms, v_rest, v_reset, theta, alpha_c,
                 refrac_steps):
    refractory = refrac > 0
    v_int = v_rest + (v - v_rest) * leak_decay + i_total - g_sfa * c * dt_ms
    v_new = jnp.where(refractory, v_reset, v_int)
    spiked = jnp.logical_and(v_new >= theta, active)
    v_new = jnp.where(spiked, v_reset, v_new)
    spk_f = spiked.astype(jnp.float32)
    c_new = c * sfa_decay + alpha_c * spk_f
    refrac_new = jnp.where(spiked, jnp.int32(refrac_steps),
                           jnp.maximum(refrac - 1, 0)).astype(jnp.int32)
    return v_new.astype(v.dtype), c_new.astype(c.dtype), refrac_new, spk_f


# ---------------------------------------------------------------------------
# Event-driven synaptic accumulation (kernels/synaptic_accum.py)
# ---------------------------------------------------------------------------

def synaptic_accum_ref(idx, t_slot, tgt, w, dslot, ring):
    """Deliver the rows listed in ``idx`` into the delay ring.

    idx: (A,) int32 row indices (padding rows point at the all-zero sink
    row ``tgt.shape[0]-1``); ring: (D, n_local) f32.
    """
    d_ring = ring.shape[0]
    rows_t = tgt[idx]
    rows_w = w[idx].astype(jnp.float32)
    rows_d = dslot[idx].astype(jnp.int32)
    slots = (t_slot + rows_d) % d_ring
    return ring.at[slots.ravel(), rows_t.ravel()].add(rows_w.ravel())


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (kernels/flash_attention.py)
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=None, scale=None,
                  q_offset=0):
    """Masked multi-head attention oracle.

    q: (BH, Sq, D); k, v: (BH_kv, Sk, D) with BH % BH_kv == 0 (GQA --
    query-head block bh uses kv head bh // (BH // BH_kv)).
    ``window``: sliding-window width (keys with q_pos - k_pos >= window
    masked out); ``q_offset``: absolute position of q[0] (decode).
    """
    bh, sq, d = q.shape
    bh_kv = k.shape[0]
    group = bh // bh_kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = jnp.repeat(k, group, axis=0)
    vv = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> 0
    return jnp.einsum("bqk,bkd->bqd", p, vv).astype(q.dtype)
