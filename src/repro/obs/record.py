"""Device-side spike recorder: bounded per-segment event buffers.

Recording runs *inside* the simulation scan (``engine.simulate`` /
``dist_engine.make_sim_fn``): each step's spike vector is compacted to
its spiking-row indices -- through the Pallas compaction kernel
(``kernels.spike_compact``) or the XLA ``compact_events`` fallback,
following the engine's ``use_kernels="auto"`` routing -- and appended as
``(sim_step, global_neuron_id)`` pairs to a fixed-capacity buffer
carried in the scan state.  Overflow never aborts or reallocates: spikes
that do not fit increment an explicit drop counter, so a too-small
capacity is *visible* (surfaced by ``SimDriver`` and ``--metrics-out``),
not silent.

Recording is a pure function of the spike vector: it consumes no RNG and
feeds nothing back into the dynamics, so spike trains with recording on
are bit-identical to recording off (tested).

The buffer is per-shard and per-segment: the host spooler
(``obs.spool``) drains it between segments, so host/device memory stays
bounded for multi-hour runs.  Neuron identity is the tiling-invariant
**global neuron id** ``global_column_id * n_per_column + within_column``
-- the same id ``core.retile`` permutes by -- so logs written before and
after an elastic retile concatenate seamlessly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core.grid import TileDecomposition


@dataclasses.dataclass(frozen=True)
class RecorderSpec:
    """Static sizing of the device-side recorder (one shard, one segment).

    ``capacity``: event slots per shard per segment.  ``active_cap``:
    per-step compaction width (spikes per step beyond it are dropped and
    counted -- same bound the event-delivery pipeline uses).  ``n_rows``:
    neuron slots per shard.  ``use_kernels``: route compaction through
    the Pallas kernel (True) or the XLA fallback (False).
    """

    capacity: int
    active_cap: int
    n_rows: int
    use_kernels: bool = True

    def __post_init__(self):
        if self.capacity <= 0:
            raise ValueError(f"recorder capacity={self.capacity} must be > 0")


def recorder_spec(engine_cfg, segment_steps: int,
                  capacity: Optional[int] = None) -> RecorderSpec:
    """Size a recorder for ``engine_cfg``.

    The default capacity ``active_cap_local * segment_steps`` is the
    no-drop bound: the per-step compaction can never emit more than
    ``active_cap_local`` events, so the segment buffer can never
    overflow.  At 8 bytes/event that is ~1.2 MiB per shard for the
    committed 8x8x60 / 50-step-segment benchmark config.  Pass
    ``capacity`` to trade memory for (counted) drops.
    """
    spec = engine_cfg.spec()
    cap = spec.active_cap_local * segment_steps if capacity is None \
        else capacity
    return RecorderSpec(capacity=cap, active_cap=spec.active_cap_local,
                        n_rows=spec.n_local,
                        use_kernels=engine_cfg.kernels_enabled)


def init_recorder_state(rspec: RecorderSpec) -> dict:
    """Empty per-segment recorder carry (one shard)."""
    return {
        "step": jnp.zeros((rspec.capacity,), jnp.int32),
        "gid": jnp.zeros((rspec.capacity,), jnp.int32),
        "count": jnp.zeros((), jnp.int32),
        "dropped": jnp.zeros((), jnp.int32),
    }


def tile_gid_map(decomp: TileDecomposition, tile_y: int,
                 tile_x: int) -> np.ndarray:
    """(n_local + 1,) global neuron id of each local slot; -1 for slots
    in padded columns and for the trailing compaction-sink slot."""
    from ..core.retile import global_column_ids
    gid_col = global_column_ids(decomp)[tile_y, tile_x]      # (tile_cols,)
    n_per = decomp.grid.n_per_column
    gnid = gid_col[:, None] * n_per + np.arange(n_per)[None, :]
    gnid = np.where(gid_col[:, None] >= 0, gnid, -1).ravel()
    return np.concatenate([gnid, [-1]]).astype(np.int32)


def stacked_gid_maps(decomp: TileDecomposition) -> np.ndarray:
    """(TY, TX, n_local + 1) int32 -- per-shard gid maps, stacked like
    the distributed state/tables."""
    return np.stack([
        np.stack([tile_gid_map(decomp, y, x)
                  for x in range(decomp.tiles_x)])
        for y in range(decomp.tiles_y)])


def record_step(rec: dict, spikes, gids, t, rspec: RecorderSpec) -> dict:
    """Append this step's spikes to the segment buffer.

    ``spikes``: (n_rows,) spike vector (>0 == spiked); ``gids``:
    (n_rows + 1,) global-id map (sink slot last); ``t``: the sim step
    the spikes belong to (absolute, so spooled logs need no segment
    bookkeeping).  Returns the new recorder carry; pure -- never touches
    the dynamics.
    """
    if rspec.use_kernels:
        from ..kernels import ops as kops
        idx, n_spk = kops.spike_compact(spikes, rspec.n_rows,
                                        rspec.active_cap)
    else:
        from ..kernels.synaptic_accum import compact_events
        idx, n_spk = compact_events(spikes, rspec.n_rows, rspec.active_cap)
    n_spk = n_spk.astype(jnp.int32)
    take = jnp.minimum(n_spk, rspec.active_cap)
    room = jnp.maximum(rspec.capacity - rec["count"], 0)
    appended = jnp.minimum(take, room)
    ar = jnp.arange(rspec.active_cap, dtype=jnp.int32)
    # invalid lanes scatter to index `capacity` == out of bounds, which
    # mode="drop" discards -- no branch, no dynamic shapes
    pos = jnp.where(ar < appended, rec["count"] + ar, rspec.capacity)
    step_v = jnp.full((rspec.active_cap,), t, jnp.int32)
    return {
        "step": rec["step"].at[pos].set(step_v, mode="drop"),
        "gid": rec["gid"].at[pos].set(gids[idx], mode="drop"),
        "count": rec["count"] + appended,
        "dropped": rec["dropped"] + (n_spk - appended),
    }
