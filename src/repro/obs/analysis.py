"""Activity analysis over spooled spike logs (the paper-family stats).

The DPSNN companion studies validate the simulator by *activity*, not
just throughput: firing-rate distributions (Pastorelli et al. 2018,
arXiv:1803.08833) and slow-wave vs awake-like regime statistics
(Pastorelli et al. 2019, Front. Syst. Neurosci. 13:33).  This module
computes those families from the observatory's spooled ``(step, gid)``
event logs:

  * per-neuron and per-column firing-rate distributions (columns are
    tiling-invariant, so "per-tile" statistics survive elastic
    retiles), plus per-shard-log event totals;
  * ISI coefficient of variation (irregularity of single-neuron spike
    trains; ~1 for Poisson-like firing);
  * population-rate time series with thresholded Down/Up state
    segmentation and a slow-wave vs awake-like regime call:
    the smoothed population rate is thresholded at ``lo + frac * (hi -
    lo)`` (lo/hi = 10th/90th percentile); a run that keeps toggling
    between Down and Up states with a duty cycle away from saturation
    classifies as ``slow_wave_like``, a run pinned in the Up state as
    ``awake_like``, and a run with (almost) no spikes as ``silent``;
  * multi-run comparison tables (e.g. Gaussian vs exponential law):
    mean-rate ratios and the two-sample Kolmogorov-Smirnov statistic
    between per-neuron rate distributions.

Everything returns plain JSON-serializable dicts; the
``repro.launch.analyze`` CLI renders them under ``results/``.
"""

from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from .spool import load_events, read_header, shard_events


def _percentiles(x: np.ndarray, qs=(5, 25, 50, 75, 95)) -> dict:
    return {f"p{q:02d}": float(np.percentile(x, q)) for q in qs}


def rate_distribution(counts: np.ndarray, sim_sec: float,
                      n_bins: int = 24) -> dict:
    """Firing-rate distribution of ``counts`` spike counts over
    ``sim_sec`` seconds (one entry per neuron or per column)."""
    rates = counts / max(sim_sec, 1e-9)
    hi = float(rates.max()) if len(rates) else 0.0
    edges = np.linspace(0.0, max(hi, 1e-9), n_bins + 1)
    hist, _ = np.histogram(rates, bins=edges)
    out = {
        "n": int(len(rates)),
        "mean_hz": float(rates.mean()) if len(rates) else 0.0,
        "std_hz": float(rates.std()) if len(rates) else 0.0,
        "min_hz": float(rates.min()) if len(rates) else 0.0,
        "max_hz": hi,
        "fraction_silent": float(np.mean(counts == 0)) if len(counts)
        else 1.0,
        "hist": {"edges_hz": [float(e) for e in edges],
                 "counts": [int(c) for c in hist]},
    }
    if len(rates):
        out.update(_percentiles(rates))
    return out


def isi_cv(events: np.ndarray, min_spikes: int = 3) -> dict:
    """Per-neuron inter-spike-interval coefficient of variation.

    Neurons with fewer than ``min_spikes`` spikes (< 2 intervals) carry
    no irregularity information and are excluded (their count is
    reported).  CV ~ 1 is Poisson-like, << 1 regular, >> 1 bursty.
    """
    if len(events) == 0:
        return {"n_neurons": 0, "n_excluded": 0,
                "mean_cv": None, "median_cv": None}
    order = np.lexsort((events["step"], events["gid"]))
    gid = events["gid"][order].astype(np.int64)
    step = events["step"][order].astype(np.int64)
    isi = np.diff(step)
    same = gid[1:] == gid[:-1]                    # interval stays in-neuron
    # segment boundaries per neuron
    uniq, start, counts = np.unique(gid, return_index=True,
                                    return_counts=True)
    cvs = []
    excluded = 0
    for s, c in zip(start, counts):
        if c < min_spikes:
            excluded += 1
            continue
        iv = isi[s:s + c - 1]
        assert same[s:s + c - 1].all()
        m = iv.mean()
        cvs.append(iv.std() / m if m > 0 else 0.0)
    if not cvs:
        return {"n_neurons": 0, "n_excluded": excluded,
                "mean_cv": None, "median_cv": None}
    cvs = np.asarray(cvs)
    return {"n_neurons": int(len(cvs)), "n_excluded": int(excluded),
            "mean_cv": float(cvs.mean()), "median_cv": float(np.median(cvs)),
            **_percentiles(cvs)}


def population_rate(events: np.ndarray, t_steps: int, n_neurons: int,
                    dt_ms: float, bin_steps: int = 1) -> np.ndarray:
    """(n_bins,) mean per-neuron rate in Hz per time bin."""
    n_bins = -(-t_steps // bin_steps)
    counts = np.bincount(events["step"].astype(np.int64) // bin_steps,
                         minlength=n_bins)[:n_bins]
    bin_sec = bin_steps * dt_ms * 1e-3
    return counts / max(n_neurons, 1) / bin_sec


def updown_segmentation(pop_hz: np.ndarray, smooth_bins: int = 5,
                        frac: float = 0.3) -> dict:
    """Threshold the (smoothed) population rate into Down/Up states.

    Threshold = ``lo + frac * (hi - lo)`` with lo/hi the 10th/90th
    percentile of the smoothed series -- scale-free, so the same
    segmentation applies to the Gaussian net at ~8 Hz and the
    exponential net at ~35 Hz.  Durations are reported in bins.
    """
    if len(pop_hz) == 0 or float(pop_hz.max()) <= 0.0:
        return {"regime": "silent", "threshold_hz": 0.0,
                "up_fraction": 0.0, "n_up_periods": 0, "n_down_periods": 0,
                "mean_up_bins": None, "mean_down_bins": None}
    k = max(1, min(smooth_bins, len(pop_hz)))
    # edge-replicated moving average ("same"-mode convolution zero-pads,
    # which fakes Down states at the series boundaries)
    padded = np.pad(pop_hz, (k // 2, k - 1 - k // 2), mode="edge")
    sm = np.convolve(padded, np.ones(k) / k, mode="valid")
    lo, hi = np.percentile(sm, 10), np.percentile(sm, 90)
    if hi - lo < 0.25 * sm.mean():
        # sustained firing with small fluctuations: thresholding inside
        # the noise band would fabricate state flips
        return {"regime": "awake_like", "threshold_hz": float(lo),
                "up_fraction": 1.0, "n_up_periods": 1, "n_down_periods": 0,
                "mean_up_bins": float(len(sm)), "mean_down_bins": None}
    thr = float(lo + frac * (hi - lo))
    up = sm > thr
    edges = np.flatnonzero(np.diff(up.astype(np.int8)))
    bounds = np.concatenate([[-1], edges, [len(up) - 1]])
    durations = np.diff(bounds)
    states = up[bounds[1:]]                      # state of each run-length
    up_d = durations[states]
    down_d = durations[~states]
    up_fraction = float(np.mean(up))
    if up_fraction >= 0.95 or len(down_d) == 0:
        regime = "awake_like"
    elif len(up_d) >= 2 and len(down_d) >= 1 and up_fraction > 0.02:
        regime = "slow_wave_like"
    else:
        regime = "sparse"
    return {
        "regime": regime, "threshold_hz": thr,
        "up_fraction": up_fraction,
        "n_up_periods": int(len(up_d)), "n_down_periods": int(len(down_d)),
        "mean_up_bins": float(up_d.mean()) if len(up_d) else None,
        "mean_down_bins": float(down_d.mean()) if len(down_d) else None,
    }


def ks_statistic(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sample Kolmogorov-Smirnov statistic (max ECDF distance) --
    the "distinct distribution" score for rate-distribution tables."""
    if len(a) == 0 or len(b) == 0:
        return 0.0
    grid = np.sort(np.concatenate([a, b]))
    ca = np.searchsorted(np.sort(a), grid, side="right") / len(a)
    cb = np.searchsorted(np.sort(b), grid, side="right") / len(b)
    return float(np.abs(ca - cb).max())


def _infer_t_steps(run_dir: str, events: np.ndarray) -> int:
    """Best-effort run length: the latest checkpoint label when the
    spool sits inside a run directory (exact -- the driver checkpoints
    at the final step), else the last event's step + 1 (biased low if
    the run ended silent)."""
    from ..checkpoint.store import latest_step
    d = os.path.abspath(run_dir)
    cands = [d, os.path.dirname(d)]
    # a member stream sits at <run>/spool/member_NNN -- walk up past
    # the spool wrapper directories to the checkpointed run itself
    while (os.path.basename(d).startswith("member_")
           or os.path.basename(d) == "spool"):
        d = os.path.dirname(d)
        cands.append(d)
    for c in dict.fromkeys(cands):
        last = latest_step(c)
        if last is not None:
            return int(last)
    return int(events["step"].max()) + 1 if len(events) else 0


def analyze_run(run_dir: str, t_steps: Optional[int] = None,
                bin_steps: int = 5, smooth_bins: int = 5,
                updown_frac: float = 0.3) -> dict:
    """Full activity report for one recorded run.

    ``run_dir``: the run (checkpoint) directory or its ``spool``
    subdirectory.  ``t_steps``: simulated steps; inferred from the run's
    checkpoints (or the last event) when omitted.
    """
    header = read_header(run_dir)
    events = load_events(run_dir)
    if t_steps is None:
        t_steps = _infer_t_steps(run_dir, events)
    n_neurons = int(header["n_neurons"])
    n_per_col = int(header["grid"][2])
    dt_ms = float(header.get("dt_ms", 1.0))
    sim_sec = t_steps * dt_ms * 1e-3
    gid = events["gid"].astype(np.int64)
    neuron_counts = np.bincount(gid, minlength=n_neurons) if len(events) \
        else np.zeros(n_neurons, np.int64)
    col_counts = neuron_counts.reshape(-1, n_per_col).sum(axis=1) \
        / n_per_col                    # mean per-neuron count per column
    pop = population_rate(events, t_steps, n_neurons, dt_ms, bin_steps)
    report = {
        "run_dir": os.path.abspath(run_dir),
        "law": header.get("law"), "grid": header.get("grid"),
        "seed": header.get("seed"),
        "t_steps": int(t_steps), "sim_seconds": sim_sec,
        "n_events": int(len(events)),
        "mean_rate_hz": float(len(events)) / max(n_neurons, 1)
        / max(sim_sec, 1e-9),
        "rates": rate_distribution(neuron_counts, sim_sec),
        "rates_per_column": rate_distribution(col_counts, sim_sec,
                                              n_bins=16),
        "per_shard_events": {k: int(len(v))
                             for k, v in shard_events(run_dir).items()},
        "isi": isi_cv(events),
        "population": {
            "bin_steps": bin_steps,
            "mean_hz": float(pop.mean()) if len(pop) else 0.0,
            "peak_hz": float(pop.max()) if len(pop) else 0.0,
            "updown": updown_segmentation(pop, smooth_bins, updown_frac),
        },
        "_neuron_rates": neuron_counts / max(sim_sec, 1e-9),  # stripped
    }
    if len(pop) <= 512:                  # keep JSON bounded for long runs
        report["population"]["series_hz"] = [float(x) for x in pop]
    return report


def compare_runs(reports: Dict[str, dict]) -> dict:
    """Cross-run comparison table (e.g. Gaussian vs exponential).

    For every ordered pair: mean-rate ratio and the KS statistic
    between per-neuron rate distributions.
    """
    labels = list(reports)
    table = {}
    for i, a in enumerate(labels):
        for b in labels[i + 1:]:
            ra = reports[a].get("_neuron_rates")
            rb = reports[b].get("_neuron_rates")
            ma = reports[a]["mean_rate_hz"]
            mb = reports[b]["mean_rate_hz"]
            table[f"{a}_vs_{b}"] = {
                "mean_rate_ratio": ma / mb if mb > 0 else None,
                "rate_ks_statistic": ks_statistic(
                    np.asarray(ra), np.asarray(rb))
                if ra is not None and rb is not None else None,
            }
    return {
        "mean_rate_hz": {k: r["mean_rate_hz"] for k, r in reports.items()},
        "regime": {k: r["population"]["updown"]["regime"]
                   for k, r in reports.items()},
        "pairs": table,
    }


def strip_private(report: dict) -> dict:
    """Drop the in-memory-only arrays before JSON serialization."""
    return {k: v for k, v in report.items() if not k.startswith("_")}
