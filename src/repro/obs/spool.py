"""Host-side spike-log spooler: sharded, append-only, exactly-once.

Layout (one directory per run, by default ``<ckpt_dir>/spool``)::

    spool/
        header.json                  # format + model identity (once)
        events_000_000.spk ...       # one log per recording shard

    spool/                           # ensemble run (M member streams)
        header.json                  # shared identity + ensemble_seeds
        member_000/
            header.json              # + member index / state_seed
            events_000_000.spk ...
        member_001/ ...

Each ``.spk`` file is a raw little-endian stream of fixed 8-byte
records ``(step int32, gid int32)`` -- ``RECORD_DTYPE`` -- appended in
sim-step order by a daemon writer thread (same pattern as
``checkpoint.store.AsyncCheckpointer``: ``append`` costs a host-side
copy, the file write happens off the hot path).

Exactly-once contract with the segmented driver: the spooler's
per-shard event counts are updated synchronously at ``append`` time, so
the driver can snapshot ``offsets()`` into each checkpoint's manifest
(atomic with the checkpoint).  On any restore -- preemption resume,
failure rewind, elastic retile -- ``truncate(manifest_offsets)`` cuts
every log back to the checkpoint's frontier and wipes logs the manifest
does not know, so replayed segments re-append their events exactly once
and a crash can never leave phantom events from an abandoned timeline.
Ensemble member logs are ordinary shard logs under a subdirectory --
their offsets ride the same manifest under their relative path, so the
contract covers every member uniformly.

Shard files are keyed by the *writing* tile, but events carry global
neuron ids, so logs written under different tilings (before/after an
elastic retile) concatenate into one coherent global stream --
``load_events`` merges and orders them by ``(step, gid)``.

The append-only layout doubles as a streaming surface: a reader that
remembers per-log record offsets (``offsets()``-shaped) can poll
``read_new_events`` for just the records appended since its cursor --
this is what the sim job server's incremental endpoint serves to
concurrent clients while a run is still in flight.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

import numpy as np

from ..checkpoint.store import AsyncWriterThread
from .telemetry import NULL, Telemetry

RECORD_DTYPE = np.dtype([("step", "<i4"), ("gid", "<i4")])
FORMAT = "dpsnn-spk-v1"


def shard_name(tile_y: int, tile_x: int) -> str:
    return f"events_{tile_y:03d}_{tile_x:03d}.spk"


def member_name(member: int) -> str:
    return f"member_{member:03d}"


def _write_or_validate_header(directory: str, header: dict):
    """Create ``header.json`` or validate an existing one key-by-key."""
    hpath = os.path.join(directory, "header.json")
    if os.path.exists(hpath):
        with open(hpath) as f:
            have = json.load(f)
        for k, v in header.items():
            if k in have and have[k] != v:
                raise ValueError(
                    f"spool header {hpath} was written with {k}="
                    f"{have[k]!r}, current run has {k}={v!r} -- "
                    "this spool directory belongs to a different "
                    "model; use a fresh --ckpt-dir or delete it")
    else:
        with open(hpath, "w") as f:
            json.dump({"format": FORMAT,
                       "record": [list(t[:2]) for t in RECORD_DTYPE.descr],
                       **header}, f, indent=1)


class SpikeSpooler(AsyncWriterThread):
    """Async writer of per-shard spike logs.

    ``tiles``: the recording tiling -- its shard files are created
    eagerly so zero-spike runs still leave valid (empty) logs.
    ``header``: model-identity dict written to ``header.json`` on first
    open (grid, law, dt -- everything analysis needs).  An existing
    header is kept (resumes must not rewrite history) but **validated**:
    a spool directory left behind by a *different* model is refused, the
    same way the driver refuses a checkpoint-meta mismatch -- silently
    appending 8x8x60 events to a 4x4x20 header would poison every
    downstream rate (analysis normalizes by the header's n_neurons).

    ``members``: member state seeds of an ensemble run.  When given,
    each member gets its own ``member_{m:03d}/`` stream (own validated
    header carrying that member's ``state_seed``), ``append`` takes the
    member index, and offsets/truncation key logs by their relative
    path -- the exactly-once contract is per member log.
    """

    def __init__(self, directory: str, tiles, header: Optional[dict] = None,
                 telemetry: Telemetry = NULL, members=None):
        self.directory = directory
        self.tel = telemetry
        self.members = (None if members is None
                        else tuple(int(s) for s in members))
        os.makedirs(directory, exist_ok=True)
        header = dict(header or {})
        if self.members is None:
            shard_dirs = [("", header)]
        else:
            _write_or_validate_header(
                directory, dict(header, ensemble_seeds=list(self.members)))
            shard_dirs = []
            for m, s in enumerate(self.members):
                sub = member_name(m)
                os.makedirs(os.path.join(directory, sub), exist_ok=True)
                shard_dirs.append(
                    (sub, dict(header, member=m, state_seed=s)))
        self._counts: Dict[str, int] = {}
        for sub, hdr in shard_dirs:
            _write_or_validate_header(os.path.join(directory, sub), hdr)
            for ty in range(tiles[0]):
                for tx in range(tiles[1]):
                    name = os.path.join(sub, shard_name(ty, tx)) if sub \
                        else shard_name(ty, tx)
                    path = os.path.join(directory, name)
                    with open(path, "ab"):
                        pass
                    self._counts[name] = os.path.getsize(path) \
                        // RECORD_DTYPE.itemsize
        # pre-existing logs of *other* tilings (elastic resume) keep
        # appending under their own names; count them too
        for name in _iter_spk(directory):
            if name not in self._counts:
                self._counts[name] = os.path.getsize(
                    os.path.join(directory, name)) // RECORD_DTYPE.itemsize
        super().__init__()

    # ---- writer thread (AsyncWriterThread) -----------------------------
    def _write(self, item):
        name, arr = item
        with self.tel.span("spool.write", shard=name, events=len(arr)):
            with open(os.path.join(self.directory, name), "ab") as f:
                arr.tofile(f)

    # ---- producer API --------------------------------------------------
    def append(self, tile_y: int, tile_x: int, steps, gids,
               member: Optional[int] = None):
        """Enqueue one shard's segment events (valid prefixes only).

        The shard's offset advances *synchronously*, so ``offsets()``
        read immediately after covers this append -- the property the
        checkpoint-manifest snapshot relies on.  Ensemble spoolers
        require the ``member`` index (and solo spoolers refuse one)."""
        self._assert_owner("append")
        if (member is None) != (self.members is None):
            raise ValueError(
                f"append(member={member!r}) on a spooler with members="
                f"{self.members!r}: member index is required exactly "
                "when the spool is an ensemble")
        steps = np.asarray(steps)
        n = len(steps)
        name = shard_name(tile_y, tile_x)
        if member is not None:
            name = os.path.join(member_name(member), name)
        if name not in self._counts:          # a tiling seen mid-run
            with open(os.path.join(self.directory, name), "ab"):
                pass
            self._counts[name] = 0
        if n == 0:
            return
        arr = np.empty(n, RECORD_DTYPE)
        arr["step"] = steps
        arr["gid"] = np.asarray(gids)
        self._counts[name] += n
        self._submit((name, arr))

    def offsets(self) -> Dict[str, int]:
        """Per-shard event counts covering every ``append`` so far (the
        writes themselves may still be in flight).  Keys are paths
        relative to the spool directory (``member_000/...`` for
        ensemble streams)."""
        self._assert_owner("offsets")
        return dict(self._counts)

    def truncate(self, offsets: Dict[str, int]):
        """Rewind every log to a checkpoint's spool frontier.

        Logs absent from ``offsets`` are cut to zero: they belong to a
        timeline the checkpoint does not know about (events appended
        after the checkpoint, possibly under a different tiling)."""
        self._assert_owner("truncate")
        self.wait()
        for fn in sorted(self._counts):
            path = os.path.join(self.directory, fn)
            want = int(offsets.get(fn, 0)) * RECORD_DTYPE.itemsize
            have = os.path.getsize(path)
            if have < want:
                raise IOError(
                    f"spool log {path} holds {have} bytes but the "
                    f"checkpoint manifest expects {want} -- the log was "
                    "truncated or deleted behind the driver's back")
            if have > want:
                os.truncate(path, want)
            self._counts[fn] = want // RECORD_DTYPE.itemsize
        for fn, n in offsets.items():
            if fn not in self._counts and int(n) > 0:
                raise IOError(
                    f"checkpoint manifest expects {n} events in missing "
                    f"spool log {os.path.join(self.directory, fn)}")


# --------------------------------------------------------------------------
# Readers
# --------------------------------------------------------------------------

def _spool_dir(run_dir: str) -> str:
    sub = os.path.join(run_dir, "spool")
    return sub if os.path.isdir(sub) else run_dir


def _iter_spk(directory: str):
    """Relative paths of every ``.spk`` log: top level plus one level of
    ``member_*`` subdirectories, in sorted order."""
    for fn in sorted(os.listdir(directory)):
        path = os.path.join(directory, fn)
        if fn.endswith(".spk"):
            yield fn
        elif fn.startswith("member_") and os.path.isdir(path):
            for sub in sorted(os.listdir(path)):
                if sub.endswith(".spk"):
                    yield os.path.join(fn, sub)


def read_header(run_dir: str) -> dict:
    """The spool's ``header.json``; ``run_dir`` may be the run (ckpt)
    directory, the spool directory, or one member's stream directory."""
    with open(os.path.join(_spool_dir(run_dir), "header.json")) as f:
        h = json.load(f)
    if h.get("format") != FORMAT:
        raise ValueError(f"{run_dir}: unknown spool format "
                         f"{h.get('format')!r} (expected {FORMAT!r})")
    return h


def member_dirs(run_dir: str) -> Dict[str, str]:
    """Ensemble member streams under a run: ``{"member_000": abspath,
    ...}`` in member order; empty for a solo run."""
    d = _spool_dir(run_dir)
    out = {}
    for fn in sorted(os.listdir(d)) if os.path.isdir(d) else []:
        path = os.path.join(d, fn)
        if fn.startswith("member_") and os.path.isdir(path):
            out[fn] = path
    return out


def shard_events(run_dir: str) -> Dict[str, np.ndarray]:
    """Per-shard raw event arrays (file order preserved).  For an
    ensemble run this is ONE member's stream directory -- pass a
    ``member_dirs`` entry, not the spool root."""
    d = _spool_dir(run_dir)
    out = {}
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".spk"):
            out[fn] = np.fromfile(os.path.join(d, fn), dtype=RECORD_DTYPE)
    return out


def load_events(run_dir: str) -> np.ndarray:
    """All spooled events merged into one global stream, ordered by
    ``(step, gid)`` -- the canonical order for comparing runs (shard
    interleaving is tiling-dependent; the ordered stream is not)."""
    shards = list(shard_events(run_dir).values())
    if not shards:
        raise FileNotFoundError(f"no .spk spike logs under {run_dir}")
    ev = np.concatenate(shards) if len(shards) > 1 else shards[0]
    return ev[np.lexsort((ev["gid"], ev["step"]))]


def read_new_events(run_dir: str, cursor: Optional[Dict[str, int]] = None
                    ) -> Tuple[Dict[str, np.ndarray], Dict[str, int]]:
    """Incremental read: records appended since ``cursor``.

    ``cursor`` maps relative log paths to record offsets (the shape of
    ``SpikeSpooler.offsets()``); ``None`` reads from the beginning.
    Returns ``(new_events, new_cursor)`` where ``new_events`` holds the
    per-log arrays appended at/after the cursor (only logs with new
    records appear) and ``new_cursor`` covers every log seen.  Safe
    against concurrent appends: a torn trailing record (partial 8-byte
    write in flight) is excluded by reading whole records only, and the
    writer is append-only, so successive cursors are monotone.  This is
    the read side of the exactly-once offset contract and the backing
    of the job server's streaming endpoint.
    """
    d = _spool_dir(run_dir)
    cursor = dict(cursor or {})
    new = {}
    for name in _iter_spk(d):
        path = os.path.join(d, name)
        have = os.path.getsize(path) // RECORD_DTYPE.itemsize
        done = int(cursor.get(name, 0))
        if have > done:
            new[name] = np.fromfile(
                path, dtype=RECORD_DTYPE, count=have - done,
                offset=done * RECORD_DTYPE.itemsize)
        cursor[name] = max(have, done)
    return new, cursor
