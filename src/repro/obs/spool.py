"""Host-side spike-log spooler: sharded, append-only, exactly-once.

Layout (one directory per run, by default ``<ckpt_dir>/spool``)::

    spool/
        header.json                  # format + model identity (once)
        events_000_000.spk ...       # one log per recording shard

Each ``.spk`` file is a raw little-endian stream of fixed 8-byte
records ``(step int32, gid int32)`` -- ``RECORD_DTYPE`` -- appended in
sim-step order by a daemon writer thread (same pattern as
``checkpoint.store.AsyncCheckpointer``: ``append`` costs a host-side
copy, the file write happens off the hot path).

Exactly-once contract with the segmented driver: the spooler's
per-shard event counts are updated synchronously at ``append`` time, so
the driver can snapshot ``offsets()`` into each checkpoint's manifest
(atomic with the checkpoint).  On any restore -- preemption resume,
failure rewind, elastic retile -- ``truncate(manifest_offsets)`` cuts
every log back to the checkpoint's frontier and wipes logs the manifest
does not know, so replayed segments re-append their events exactly once
and a crash can never leave phantom events from an abandoned timeline.

Shard files are keyed by the *writing* tile, but events carry global
neuron ids, so logs written under different tilings (before/after an
elastic retile) concatenate into one coherent global stream --
``load_events`` merges and orders them by ``(step, gid)``.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

import numpy as np

from ..checkpoint.store import AsyncWriterThread
from .telemetry import NULL, Telemetry

RECORD_DTYPE = np.dtype([("step", "<i4"), ("gid", "<i4")])
FORMAT = "dpsnn-spk-v1"


def shard_name(tile_y: int, tile_x: int) -> str:
    return f"events_{tile_y:03d}_{tile_x:03d}.spk"


class SpikeSpooler(AsyncWriterThread):
    """Async writer of per-shard spike logs.

    ``tiles``: the recording tiling -- its shard files are created
    eagerly so zero-spike runs still leave valid (empty) logs.
    ``header``: model-identity dict written to ``header.json`` on first
    open (grid, law, dt -- everything analysis needs).  An existing
    header is kept (resumes must not rewrite history) but **validated**:
    a spool directory left behind by a *different* model is refused, the
    same way the driver refuses a checkpoint-meta mismatch -- silently
    appending 8x8x60 events to a 4x4x20 header would poison every
    downstream rate (analysis normalizes by the header's n_neurons).
    """

    def __init__(self, directory: str, tiles, header: Optional[dict] = None,
                 telemetry: Telemetry = NULL):
        self.directory = directory
        self.tel = telemetry
        os.makedirs(directory, exist_ok=True)
        hpath = os.path.join(directory, "header.json")
        if os.path.exists(hpath):
            with open(hpath) as f:
                have = json.load(f)
            for k, v in (header or {}).items():
                if k in have and have[k] != v:
                    raise ValueError(
                        f"spool header {hpath} was written with {k}="
                        f"{have[k]!r}, current run has {k}={v!r} -- "
                        "this spool directory belongs to a different "
                        "model; use a fresh --ckpt-dir or delete it")
        else:
            with open(hpath, "w") as f:
                json.dump({"format": FORMAT,
                           "record": [list(t[:2]) for t in RECORD_DTYPE.descr],
                           **(header or {})}, f, indent=1)
        self._counts: Dict[str, int] = {}
        for ty in range(tiles[0]):
            for tx in range(tiles[1]):
                name = shard_name(ty, tx)
                path = os.path.join(directory, name)
                with open(path, "ab"):
                    pass
                self._counts[name] = os.path.getsize(path) \
                    // RECORD_DTYPE.itemsize
        # pre-existing logs of *other* tilings (elastic resume) keep
        # appending under their own names; count them too
        for fn in os.listdir(directory):
            if fn.endswith(".spk") and fn not in self._counts:
                self._counts[fn] = os.path.getsize(
                    os.path.join(directory, fn)) // RECORD_DTYPE.itemsize
        super().__init__()

    # ---- writer thread (AsyncWriterThread) -----------------------------
    def _write(self, item):
        name, arr = item
        with self.tel.span("spool.write", shard=name, events=len(arr)):
            with open(os.path.join(self.directory, name), "ab") as f:
                arr.tofile(f)

    # ---- producer API --------------------------------------------------
    def append(self, tile_y: int, tile_x: int, steps, gids):
        """Enqueue one shard's segment events (valid prefixes only).

        The shard's offset advances *synchronously*, so ``offsets()``
        read immediately after covers this append -- the property the
        checkpoint-manifest snapshot relies on."""
        self._assert_owner("append")
        steps = np.asarray(steps)
        n = len(steps)
        name = shard_name(tile_y, tile_x)
        if name not in self._counts:          # a tiling seen mid-run
            with open(os.path.join(self.directory, name), "ab"):
                pass
            self._counts[name] = 0
        if n == 0:
            return
        arr = np.empty(n, RECORD_DTYPE)
        arr["step"] = steps
        arr["gid"] = np.asarray(gids)
        self._counts[name] += n
        self._submit((name, arr))

    def offsets(self) -> Dict[str, int]:
        """Per-shard event counts covering every ``append`` so far (the
        writes themselves may still be in flight)."""
        self._assert_owner("offsets")
        return dict(self._counts)

    def truncate(self, offsets: Dict[str, int]):
        """Rewind every log to a checkpoint's spool frontier.

        Logs absent from ``offsets`` are cut to zero: they belong to a
        timeline the checkpoint does not know about (events appended
        after the checkpoint, possibly under a different tiling)."""
        self._assert_owner("truncate")
        self.wait()
        for fn in sorted(self._counts):
            path = os.path.join(self.directory, fn)
            want = int(offsets.get(fn, 0)) * RECORD_DTYPE.itemsize
            have = os.path.getsize(path)
            if have < want:
                raise IOError(
                    f"spool log {path} holds {have} bytes but the "
                    f"checkpoint manifest expects {want} -- the log was "
                    "truncated or deleted behind the driver's back")
            if have > want:
                os.truncate(path, want)
            self._counts[fn] = want // RECORD_DTYPE.itemsize
        for fn, n in offsets.items():
            if fn not in self._counts and int(n) > 0:
                raise IOError(
                    f"checkpoint manifest expects {n} events in missing "
                    f"spool log {os.path.join(self.directory, fn)}")


# --------------------------------------------------------------------------
# Readers
# --------------------------------------------------------------------------

def _spool_dir(run_dir: str) -> str:
    sub = os.path.join(run_dir, "spool")
    return sub if os.path.isdir(sub) else run_dir


def read_header(run_dir: str) -> dict:
    """The spool's ``header.json``; ``run_dir`` may be the run (ckpt)
    directory or the spool directory itself."""
    with open(os.path.join(_spool_dir(run_dir), "header.json")) as f:
        h = json.load(f)
    if h.get("format") != FORMAT:
        raise ValueError(f"{run_dir}: unknown spool format "
                         f"{h.get('format')!r} (expected {FORMAT!r})")
    return h


def shard_events(run_dir: str) -> Dict[str, np.ndarray]:
    """Per-shard raw event arrays (file order preserved)."""
    d = _spool_dir(run_dir)
    out = {}
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".spk"):
            out[fn] = np.fromfile(os.path.join(d, fn), dtype=RECORD_DTYPE)
    return out


def load_events(run_dir: str) -> np.ndarray:
    """All spooled events merged into one global stream, ordered by
    ``(step, gid)`` -- the canonical order for comparing runs (shard
    interleaving is tiling-dependent; the ordered stream is not)."""
    shards = list(shard_events(run_dir).values())
    if not shards:
        raise FileNotFoundError(f"no .spk spike logs under {run_dir}")
    ev = np.concatenate(shards) if len(shards) > 1 else shards[0]
    return ev[np.lexsort((ev["gid"], ev["step"]))]
