"""Host-side runtime telemetry: span tracer + structured event stream.

The paper family's central performance measurement is not total
wall-clock but *where the time goes*: DPSNN's companion scaling study
(arXiv:1511.09325) decomposes time-per-simulated-second into phases
(spike delivery, synaptic/neural dynamics, inter-process exchange) and
shows how the exponential connectivity law shifts cost between them.
This module is the host half of that instrument for the segmented
driver: a low-overhead span tracer every runtime phase reports into --
segment compute, checkpoint snapshot / D2H / file write, spool drain,
restore and retile, straggler stalls -- plus a structured per-segment
metrics stream.  The device half (attributing compiled-step cost to
delivery vs neuron update vs STDP vs recorder compaction) lives in
``benchmarks.fig_phase_breakdown``, which times each sub-function of
the step in isolation and commits the paper-style breakdown as
``BENCH_phase_breakdown.json``.

Design constraints, in order:

  * **pure observer** -- telemetry must never perturb the simulation:
    spans run host-side only (monotonic ``perf_counter`` reads), never
    inside traced closures (enforced statically by repro-lint's
    ``tracer-purity`` pass, which flags a span or host clock inside a
    jit/scan body), and a disabled tracer costs one attribute check per
    instrumentation site.  Spike trains and plastic weight checksums
    are bit-identical with tracing on or off (tested).
  * **thread-aware** -- the async writers (``AsyncCheckpointer``,
    ``SpikeSpooler``) do their D2H transfers and file writes on daemon
    threads; their spans record the emitting thread so checkpoint wall
    time is attributed to the writer, not the segment that overlapped
    it.  Span nesting is tracked per-thread (a thread-local stack).
  * **exactly-once flush** -- ``flush_jsonl`` appends only records not
    yet written (a cursor, not a rewrite), so periodic flushes plus the
    final one never duplicate a span, and a preempted process's file
    picks up cleanly when the resuming process appends to it.

Record types (each one JSON dict in the JSONL stream)::

    {"type": "header",  "format": "dpsnn-telemetry-v1", "pid": ..., ...}
    {"type": "span",    "name": "segment.compute", "t0": s, "dur": s,
                        "thread": ..., "tid": ..., "depth": n,
                        "parent": name-or-null, "attrs": {...}}
    {"type": "event",   "kind": "straggler", "level": "warning",
                        "t": s, "msg": ..., ...fields}
    {"type": "metrics", "kind": "segment", "t": s, ...fields}

Timestamps are seconds relative to the tracer's construction
(``epoch_unix`` in the header anchors them to wall time).  Chrome-trace
export (``chrome://tracing`` / Perfetto) is a view over the same
records: ``repro.perf.trace.write_chrome_trace``.
"""

from __future__ import annotations

import contextlib
import json
import logging
import os
import threading
import time
from typing import Dict, List, Optional

FORMAT = "dpsnn-telemetry-v1"

log = logging.getLogger("repro.telemetry")

_LEVELS = {"debug": logging.DEBUG, "info": logging.INFO,
           "warning": logging.WARNING, "error": logging.ERROR}


class Telemetry:
    """Span tracer + structured event/metrics stream.

    ``enabled=False`` (the drivers' default) makes every method a
    near-no-op -- ``span`` yields immediately, ``event`` only forwards
    to the stdlib logger -- so instrumentation sites are unconditional
    and the uninstrumented hot path stays unchanged.

    All record-appending methods are thread-safe; span *nesting* is
    per-thread (each thread sees its own stack, so a checkpoint
    writer's ``ckpt.write`` span never claims the main thread's
    ``segment`` span as parent).
    """

    def __init__(self, enabled: bool = True,
                 jsonl_path: Optional[str] = None):
        self.enabled = enabled
        self.jsonl_path = jsonl_path
        self.epoch = time.perf_counter()
        self.epoch_unix = time.time()
        self._lock = threading.Lock()
        self._records: List[dict] = []
        self._local = threading.local()
        self._flushed = 0                 # JSONL cursor (exactly-once)
        self._header_written = False

    # ---- clock ---------------------------------------------------------
    def now(self) -> float:
        """Seconds since tracer construction (monotonic)."""
        return time.perf_counter() - self.epoch

    # ---- spans ---------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Time a host-side phase.  Pure observer: the only work inside
        the ``with`` boundary is two monotonic clock reads and (on
        exit) one locked list append.  Never use inside jit/scan
        closures -- the clock would read at trace time, not per step
        (repro-lint's ``tracer-purity`` pass flags it)."""
        if not self.enabled:
            yield self
            return
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        parent = stack[-1] if stack else None
        stack.append(name)
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            dur = time.perf_counter() - t0
            stack.pop()
            th = threading.current_thread()
            rec = {"type": "span", "name": name,
                   "t0": t0 - self.epoch, "dur": dur,
                   "thread": th.name, "tid": th.ident,
                   "depth": len(stack), "parent": parent}
            if attrs:
                rec["attrs"] = attrs
            with self._lock:
                self._records.append(rec)

    # ---- structured events / metrics ----------------------------------
    def event(self, kind: str, msg: Optional[str] = None,
              level: str = "info", logger: Optional[logging.Logger] = None,
              **fields):
        """One structured event: logged through the stdlib logger
        (human-readable, or JSON lines under ``enable_json_logging``)
        AND appended to the telemetry stream when enabled -- the
        drivers' replacement for ad-hoc ``log.warning`` calls, so every
        operational notice (drop warning, straggler, retry, preempt)
        lands in the same machine-readable JSONL as the spans."""
        lg = logger or log
        lg.log(_LEVELS.get(level, logging.INFO), "%s",
               msg if msg is not None else kind,
               extra={"repro_event": {"kind": kind, **fields}})
        if not self.enabled:
            return
        rec = {"type": "event", "kind": kind, "level": level,
               "t": self.now(), **fields}
        if msg is not None:
            rec["msg"] = msg
        with self._lock:
            self._records.append(rec)

    def metrics(self, kind: str, **fields):
        """One structured metrics sample (e.g. the per-segment record:
        spike/event/drop deltas, segment wall, steps/s)."""
        if not self.enabled:
            return
        with self._lock:
            self._records.append(
                {"type": "metrics", "kind": kind, "t": self.now(),
                 **fields})

    # ---- views ---------------------------------------------------------
    def records(self) -> List[dict]:
        with self._lock:
            return list(self._records)

    def spans(self, name: Optional[str] = None) -> List[dict]:
        return [r for r in self.records() if r["type"] == "span"
                and (name is None or r["name"] == name)]

    def events(self, kind: Optional[str] = None) -> List[dict]:
        return [r for r in self.records() if r["type"] == "event"
                and (kind is None or r["kind"] == kind)]

    def _header(self) -> dict:
        return {"type": "header", "format": FORMAT, "pid": os.getpid(),
                "epoch_unix": self.epoch_unix}

    # ---- JSONL flush (exactly-once) ------------------------------------
    def flush_jsonl(self, path: Optional[str] = None) -> int:
        """Append records not yet flushed to ``path`` (default: the
        tracer's ``jsonl_path``); returns the number written.

        Exactly-once by cursor: repeated flushes (periodic + final)
        never rewrite or duplicate a record.  The file is append-only,
        so a resumed process (its own tracer, its own header line)
        extends the preempted process's stream rather than clobbering
        it -- the reader groups by the interleaved header records.
        """
        path = path or self.jsonl_path
        if not self.enabled or path is None:
            return 0
        with self._lock:
            pending = self._records[self._flushed:]
            self._flushed = len(self._records)
            write_header = not self._header_written
            self._header_written = True
        if not pending and not write_header:
            return 0
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "a") as f:
            if write_header:
                f.write(json.dumps(self._header()) + "\n")
            for rec in pending:
                f.write(json.dumps(rec) + "\n")
        return len(pending)


#: Shared disabled tracer: the default for every instrumented component,
#: so call sites never need a None check.
NULL = Telemetry(enabled=False)

_default: Telemetry = NULL


def set_default(tel: Telemetry) -> Telemetry:
    """Install the process-default tracer (used by module-level
    ``span``); returns the previous one."""
    global _default
    prev, _default = _default, tel
    return prev


def get_default() -> Telemetry:
    return _default


def span(name: str, **attrs):
    """Module-level convenience: a span on the process-default tracer.
    Host-side only -- inside a jit/scan closure this is a trace-time
    no-op at best and a purity violation always (lint-flagged)."""
    return _default.span(name, **attrs)


# ---------------------------------------------------------------------------
# Structured (JSON-lines) logging -- the --log-json flag
# ---------------------------------------------------------------------------

class JsonLogFormatter(logging.Formatter):
    """Formats every log record as one JSON object per line, carrying
    the structured ``repro_event`` payload ``Telemetry.event`` attaches
    (plain third-party records format with ``event: null``)."""

    def format(self, record: logging.LogRecord) -> str:
        out = {"ts": round(record.created, 6),
               "level": record.levelname.lower(),
               "logger": record.name,
               "msg": record.getMessage(),
               "event": getattr(record, "repro_event", None)}
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out)


def enable_json_logging(logger_name: str = "repro",
                        stream=None) -> logging.Handler:
    """Route the ``repro.*`` loggers through ``JsonLogFormatter`` (the
    sim CLI's ``--log-json``).  Returns the installed handler (tests
    detach it)."""
    handler = logging.StreamHandler(stream)
    handler.setFormatter(JsonLogFormatter())
    lg = logging.getLogger(logger_name)
    lg.addHandler(handler)
    lg.setLevel(logging.INFO)
    lg.propagate = False
    return handler


def read_jsonl(path: str) -> Dict[str, List[dict]]:
    """Parse a telemetry JSONL stream back into records grouped by
    type: ``{"header": [...], "span": [...], "event": [...],
    "metrics": [...]}``.  Validates the format marker of every header
    line (a resumed run appends one header per process)."""
    out: Dict[str, List[dict]] = {"header": [], "span": [], "event": [],
                                  "metrics": []}
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("type")
            if kind == "header" and rec.get("format") != FORMAT:
                raise ValueError(
                    f"{path}:{i}: unknown telemetry format "
                    f"{rec.get('format')!r} (expected {FORMAT!r})")
            if kind not in out:
                raise ValueError(f"{path}:{i}: unknown record type "
                                 f"{kind!r}")
            out[kind].append(rec)
    if not out["header"]:
        raise ValueError(f"{path}: no telemetry header record")
    return out


def summarize(groups: Dict[str, List[dict]]) -> dict:
    """Aggregate a ``read_jsonl`` grouping into the compact per-run
    digest ``repro.launch.analyze --telemetry`` folds into its report:
    per-span wall totals (where the host time went), event counts by
    kind, and segment throughput with the per-segment delta sums.

    ``total_s`` double-counts nested spans by design (``segment``
    contains ``segment.compute``) -- it answers "how long was this
    phase open", not "exclusive self time"; read the hierarchy from
    the Chrome trace when exclusivity matters.
    """
    spans: Dict[str, dict] = {}
    for s in groups["span"]:
        d = spans.setdefault(s["name"], {"count": 0, "total_s": 0.0,
                                         "max_s": 0.0})
        d["count"] += 1
        d["total_s"] += s["dur"]
        d["max_s"] = max(d["max_s"], s["dur"])
    for d in spans.values():
        d["mean_s"] = d["total_s"] / d["count"]
    events: Dict[str, int] = {}
    for e in groups["event"]:
        events[e["kind"]] = events.get(e["kind"], 0) + 1
    out = {"processes": len(groups["header"]), "spans": spans,
           "events": events}
    segs = [m for m in groups["metrics"] if m.get("kind") == "segment"]
    if segs:
        sps = [m["steps_per_s"] for m in segs]
        out["segments"] = {
            "n": len(segs),
            "wall_s": sum(m["wall_s"] for m in segs),
            "steps_per_s_mean": sum(sps) / len(sps),
            "steps_per_s_min": min(sps),
            **{k: sum(m.get(k, 0) for m in segs)
               for k in ("d_spikes", "d_events", "d_dropped",
                         "d_recorder_dropped")},
        }
    return out
