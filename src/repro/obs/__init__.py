"""Spike observatory: device-side recording, disk spooling, analysis.

Three layers turn the fast kernel path into a scientifically usable
instrument (the paper family validates DPSNN by firing-rate
distributions and slow-wave/awake activity statistics):

  * ``record``  -- device-side recorder: per-step spike compaction
    (Pallas kernel or XLA fallback) into a bounded per-segment
    ``(step, global_neuron_id)`` event buffer carried in the scan state,
    with an explicit overflow-drop counter;
  * ``spool``   -- host-side async spooler: drains each segment's
    buffer into sharded append-only binary spike logs, with per-segment
    offsets recorded in the checkpoint manifest so resume replays
    deliver every event exactly once;
  * ``analysis``-- paper-family statistics from spooled logs (rate
    distributions, ISI CV, population rate, Up/Down segmentation) plus
    multi-run comparison, behind the ``repro.launch.analyze`` CLI.

A fourth layer, ``telemetry``, observes the *runtime* rather than the
spikes: a thread-aware host-side span tracer plus a structured
per-segment metrics stream (JSONL + Chrome-trace export via
``repro.perf.trace``), instrumenting every driver phase -- segment
compute, checkpoint snapshot/D2H/write, spool drain, restore/retile,
straggler stalls.  Like recording, it is a pure observer: spike trains
and plastic weight checksums are bit-identical with tracing on or off.
"""

from .record import (RecorderSpec, init_recorder_state, record_step,
                     recorder_spec, stacked_gid_maps, tile_gid_map)
from .spool import SpikeSpooler, load_events, read_header
from .telemetry import (Telemetry, enable_json_logging, get_default,
                        read_jsonl, set_default, span)

__all__ = [
    "RecorderSpec", "init_recorder_state", "record_step", "recorder_spec",
    "stacked_gid_maps", "tile_gid_map", "SpikeSpooler", "load_events",
    "read_header", "Telemetry", "enable_json_logging", "get_default",
    "read_jsonl", "set_default", "span",
]
