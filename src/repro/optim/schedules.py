"""Learning-rate schedules (step -> lr, traceable)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def warmup_rsqrt(peak: float, warmup: int):
    def lr(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(s / max(warmup, 1),
                                  jnp.sqrt(warmup / s))
    return lr
