"""AdamW and Adafactor, params-as-pytrees, sharding-transparent.

Optimizer state mirrors the param tree, so the same logical-axis specs
shard it (FSDP shards moments exactly like params).  Adafactor keeps
factored second moments for >=2D params -- mandatory for the 1T kimi-k2
config (full Adam moments would be 2 x 2 TB).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    # expressed as a dot so the f32 accumulation happens inside the
    # contraction -- a dtype=f32 reduce would materialize a whole-leaf
    # f32 convert (5 GB per expert stack at the 1T scale)
    def sq(x):
        # full contraction without reshape: keeps the leaf's sharding
        # (each shard reduces locally, then a scalar psum)
        dims = tuple(range(x.ndim))
        return jax.lax.dot_general(
            x, x, ((dims, dims), ((), ())),
            preferred_element_type=jnp.float32)
    leaves = [sq(x) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _is_spec(t):
    return isinstance(t, tuple)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable          # params -> opt_state
    _update: Callable       # (params, grads, state) -> (params, state, gnorm)
    _state_specs: Callable  # param logical-spec tree -> state spec tree

    def update(self, params, grads, state):
        return self._update(params, grads, state)

    def abstract_state(self, abstract_params):
        return jax.eval_shape(self.init, abstract_params)

    def state_specs(self, param_specs):
        """Logical axis names for the state tree (FSDP shards moments
        exactly like the params they mirror)."""
        return self._state_specs(param_specs)


def _clipped(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    # keep the gradient dtype: a tree-wide f32 upcast would transiently
    # double the biggest arrays (fatal at the 1T-param scale)
    return jax.tree.map(lambda x: (x * scale.astype(x.dtype)), grads), g


def _mean_sq(x, axis: int) -> jnp.ndarray:
    """mean(x^2) over one axis with f32 accumulation, WITHOUT an f32
    materialization of x (expressed as a contraction)."""
    dims = ((axis % x.ndim,), (axis % x.ndim,))
    batch = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    out = jax.lax.dot_general(x, x, (dims, (batch, batch)),
                              preferred_element_type=jnp.float32)
    return out / x.shape[axis]


def adamw(lr: Callable, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: float = 1.0, moment_dtype="float32") -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        grads, gnorm = _clipped(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * upd).astype(p.dtype),
                    m.astype(mdt), v.astype(mdt))

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs, "step": ()}

    return Optimizer(init=init, _update=update, _state_specs=state_specs)


def adafactor(lr: Callable, *, decay=0.8, eps=1e-30, clip_norm: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    """Factored second moments over the last two dims of >=2D params."""

    def init(params):
        def moments(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(moments, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        grads, gnorm = _clipped(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, v):
            # bf16-native: every full-size intermediate keeps the param
            # dtype; f32 exists only on the small factored stats.  The
            # factored denominator is exactly separable:
            #   1/denom = rsqrt(row_hat) (x) rsqrt(col_hat / mean_row)
            if p.ndim >= 2:
                row = beta * v["row"] + (1 - beta) * (_mean_sq(g, -1) + eps)
                col = beta * v["col"] + (1 - beta) * (_mean_sq(g, -2) + eps)
                rinv = jax.lax.rsqrt(row + eps)
                cinv = jax.lax.rsqrt(
                    col / (jnp.mean(row, axis=-1, keepdims=True) + eps)
                    + eps)
                # cast the small factors BEFORE the outer product: the
                # f32 (L,E,d,f) product would materialize whole-stack
                u = (g * rinv.astype(g.dtype)[..., None]) \
                    * cinv.astype(g.dtype)[..., None, :]
                nv = {"row": row, "col": col}
            else:
                full = beta * v["full"] + (1 - beta) * (
                    jnp.square(g.astype(jnp.float32)) + eps)
                u = (g.astype(jnp.float32)
                     * jax.lax.rsqrt(full + eps)).astype(g.dtype)
                nv = {"full": full}
            # relative-scale update clipping (adafactor's d=1.0 rule);
            # rms via contraction (f32 accumulate, no f32 upcast)
            dims = tuple(range(u.ndim))
            sq = jax.lax.dot_general(u, u, ((dims, dims), ((), ())),
                                     preferred_element_type=jnp.float32)
            rms = jnp.sqrt(sq / float(u.size) + 1e-12)
            u = u * (lr_t / jnp.maximum(1.0, rms)).astype(u.dtype)
            decay = (1.0 - lr_t * weight_decay).astype(p.dtype)
            return (p * decay - u.astype(p.dtype), nv)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[1] for o in out])
        return new_p, {"v": new_v, "step": step}, gnorm

    def state_specs(param_specs):
        def moments(names):
            if len(names) >= 2:
                return {"row": names[:-1], "col": names[:-2] + names[-1:]}
            return {"full": names}
        return {"v": jax.tree.map(moments, param_specs, is_leaf=_is_spec),
                "step": ()}

    return Optimizer(init=init, _update=update, _state_specs=state_specs)
