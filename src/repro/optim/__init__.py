"""Optimizers, schedules and gradient compression (no external deps)."""

from .optimizers import Optimizer, adamw, adafactor
from .schedules import warmup_cosine, warmup_rsqrt, constant
from .compression import (int8_quantize, int8_dequantize,
                          CompressedAllReduce)
