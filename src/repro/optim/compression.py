"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the cross-pod gradient reduction: the
intra-pod reduction stays full precision (fast ICI), but the *data-center
network* hop between pods carries int8 blocks (4x fewer bytes than f32,
2x fewer than bf16).  Error feedback accumulates the quantization
residual locally and re-injects it next step, which keeps SGD-style
convergence (Karimireddy et al. 2019).

``CompressedAllReduce`` is the shard_map-level primitive: quantize ->
psum over the pod axis -> dequantize, with the residual carried by the
caller.  Block-wise scales (one f32 per 256 values) bound the error.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = -flat.shape[0] % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def int8_quantize(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (int8 blocks (N, BLOCK), f32 scales (N,))."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None])
    return q.astype(jnp.int8), scale


def int8_dequantize(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


@dataclasses.dataclass(frozen=True)
class CompressedAllReduce:
    """psum over ``axis`` with int8 payload + error feedback.

    Use inside shard_map:  (g_avg, new_residual) = car(g, residual).
    """

    axis: str = "pod"

    def __call__(self, g, residual):
        shape = g.shape
        with_err = g.astype(jnp.float32) + residual
        q, scale = int8_quantize(with_err)
        sent = int8_dequantize(q, scale, shape)
        new_residual = with_err - sent        # error feedback
        # int8 ints summed in int32 to avoid overflow; scales are
        # per-sender so the sum of dequantized blocks is exact psum of
        # the quantized payloads.
        total = jax.lax.psum(sent, self.axis)
        n = jax.lax.psum(jnp.ones(()), self.axis)
        return total / n, new_residual

    def wire_bytes(self, n_elems: int) -> int:
        blocks = -(-n_elems // BLOCK)
        return n_elems + 4 * blocks          # int8 payload + f32 scales


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
