"""Dtype/overflow contract pass (``dtype-bounds``).

The compressed synapse tables trade bytes for invariants: int16 in-tile
target ids are only sound while ``n_local < 2**15``, bfloat16 weights
are only value-exact because every *accumulation* happens in float32,
and ``core/``/``kernels/`` stay float32-first so a stray float64
promotion can't silently double the memory envelope (or diverge from
the TPU path, which has no f64).  Three sub-checks:

1. **int16 bound, cross-checked against committed configs**: for every
   grid x law case in ``repro.configs.snn`` (paper Table 1 grids plus
   the reduced test case) over a sweep of committed tilings, if the
   derived ``TableStorage`` selects int16 target ids then the tile's
   ``n_local`` must fit; runs the *real* constructors at lint time so
   the check can never drift from the code (skipped, not failed, if
   the repo isn't importable).  A ``TableStorage(tgt_dtype="int16")``
   literal outside ``core/synapses.py`` is flagged statically: storage
   must come from ``spec.storage()``/``from_meta`` so the bound is
   derived, never asserted by hand.
2. **No accumulation in a storage dtype**: reductions / contractions
   (``jnp.sum``, ``dot``, ``matmul``, ``einsum``, ``cumsum``,
   ``dot_general``, ``.at[].add``) whose operand is visibly cast to a
   16-bit dtype in the same expression.
3. **No float64 in ``core/``/``kernels/``**: any ``float64`` mention
   (attribute, string dtype, ``astype(float)``); host-side analytic
   code that *needs* f64 precision carries an explicit pragma.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .core import Checker, Finding, Module, Project

NAME = "dtype-bounds"

_ACCUM_CALLS = ("jax.numpy.sum", "jax.numpy.dot", "jax.numpy.matmul",
                "jax.numpy.einsum", "jax.numpy.cumsum", "jax.numpy.prod",
                "jax.numpy.mean", "jax.lax.dot_general", "jax.lax.dot")
_STORAGE_DTYPES = {"bfloat16", "float16", "int16", "int8", "uint8"}
_F32_FIRST_DIRS = ("/core/", "/kernels/")
_TILINGS = ((1, 1), (1, 2), (2, 2), (4, 4), (8, 8))


def _is_f32_first(mod: Module) -> bool:
    p = mod.path.replace("\\", "/")
    return "src/repro" in p and any(d in p for d in _F32_FIRST_DIRS)


def _casts_to_storage_dtype(expr: ast.expr, mod: Module) -> bool:
    """True if the expression visibly casts to a 16/8-bit dtype."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "astype" and node.args:
            a = node.args[0]
            if isinstance(a, ast.Constant) and a.value in _STORAGE_DTYPES:
                return True
            dn = mod.resolve_dotted(a)
            if dn and dn.split(".")[-1] in _STORAGE_DTYPES:
                return True
    return False


class DtypeContractsChecker(Checker):
    name = NAME
    description = ("int16 target-id bound vs committed configs, no "
                   "accumulation in storage dtypes, no float64 in "
                   "core//kernels/")

    def run(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            if _is_f32_first(mod):
                yield from self._no_float64(mod)
            yield from self._no_storage_accum(mod)
            yield from self._no_handmade_int16(mod)
        yield from self._int16_bound_vs_configs(project)

    # ---- float64 ------------------------------------------------------
    def _no_float64(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and node.attr == "float64":
                yield Finding(
                    mod.path, node.lineno, self.name,
                    "float64 in core//kernels/: f32-first contract "
                    "(TPU has no f64; doubles the memory envelope)")
            elif isinstance(node, ast.Constant) and node.value == "float64":
                yield Finding(
                    mod.path, node.lineno, self.name,
                    '"float64" dtype string in core//kernels/: '
                    "f32-first contract")
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "astype" and node.args and \
                    isinstance(node.args[0], ast.Name) and \
                    node.args[0].id == "float":
                yield Finding(
                    mod.path, node.lineno, self.name,
                    "astype(float) promotes to float64 on host numpy")

    # ---- accumulation in storage dtype --------------------------------
    def _no_storage_accum(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = mod.resolve_dotted(node.func)
            is_accum = dn in _ACCUM_CALLS
            # x.at[idx].add(v) scatter-accumulation
            if not is_accum and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("add", "sum") \
                    and isinstance(node.func.value, ast.Subscript):
                sub = node.func.value.value
                is_accum = isinstance(sub, ast.Attribute) \
                    and sub.attr == "at"
            if not is_accum:
                continue
            args = list(node.args) + [kw.value for kw in node.keywords]
            for a in args:
                if _casts_to_storage_dtype(a, mod):
                    yield Finding(
                        mod.path, node.lineno, self.name,
                        "accumulation over a value cast to a storage "
                        "dtype: cast to float32 *after* the reduction "
                        "(bf16 partial sums are not value-exact)")
                    break

    # ---- hand-built int16 storage -------------------------------------
    def _no_handmade_int16(self, mod: Module) -> Iterable[Finding]:
        if mod.path.replace("\\", "/").endswith("core/synapses.py"):
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = mod.resolve_dotted(node.func)
            if not dn or dn.split(".")[-1] != "TableStorage":
                continue
            for kw in node.keywords:
                if kw.arg == "tgt_dtype" and \
                        isinstance(kw.value, ast.Constant) and \
                        kw.value.value == "int16":
                    yield Finding(
                        mod.path, node.lineno, self.name,
                        "hand-built TableStorage(tgt_dtype='int16'): "
                        "the n_local < 2**15 bound is only checked by "
                        "spec.storage()/TableStorage.from_meta -- "
                        "derive storage, don't assert it")

    # ---- the live bound vs every committed config ---------------------
    def _int16_bound_vs_configs(self, project: Project) \
            -> Iterable[Finding]:
        try:
            from repro.configs import snn as snn_configs
        except ImportError:
            return                       # lint run outside the repo env
        cfg_mod = next(
            (m for m in project.modules
             if m.path.replace("\\", "/").endswith("configs/snn.py")),
            None)
        if cfg_mod is None:
            return                       # configs not in the lint scope
        cases = dict(snn_configs.CASES)
        cases["reduced"] = snn_configs.reduced_case()
        for cname, case in sorted(cases.items()):
            for ty, tx in _TILINGS:
                if case.grid[0] % ty or case.grid[1] % tx:
                    continue
                try:
                    spec = case.engine_config(ty, tx).spec()
                    storage = spec.storage()
                except Exception as e:  # noqa: BLE001 - report, don't crash
                    yield Finding(
                        cfg_mod.path, 1, self.name,
                        f"config {cname} @ {ty}x{tx} failed to "
                        f"construct during bound check: {e!r}")
                    continue
                if storage.tgt_dtype == "int16" \
                        and spec.n_local >= 2 ** 15:
                    yield Finding(
                        cfg_mod.path, 1, self.name,
                        f"config {cname} @ {ty}x{tx}: int16 target ids "
                        f"but n_local={spec.n_local} >= 2**15 -- "
                        "in-tile ids overflow")
