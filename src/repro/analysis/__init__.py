"""repro-lint: repo-specific contract analysis.

Each checker pass encodes an invariant the codebase has been burned by
(see the module docstrings); the CLI lives at ``repro.launch.lint``.
"""

from .core import Checker, Finding, Module, Project
from .deprecated_api import DeprecatedApiChecker
from .donation import DonationChecker
from .dtype_contracts import DtypeContractsChecker
from .meta_drift import MetaDriftChecker
from .pallas_geometry import PallasGeometryChecker
from .pytree_aux import PytreeAuxChecker
from .tracer_purity import TracerPurityChecker

ALL_CHECKERS = (
    TracerPurityChecker,
    DeprecatedApiChecker,
    DtypeContractsChecker,
    DonationChecker,
    MetaDriftChecker,
    PytreeAuxChecker,
    PallasGeometryChecker,
)

__all__ = [
    "ALL_CHECKERS", "Checker", "Finding", "Module", "Project",
    "TracerPurityChecker", "DeprecatedApiChecker",
    "DtypeContractsChecker", "DonationChecker",
    "MetaDriftChecker", "PytreeAuxChecker", "PallasGeometryChecker",
]
