"""Pallas geometry pass (``pallas-geometry``).

The delivery pipeline's kernels are only correct *and* only fit in
VMEM under specific alignment facts: the entry stream is lane-packed
as ``(E/128, 128)`` so ``LANES`` is pinned to the TPU lane width,
block minor dims must be multiples of 128 (or 1) and second-minor
multiples of 8 (or 1) to match Mosaic's (8, 128) f32 tiling, and the
two-level one-hot MXU factors scale with ``d_ring * TILE_N`` --
``{ENTRY_BLOCK: 64, TILE_N: 4096}``-style constants would compile to
an ~18 MiB block and fail on real hardware.  Checks, per module under
``kernels/``:

* ``LANES == 128``; ``TILE_N`` / ``OUT_TILE`` / ``CHUNK`` divisible by
  ``LANES``; ``ENTRY_BLOCK == ENTRY_SUBLANES * LANES``;
* every ``pl.BlockSpec((a, b), ...)`` with statically-foldable dims:
  ``b % 128 == 0`` (or ``b == 1``) and ``a % 8 == 0`` (or ``a == 1``);
* the one-hot factor footprint at the engine's default ``d_ring``
  (read from ``EngineConfig``) stays under the ~16 MiB VMEM budget --
  both the ring-tiled delivery layout (``ENTRY_BLOCK`` x ``TILE_N``)
  and the fused plastic step's resident-ring layout (``CHUNK`` x
  ``RING_N_MAX``, the whole ring live across grid steps).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional

from .core import (Checker, Finding, Module, Project, eval_const,
                   module_int_constants)

NAME = "pallas-geometry"

VMEM_BUDGET_BYTES = 16 * 1024 * 1024
_LANE = 128
_SUBLANE = 8


def _engine_d_ring_default(project: Project) -> int:
    for mod in project.modules:
        if not mod.path.replace("\\", "/").endswith("core/engine.py"):
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) \
                    and node.name == "EngineConfig":
                for s in node.body:
                    if isinstance(s, ast.AnnAssign) \
                            and isinstance(s.target, ast.Name) \
                            and s.target.id == "d_ring" \
                            and isinstance(s.value, ast.Constant) \
                            and isinstance(s.value.value, int):
                        return s.value.value
    return 8


class PallasGeometryChecker(Checker):
    name = NAME
    description = ("lane/sublane alignment of kernel constants and "
                   "BlockSpecs, one-hot factor footprint vs the VMEM "
                   "budget")

    def run(self, project: Project) -> Iterable[Finding]:
        d_ring = _engine_d_ring_default(project)
        for mod in project.modules:
            p = mod.path.replace("\\", "/")
            if "/kernels/" not in p and not p.startswith("kernels/"):
                continue
            env = module_int_constants(mod)
            yield from self._constants(mod, env)
            yield from self._blockspecs(mod, env)
            yield from self._vmem_budget(mod, env, d_ring)

    # ---- named constants ----------------------------------------------
    def _constants(self, mod: Module, env: Dict[str, int]) \
            -> Iterable[Finding]:
        def line_of(name: str) -> int:
            for node in mod.tree.body:
                if isinstance(node, ast.Assign) \
                        and isinstance(node.targets[0], ast.Name) \
                        and node.targets[0].id == name:
                    return node.lineno
            return 1

        lanes = env.get("LANES")
        if lanes is not None and lanes != _LANE:
            yield Finding(
                mod.path, line_of("LANES"), self.name,
                f"LANES = {lanes}: the entry stream is lane-packed as "
                "(E/128, 128); LANES is the TPU lane width, not tunable")
        lanes = lanes or _LANE
        for cname in ("TILE_N", "OUT_TILE", "CHUNK"):
            v = env.get(cname)
            if v is not None and v % lanes:
                yield Finding(
                    mod.path, line_of(cname), self.name,
                    f"{cname} = {v} is not a multiple of LANES "
                    f"({lanes}): lane-packed blocks would straddle "
                    "tiles")
        eb, es = env.get("ENTRY_BLOCK"), env.get("ENTRY_SUBLANES")
        if eb is not None and es is not None and eb != es * lanes:
            yield Finding(
                mod.path, line_of("ENTRY_BLOCK"), self.name,
                f"ENTRY_BLOCK = {eb} != ENTRY_SUBLANES * LANES "
                f"({es} * {lanes}): the (sublanes, lanes) entry block "
                "reshape breaks")

    # ---- BlockSpec literal shapes -------------------------------------
    def _blockspecs(self, mod: Module, env: Dict[str, int]) \
            -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = mod.resolve_dotted(node.func)
            if not dn or dn.split(".")[-1] != "BlockSpec":
                continue
            shape = node.args[0] if node.args else None
            if not isinstance(shape, ast.Tuple) or len(shape.elts) < 2:
                continue
            dims = [eval_const(e, env) for e in shape.elts]
            minor, second = dims[-1], dims[-2]
            if minor is not None and minor != 1 and minor % _LANE:
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f"BlockSpec minor dim {minor} is not a multiple of "
                    f"{_LANE}: Mosaic pads to the (8, 128) register "
                    "tile -- wasted VMEM and relayouts")
            if second is not None and second != 1 and second % _SUBLANE:
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f"BlockSpec second-minor dim {second} is not a "
                    f"multiple of {_SUBLANE} (f32 sublane tile)")

    # ---- one-hot factor VMEM footprint --------------------------------
    def _vmem_budget(self, mod: Module, env: Dict[str, int],
                     d_ring: int) -> Iterable[Finding]:
        lanes = env.get("LANES", _LANE)
        if not lanes:
            return
        f32 = 4
        eb, tile_n = env.get("ENTRY_BLOCK"), env.get("TILE_N")
        if eb is not None and tile_n is not None:
            # ring-tiled delivery kernel: the block streams ENTRY_BLOCK
            # entries against a (d_ring, TILE_N) ring tile
            row_onehot = eb * (d_ring * tile_n // lanes) * f32
            lane_onehot = eb * lanes * f32
            ring_tiles = 2 * d_ring * tile_n * f32
            entry_blocks = 3 * eb * f32
            total = row_onehot + lane_onehot + ring_tiles + entry_blocks
            if total > VMEM_BUDGET_BYTES:
                yield Finding(
                    mod.path, 1, self.name,
                    f"one-hot MXU factors at ENTRY_BLOCK={eb}, "
                    f"TILE_N={tile_n}, d_ring={d_ring} need "
                    f"~{total / 2**20:.1f} MiB of VMEM "
                    f"(budget {VMEM_BUDGET_BYTES / 2**20:.0f} MiB): "
                    "shrink ENTRY_BLOCK or TILE_N")
        rnm, chunk = env.get("RING_N_MAX"), env.get("CHUNK")
        if rnm is not None and chunk is not None:
            # resident-ring fused plastic kernel: the whole
            # (d_ring, RING_N_MAX) ring (in + accumulator) stays in
            # VMEM across grid steps, and each liveness-gated CHUNK
            # contracts a (CHUNK, d_ring * RING_N_MAX / LANES) one-hot
            # row factor against the lane-packed weights; 5 entry
            # streams (tgt/w/d/mask in, depressed w out) ride along
            row_onehot = chunk * (d_ring * rnm // lanes) * f32
            lane_onehot = chunk * lanes * f32
            rings = 2 * d_ring * rnm * f32
            xpost = rnm * f32
            streams = 5 * (eb or chunk) * f32
            total = row_onehot + lane_onehot + rings + xpost + streams
            if total > VMEM_BUDGET_BYTES:
                yield Finding(
                    mod.path, 1, self.name,
                    f"resident-ring plastic kernel at CHUNK={chunk}, "
                    f"RING_N_MAX={rnm}, d_ring={d_ring} needs "
                    f"~{total / 2**20:.1f} MiB of VMEM "
                    f"(budget {VMEM_BUDGET_BYTES / 2**20:.0f} MiB): "
                    "shrink RING_N_MAX (larger shards take the "
                    "two-pass fallback) or CHUNK")
