"""Shared infrastructure for the repro-lint contract analyzer.

The analyzer (``python -m repro.launch.lint``) is *repo-aware*: each
checker pass encodes an invariant this codebase has already been burned
by (donated-buffer reuse, meta fields missing from drift refusal,
unseeded RNG inside traced code, ...).  This module holds what every
pass shares:

  * ``Module`` -- one parsed source file: AST, import-alias resolution,
    per-line / per-file suppression pragmas;
  * ``Project`` -- a set of modules plus the *call graph* and the
    **traced set**: every function reachable from a ``jax.jit`` /
    ``lax.scan`` / ``shard_map`` / ``pallas_call`` body.  Purity
    checks only apply inside the traced set -- host-side timing or
    seeded numpy RNG is fine, the same call inside a scan body is not;
  * a tiny constant-expression evaluator (kernel geometry constants);
  * the ``Finding`` record and the ``Checker`` base class.

Suppression: a violating line may carry an inline pragma with a reason::

    fan = np.zeros(shape, dtype=np.float64)  # repro-lint: ignore[dtype-bounds] host-side analytic precision

(the pragma may also sit on a comment line directly above the
violation), and a whole file opts out of one check with a comment
line::

    # repro-lint: ignore-file[tracer-purity] reason...

Pragmas without a named check are invalid (a bare "ignore everything"
escape hatch would defeat the point of per-invariant passes).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(ignore(?:-file)?)\[([a-zA-Z0-9_,\- ]+)\]")

# Callables whose function-valued arguments become traced code.  Matched
# on the final dotted segment(s) of the resolved callee name.
TRACE_INDUCERS = (
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.fori_loop",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.switch",
    "jax.lax.map", "jax.eval_shape",
)
# suffix-matched (local shims, jax.experimental paths)
TRACE_INDUCER_SUFFIXES = (".shard_map", ".pallas_call", ".scan",
                          ".fori_loop", ".while_loop", ".cond", ".switch")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One contract violation, anchored to a source line."""
    path: str
    line: int
    check: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.check}] {self.message}"


class Checker:
    """One invariant pass.  ``name`` is the pragma/selection key."""

    name: str = ""
    description: str = ""

    def run(self, project: "Project") -> Iterable[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Modules
# ---------------------------------------------------------------------------

def module_name_for(path: str) -> str:
    """Dotted module name: paths under a ``src/`` root import as
    ``repro.x.y``; everything else (tests, benchmarks, examples) gets a
    path-derived name that is unique but never importable-colliding."""
    norm = path.replace(os.sep, "/")
    for marker in ("/src/", "src/"):
        if marker in norm or norm.startswith("src/"):
            idx = norm.rfind("/src/")
            tail = norm[idx + 5:] if idx >= 0 else norm[len("src/"):]
            return tail[:-3].replace("/", ".") if tail.endswith(".py") \
                else tail.replace("/", ".")
    return norm[:-3].replace("/", ".") if norm.endswith(".py") \
        else norm.replace("/", ".")


class Module:
    """One parsed file: AST + import aliases + suppression pragmas."""

    def __init__(self, path: str, source: Optional[str] = None,
                 modname: Optional[str] = None):
        if source is None:
            with open(path) as f:
                source = f.read()
        self.path = path
        self.source = source
        self.modname = modname or module_name_for(path)
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        self.line_pragmas: Dict[int, Set[str]] = {}
        self.file_pragmas: Set[str] = set()
        for i, line in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(line)
            if not m:
                continue
            checks = {c.strip() for c in m.group(2).split(",") if c.strip()}
            if m.group(1) == "ignore-file":
                self.file_pragmas |= checks
                continue
            self.line_pragmas.setdefault(i, set()).update(checks)
            if line.lstrip().startswith("#"):
                # a pragma on a comment-only line covers the remainder
                # of its comment block and the first code line after it
                j = i + 1
                while j <= len(self.lines) \
                        and self.lines[j - 1].lstrip().startswith("#"):
                    self.line_pragmas.setdefault(j, set()).update(checks)
                    j += 1
                self.line_pragmas.setdefault(j, set()).update(checks)
        self.aliases = self._collect_aliases()

    # ---- imports -------------------------------------------------------
    def _collect_aliases(self) -> Dict[str, str]:
        """name -> fully dotted origin (``jnp`` -> ``jax.numpy``,
        ``compress_tables`` -> ``repro.core.synapses.compress_tables``)."""
        out: Dict[str, str] = {}
        pkg_parts = self.modname.split(".")[:-1]
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                if node.level:                       # relative import
                    base_parts = pkg_parts[:len(pkg_parts)
                                           - (node.level - 1)]
                    base = ".".join(base_parts + (
                        [node.module] if node.module else []))
                else:
                    base = node.module or ""
                for a in node.names:
                    if a.name == "*":
                        continue
                    out[a.asname or a.name] = (
                        f"{base}.{a.name}" if base else a.name)
        return out

    def resolve_dotted(self, expr: ast.expr) -> Optional[str]:
        """Dotted name of an expression with its root import-alias
        expanded; ``None`` for non-name expressions.  ``self.x`` keeps
        the literal ``self`` root (callers resolve via class scope)."""
        parts: List[str] = []
        node = expr
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        parts.append(node.id)
        parts.reverse()
        root = parts[0]
        if root in self.aliases:
            parts[0] = self.aliases[root]
        return ".".join(parts)

    def suppressed(self, check: str, line: int) -> bool:
        if check in self.file_pragmas:
            return True
        return check in self.line_pragmas.get(line, ())


# ---------------------------------------------------------------------------
# Functions, call graph, traced set
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FnInfo:
    """One function (or method, or nested function) in the project."""
    module: Module
    qual: str                         # "Class.method" / "outer.inner"
    node: ast.AST                     # FunctionDef | AsyncFunctionDef | Lambda
    parent: Optional["FnInfo"]

    @property
    def key(self) -> Tuple[str, str]:
        return (self.module.modname, self.qual)

    @property
    def line(self) -> int:
        return self.node.lineno

    def __hash__(self):
        return hash(self.key)

    def __eq__(self, other):
        return isinstance(other, FnInfo) and self.key == other.key


class _Scope:
    def __init__(self, parent: Optional["_Scope"]):
        self.parent = parent
        self.names: Dict[str, FnInfo] = {}

    def lookup(self, name: str) -> Optional[FnInfo]:
        s: Optional[_Scope] = self
        while s is not None:
            if name in s.names:
                return s.names[name]
            s = s.parent
        return None


@dataclasses.dataclass
class CallSite:
    call: ast.Call
    callee: Optional[str]             # resolved dotted name (or None)
    enclosing: Optional[FnInfo]       # None at module level


class Project:
    """A set of modules plus the shared call-graph / traced-set core."""

    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)
        self.functions: Dict[Tuple[str, str], FnInfo] = {}
        self.calls: List[CallSite] = []
        self._index()
        self.traced: Set[FnInfo] = self._traced_closure()

    @classmethod
    def from_paths(cls, paths: Sequence[str]) -> "Project":
        files: List[str] = []
        for p in paths:
            if os.path.isfile(p):
                files.append(p)
                continue
            for root, dirs, names in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(root, n)
                             for n in names if n.endswith(".py"))
        return cls([Module(f) for f in sorted(set(files))])

    # ---- indexing ------------------------------------------------------
    def _index(self):
        for mod in self.modules:
            self._index_module(mod)

    def _index_module(self, mod: Module):
        project = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.scope = _Scope(None)
                self.fn_stack: List[Optional[FnInfo]] = [None]
                self.class_stack: List[str] = []

            def _qual(self, name: str) -> str:
                parts = self.class_stack + [name]
                enc = self.fn_stack[-1]
                if enc is not None and not self.class_stack:
                    return f"{enc.qual}.{name}"
                return ".".join(parts)

            def visit_ClassDef(self, node: ast.ClassDef):
                self.class_stack.append(node.name)
                self.generic_visit(node)
                self.class_stack.pop()

            def _visit_fn(self, node):
                info = FnInfo(mod, self._qual(node.name), node,
                              self.fn_stack[-1])
                project.functions[info.key] = info
                self.scope.names[node.name] = info
                saved_classes = self.class_stack
                self.class_stack = []
                self.scope = _Scope(self.scope)
                self.fn_stack.append(info)
                self.generic_visit(node)
                self.fn_stack.pop()
                self.scope = self.scope.parent
                self.class_stack = saved_classes

            visit_FunctionDef = _visit_fn
            visit_AsyncFunctionDef = _visit_fn

            def visit_Call(self, node: ast.Call):
                project.calls.append(CallSite(
                    call=node, callee=mod.resolve_dotted(node.func),
                    enclosing=self.fn_stack[-1]))
                self.generic_visit(node)

        v = V()
        # attach the scope resolver for later passes
        v.visit(mod.tree)
        mod._scope = v.scope             # module-level name -> FnInfo

    # ---- traced-set computation ---------------------------------------
    @staticmethod
    def _is_trace_inducer(callee: Optional[str]) -> bool:
        if not callee:
            return False
        if callee in TRACE_INDUCERS:
            return True
        return any(callee.endswith(s) for s in TRACE_INDUCER_SUFFIXES)

    def _fn_args_of(self, site: CallSite) -> List[FnInfo]:
        """Function-valued arguments of a call, resolved lexically."""
        out: List[FnInfo] = []
        args = list(site.call.args) + [kw.value for kw in site.call.keywords]
        for a in args:
            # functools.partial(kernel, ...) wrapping
            if isinstance(a, ast.Call):
                callee = site.call and a.func
                dn = site and self._dotted(site, callee)
                if dn and dn.endswith("partial") and a.args:
                    a = a.args[0]
            fn = self._resolve_fn_ref(site, a)
            if fn is not None:
                out.append(fn)
        return out

    def _dotted(self, site: CallSite, expr) -> Optional[str]:
        mod = (site.enclosing.module if site.enclosing
               else self._module_of_call(site))
        return mod.resolve_dotted(expr) if mod else None

    def _module_of_call(self, site: CallSite) -> Optional[Module]:
        for m in self.modules:
            if site.call in ast.walk(m.tree):
                return m
        return None

    def _resolve_fn_ref(self, site: CallSite,
                        expr: ast.expr) -> Optional[FnInfo]:
        if not isinstance(expr, ast.Name):
            return None
        # walk up the enclosing functions' lexical scopes
        enc = site.enclosing
        mod = enc.module if enc else None
        if mod is None:
            for m in self.modules:
                if hasattr(m, "_scope") and m._scope.lookup(expr.id):
                    return m._scope.lookup(expr.id)
            return None
        # nested function names live in the module's scope tree; search
        # all functions of this module whose simple name matches and
        # whose parent chain includes the enclosing function
        candidates = [f for f in self.functions.values()
                      if f.module is mod
                      and f.qual.split(".")[-1] == expr.id]
        for c in candidates:
            p = c.parent
            while p is not None:
                if p == enc:
                    return c
                p = p.parent
        # fall back: module-level def, or imported repo function
        top = mod._scope.lookup(expr.id) if hasattr(mod, "_scope") else None
        if top is not None:
            return top
        dn = mod.aliases.get(expr.id)
        if dn:
            return self.lookup_dotted(dn)
        return None

    def lookup_dotted(self, dotted: str) -> Optional[FnInfo]:
        """Find a repo function by fully-qualified dotted name."""
        parts = dotted.split(".")
        for cut in range(len(parts) - 1, 0, -1):
            key = (".".join(parts[:cut]), ".".join(parts[cut:]))
            if key in self.functions:
                return self.functions[key]
        return None

    def _callees_of(self, fn: FnInfo) -> Set[FnInfo]:
        out: Set[FnInfo] = set()
        for site in self.calls:
            if site.enclosing != fn or not site.callee:
                continue
            callee = site.callee
            if callee.startswith("self."):
                # method call on the own class
                cls = fn.qual.split(".")[0]
                target = self.functions.get(
                    (fn.module.modname, f"{cls}.{callee[5:]}"))
            else:
                target = self.lookup_dotted(callee)
                if target is None and "." not in callee:
                    target = self.functions.get((fn.module.modname, callee))
            if target is not None:
                out.add(target)
        return out

    def _traced_closure(self) -> Set[FnInfo]:
        entries: Set[FnInfo] = set()
        for site in self.calls:
            if self._is_trace_inducer(site.callee):
                entries.update(self._fn_args_of(site))
        # decorator-induced tracing: @jax.jit / @partial(jax.jit, ...)
        for fn in self.functions.values():
            node = fn.node
            for dec in getattr(node, "decorator_list", []):
                target = dec.func if isinstance(dec, ast.Call) else dec
                dn = fn.module.resolve_dotted(target)
                if dn and dn.endswith("partial") and isinstance(dec, ast.Call) \
                        and dec.args:
                    dn = fn.module.resolve_dotted(dec.args[0])
                if self._is_trace_inducer(dn):
                    entries.add(fn)
        seen = set(entries)
        frontier = list(entries)
        while frontier:
            fn = frontier.pop()
            for callee in self._callees_of(fn):
                if callee not in seen:
                    seen.add(callee)
                    frontier.append(callee)
        return seen

    # ---- running checkers ---------------------------------------------
    def run(self, checkers: Sequence[Checker]) -> List[Finding]:
        by_path = {m.path: m for m in self.modules}
        out: List[Finding] = []
        for c in checkers:
            for f in c.run(self):
                mod = by_path.get(f.path)
                if mod is not None and mod.suppressed(f.check, f.line):
                    continue
                out.append(f)
        return sorted(out)


# ---------------------------------------------------------------------------
# Small shared helpers
# ---------------------------------------------------------------------------

def eval_const(expr: ast.expr, env: Dict[str, int]) -> Optional[int]:
    """Fold an integer constant expression (literals, names from
    ``env``, + - * // / % ** and unary -); ``None`` if not constant."""
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        return expr.value
    if isinstance(expr, ast.Name):
        return env.get(expr.id)
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        v = eval_const(expr.operand, env)
        return None if v is None else -v
    if isinstance(expr, ast.BinOp):
        a = eval_const(expr.left, env)
        b = eval_const(expr.right, env)
        if a is None or b is None:
            return None
        op = expr.op
        if isinstance(op, ast.Add):
            return a + b
        if isinstance(op, ast.Sub):
            return a - b
        if isinstance(op, ast.Mult):
            return a * b
        if isinstance(op, (ast.FloorDiv, ast.Div)):
            return a // b if b else None
        if isinstance(op, ast.Mod):
            return a % b if b else None
        if isinstance(op, ast.Pow):
            return a ** b
    return None


def module_int_constants(mod: Module) -> Dict[str, int]:
    """Module-level ``NAME = <int expr>`` assignments, constant-folded
    in source order (later names may reference earlier ones)."""
    env: Dict[str, int] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            v = eval_const(node.value, env)
            if v is not None:
                env[node.targets[0].id] = v
    return env


def str_literals(expr: ast.expr) -> List[str]:
    """String literals inside a tuple/list/set display (or a lone str)."""
    if isinstance(expr, ast.Constant) and isinstance(expr.value, str):
        return [expr.value]
    if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for e in expr.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return out
    return []
