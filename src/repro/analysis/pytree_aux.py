"""Pytree static-aux hygiene pass (``pytree-aux``).

The aux_data half of ``tree_flatten`` is *static*: jit treats it as
part of the function signature, so it must be hashable and cheaply
``__eq__``-comparable.  A dict/list/set aux either raises
``unhashable type`` at the first jit boundary or -- worse, with custom
containers -- hashes by identity and silently retriggers compilation
every call.  The repo's own pytrees (``SynapseTables`` carrying a
frozen ``TableStorage``, ``SimInputs`` carrying ``None``) are the
model: aux is a frozen dataclass or nothing.

Flags, for every class registered via ``register_pytree_node_class``
(and flatten functions passed to ``register_pytree_node``): a
``tree_flatten`` whose returned aux element is a mutable display
(``{...}``, ``[...]``) or a ``dict()``/``list()``/``set()`` call.
"""

from __future__ import annotations

import ast
from typing import Iterable, Optional

from .core import Checker, Finding, Module, Project

NAME = "pytree-aux"

_MUTABLE_CALLS = {"dict", "list", "set", "bytearray"}


def _aux_expr_of_flatten(fn: ast.AST) -> Optional[ast.expr]:
    """The aux element of `return children, aux` (last return wins)."""
    aux = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) \
                and isinstance(node.value, ast.Tuple) \
                and len(node.value.elts) == 2:
            aux = node.value.elts[1]
    return aux


def _mutable_reason(mod: Module, expr: ast.expr) -> Optional[str]:
    if isinstance(expr, ast.Dict):
        return "a dict literal"
    if isinstance(expr, (ast.List, ast.ListComp)):
        return "a list"
    if isinstance(expr, (ast.Set, ast.SetComp, ast.DictComp)):
        return "a set/dict comprehension"
    if isinstance(expr, ast.Call):
        dn = mod.resolve_dotted(expr.func)
        if dn in _MUTABLE_CALLS:
            return f"a {dn}() call"
    return None


class PytreeAuxChecker(Checker):
    name = NAME
    description = ("registered pytrees must return hashable (frozen) "
                   "aux data from tree_flatten")

    def run(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            yield from self._decorated_classes(mod)
            yield from self._functional_registrations(mod, project)

    def _decorated_classes(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            registered = any(
                (dn := mod.resolve_dotted(
                    d.func if isinstance(d, ast.Call) else d))
                and dn.split(".")[-1] == "register_pytree_node_class"
                for d in node.decorator_list)
            if not registered:
                continue
            flatten = next((s for s in node.body
                            if isinstance(s, ast.FunctionDef)
                            and s.name == "tree_flatten"), None)
            if flatten is None:
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f"{node.name} registered as a pytree but defines "
                    "no tree_flatten")
                continue
            yield from self._check_flatten(mod, node.name, flatten)

    def _functional_registrations(self, mod: Module,
                                  project: Project) -> Iterable[Finding]:
        for site in project.calls:
            if site.enclosing is not None and site.enclosing.module is not mod:
                continue
            call = site.call
            dn = mod.resolve_dotted(call.func)
            if not dn or dn.split(".")[-1] != "register_pytree_node":
                continue
            if call not in {c.call for c in project.calls
                            if c.enclosing is None
                            or c.enclosing.module is mod}:
                continue
            if len(call.args) < 2 or not isinstance(call.args[1], ast.Name):
                continue
            flatten_fn = next(
                (f.node for f in project.functions.values()
                 if f.module is mod
                 and f.qual.split(".")[-1] == call.args[1].id), None)
            if flatten_fn is not None:
                yield from self._check_flatten(
                    mod, f"pytree via {call.args[1].id}", flatten_fn)

    def _check_flatten(self, mod: Module, owner: str,
                       flatten: ast.AST) -> Iterable[Finding]:
        aux = _aux_expr_of_flatten(flatten)
        if aux is None:
            return
        reason = _mutable_reason(mod, aux)
        if reason:
            yield Finding(
                mod.path, aux.lineno, self.name,
                f"{owner}.tree_flatten returns {reason} as aux_data: "
                "jit hashes aux as a static argument -- use a frozen "
                "dataclass, tuple, or None")
