"""Donation-discipline pass (``donation``).

``jax.jit(..., donate_argnums=...)`` invalidates the donated buffer the
moment the jitted call runs; reading the old reference afterwards
returns garbage (or raises on some backends) -- the PR 2 bug class,
where a donated carry was reused to compute a post-hoc metric.

The pass is repo-aware in two steps:

1. **Donating factories**: any repo function whose returned value is a
   ``jax.jit(..., donate_argnums=<literal>)`` call (directly or via a
   local assignment) is itself treated as donating at the same
   positions -- so ``sim = make_sim_fn(...)`` is tracked exactly like a
   raw jit.
2. **Per-function linear scan**: names bound to a donating callable
   (locals *and* ``self.<attr>`` class attributes) mark their
   donated-position argument names dead after each call statement --
   unless the same statement rebinds them, the canonical
   ``state, out = sim(state, inputs)`` pattern.  A later read of a
   dead name is a finding.  ``if``/``else`` branches are merged by
   intersection (a name must die on *all* paths to stay dead), keeping
   the pass false-positive-free at the cost of missing some
   single-branch bugs.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .core import Checker, Finding, FnInfo, Module, Project

NAME = "donation"


def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Literal donate_argnums of a jit call, else None."""
    for kw in call.keywords:
        if kw.arg not in ("donate_argnums", "donate_argnames"):
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            out = []
            for e in v.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    out.append(e.value)
            return tuple(out)
    return None


def _is_jit(mod: Module, call: ast.Call) -> bool:
    dn = mod.resolve_dotted(call.func)
    return bool(dn) and (dn == "jax.jit" or dn.endswith(".jit"))


def _assign_target_names(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) \
            and stmt.target is not None:
        targets = [stmt.target]

    def flat(t):
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                flat(e)
        elif isinstance(t, ast.Starred):
            flat(t.value)
    for t in targets:
        flat(t)
    return out


class _Donors:
    """What names/attributes donate, discovered project-wide."""

    def __init__(self, project: Project):
        self.project = project
        # factory function -> donated positions
        self.factories: Dict[Tuple[str, str], Tuple[int, ...]] = {}
        # (modname, Class, attr) -> donated positions
        self.class_attrs: Dict[Tuple[str, str, str], Tuple[int, ...]] = {}
        self._find_factories()
        self._find_class_attrs()

    def _find_factories(self):
        for fn in self.project.functions.values():
            pos = self._factory_positions(fn)
            if pos:
                self.factories[fn.key] = pos

    def _factory_positions(self, fn: FnInfo) -> Optional[Tuple[int, ...]]:
        mod = fn.module
        jit_locals: Dict[str, Tuple[int, ...]] = {}
        for stmt in ast.walk(fn.node):
            if isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.value, ast.Call) \
                    and _is_jit(mod, stmt.value):
                pos = _donated_positions(stmt.value)
                if pos:
                    for name in _assign_target_names(stmt):
                        jit_locals[name] = pos
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                v = stmt.value
                if isinstance(v, ast.Call) and _is_jit(mod, v):
                    pos = _donated_positions(v)
                    if pos:
                        return pos
                if isinstance(v, ast.Name) and v.id in jit_locals:
                    return jit_locals[v.id]
        return None

    def positions_for_value(self, mod: Module,
                            value: ast.expr) -> Optional[Tuple[int, ...]]:
        """Donated positions if `value` evaluates to a donating callable."""
        if not isinstance(value, ast.Call):
            return None
        if _is_jit(mod, value):
            return _donated_positions(value)
        dn = mod.resolve_dotted(value.func)
        if not dn:
            return None
        target = self.project.lookup_dotted(dn)
        if target is None and "." not in dn:
            target = self.project.functions.get((mod.modname, dn))
        if target is not None and target.key in self.factories:
            return self.factories[target.key]
        return None

    def _find_class_attrs(self):
        for fn in self.project.functions.values():
            if "." not in fn.qual:
                continue
            cls = fn.qual.split(".")[0]
            for stmt in ast.walk(fn.node):
                if not isinstance(stmt, ast.Assign):
                    continue
                pos = self.positions_for_value(fn.module, stmt.value)
                if not pos:
                    continue
                for t in stmt.targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        self.class_attrs[
                            (fn.module.modname, cls, t.attr)] = pos


class DonationChecker(Checker):
    name = NAME
    description = ("reads of buffers already donated to a "
                   "jit(donate_argnums=...) call")

    def run(self, project: Project) -> Iterable[Finding]:
        donors = _Donors(project)
        for fn in project.functions.values():
            yield from self._scan_fn(fn, donors)

    def _scan_fn(self, fn: FnInfo, donors: _Donors) -> Iterable[Finding]:
        mod = fn.module
        cls = fn.qual.split(".")[0] if "." in fn.qual else None
        local_donors: Dict[str, Tuple[int, ...]] = {}
        findings: List[Finding] = []
        self._scan_block(list(getattr(fn.node, "body", [])), set(),
                         local_donors, donors, mod, cls, findings)
        return findings

    def _donating_call(self, call: ast.Call, local: Dict,
                       donors: _Donors, mod: Module,
                       cls: Optional[str]) -> Optional[Tuple[int, ...]]:
        f = call.func
        if isinstance(f, ast.Name) and f.id in local:
            return local[f.id]
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name) \
                and f.value.id == "self" and cls:
            return donors.class_attrs.get((mod.modname, cls, f.attr))
        return None

    def _scan_block(self, stmts: List[ast.stmt], dead: Set[str],
                    local: Dict[str, Tuple[int, ...]], donors: _Donors,
                    mod: Module, cls: Optional[str],
                    findings: List[Finding]):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            rebound = _assign_target_names(stmt)

            # only this statement's *own* expressions are examined here;
            # nested statement lists (if/for bodies) are scanned
            # recursively so their rebindings are tracked correctly
            if isinstance(stmt, (ast.If, ast.While)):
                own_exprs: List[ast.AST] = [stmt.test]
            elif isinstance(stmt, ast.For):
                own_exprs = [stmt.iter]
            elif isinstance(stmt, ast.With):
                own_exprs = [i.context_expr for i in stmt.items]
            elif isinstance(stmt, ast.Try):
                own_exprs = []
            else:
                own_exprs = [stmt]

            donated_here: Set[str] = set()
            for expr in own_exprs:
                for node in ast.walk(expr):
                    if isinstance(node, ast.Call):
                        pos = self._donating_call(node, local, donors,
                                                  mod, cls)
                        if pos:
                            for p in pos:
                                if p < len(node.args) and isinstance(
                                        node.args[p], ast.Name):
                                    donated_here.add(node.args[p].id)
                    if isinstance(node, ast.Name) \
                            and isinstance(node.ctx, ast.Load) \
                            and node.id in dead:
                        findings.append(Finding(
                            mod.path, node.lineno, self.name,
                            f"`{node.id}` was donated to a jitted call "
                            "above (donate_argnums) -- its buffer is "
                            "invalidated; rebind the result instead"))

            # new donor bindings
            if isinstance(stmt, ast.Assign):
                pos = donors.positions_for_value(mod, stmt.value)
                if pos:
                    for name in rebound:
                        local[name] = pos

            if isinstance(stmt, ast.If):
                d1, d2 = set(dead), set(dead)
                self._scan_block(list(stmt.body), d1, local, donors,
                                 mod, cls, findings)
                self._scan_block(list(stmt.orelse), d2, local, donors,
                                 mod, cls, findings)
                dead.clear()
                dead |= (d1 & d2)
            elif isinstance(stmt, (ast.For, ast.While)):
                self._scan_block(list(stmt.body), set(dead), local,
                                 donors, mod, cls, findings)
            elif isinstance(stmt, ast.With):
                self._scan_block(list(stmt.body), dead, local, donors,
                                 mod, cls, findings)
            elif isinstance(stmt, ast.Try):
                self._scan_block(list(stmt.body), dead, local, donors,
                                 mod, cls, findings)
                for h in stmt.handlers:
                    self._scan_block(list(h.body), set(dead), local,
                                     donors, mod, cls, findings)
                self._scan_block(list(stmt.orelse), dead, local, donors,
                                 mod, cls, findings)
                self._scan_block(list(stmt.finalbody), dead, local,
                                 donors, mod, cls, findings)

            # a donated name dies unless this very statement rebinds it
            dead |= (donated_here - rebound)
            dead -= rebound
