"""Meta-drift coverage pass (``meta-drift``).

A checkpoint manifest's meta dict is the *identity* of the saved state:
resume must refuse when a trajectory-affecting field differs.  The PR
5/6 bug class is a new field written into ``_meta()`` that never gets
validated on the restore path -- resume then silently reinterprets old
bytes under a new model.  This pass cross-references, inside
``runtime/sim_driver.py``:

* every key the driver *produces* (string keys of the ``_meta()`` dict
  literal plus ``meta["k"] = ...`` assignments in ``_save``), against
* every key the restore path *consumes* (string literals passed to
  ``refuse_meta_drift`` key tuples, ``meta.get("k")`` reads, and
  ``meta["k"]`` subscripts anywhere in the module).

A produced-but-never-consumed key is a finding; intentionally
report-only keys carry a pragma with the reason.  Three structural
checks ride along: the required identity keys (grid / law / seed /
table_realization) must appear in a ``refuse_meta_drift`` call, the
``"stdp"`` meta value must come from ``dataclasses.asdict`` (field
renames then show up as drift instead of comparing dataclass reprs),
and every ``TableStorage`` dataclass field must round-trip through its
``meta()`` dict so storage drift can't hide a field.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from .core import Checker, Finding, Module, Project, str_literals

NAME = "meta-drift"

REQUIRED_IDENTITY_KEYS = {"grid", "law", "seed", "table_realization"}


def _find_module(project: Project, suffix: str) -> Optional[Module]:
    for m in project.modules:
        if m.path.replace("\\", "/").endswith(suffix):
            return m
    return None


def _dict_str_keys(d: ast.Dict) -> List[ast.Constant]:
    return [k for k in d.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)]


class MetaDriftChecker(Checker):
    name = NAME
    description = ("checkpoint meta keys produced by the sim driver "
                   "must be consumed (refused-on-drift or read) on the "
                   "restore path")

    def run(self, project: Project) -> Iterable[Finding]:
        driver = _find_module(project, "runtime/sim_driver.py")
        if driver is not None:
            yield from self._coverage(driver)
            yield from self._stdp_is_asdict(driver)
        syn = _find_module(project, "core/synapses.py")
        if syn is not None:
            yield from self._storage_roundtrip(syn)

    # ---- produced vs consumed -----------------------------------------
    def _coverage(self, mod: Module) -> Iterable[Finding]:
        produced: List[ast.Constant] = []      # key Constant nodes
        consumed: Set[str] = set()

        for node in ast.walk(mod.tree):
            # _meta()'s dict literal(s): any dict returned by a function
            # named _meta
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == "_meta":
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Return) \
                            and isinstance(sub.value, ast.Dict):
                        produced.extend(_dict_str_keys(sub.value))
            # meta["k"] = ...  (production); meta["k"] / m.get("k") reads
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.slice, ast.Constant) \
                    and isinstance(node.slice.value, str):
                if isinstance(node.ctx, ast.Store):
                    produced.append(node.slice)
                else:
                    consumed.add(node.slice.value)
            if isinstance(node, ast.Call):
                func = node.func
                if isinstance(func, ast.Attribute) and func.attr == "get" \
                        and node.args \
                        and isinstance(node.args[0], ast.Constant) \
                        and isinstance(node.args[0].value, str):
                    consumed.add(node.args[0].value)
                dn = mod.resolve_dotted(func)
                if dn and dn.split(".")[-1] == "refuse_meta_drift":
                    for a in list(node.args) + [k.value
                                                for k in node.keywords]:
                        consumed.update(str_literals(a))

        refused: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                dn = mod.resolve_dotted(node.func)
                if dn and dn.split(".")[-1] == "refuse_meta_drift":
                    for a in list(node.args) + [k.value
                                                for k in node.keywords]:
                        refused.update(str_literals(a))

        seen: Set[str] = set()
        for key_node in produced:
            key = key_node.value
            if key in seen:
                continue
            seen.add(key)
            if key not in consumed:
                yield Finding(
                    mod.path, key_node.lineno, self.name,
                    f"meta key '{key}' is written to the checkpoint "
                    "manifest but never validated or read on the "
                    "restore path -- drift in it goes unnoticed "
                    "(refuse_meta_drift it, read it, or pragma with "
                    "a reason)")

        for key in sorted(REQUIRED_IDENTITY_KEYS - refused):
            yield Finding(
                mod.path, 1, self.name,
                f"identity key '{key}' is not in any "
                "refuse_meta_drift() call: resume would accept a "
                "checkpoint from a different model")

    # ---- stdp must serialize via asdict -------------------------------
    def _stdp_is_asdict(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                    and node.name == "_meta"):
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Return) \
                        or not isinstance(sub.value, ast.Dict):
                    continue
                for k, v in zip(sub.value.keys, sub.value.values):
                    if not (isinstance(k, ast.Constant)
                            and k.value == "stdp"):
                        continue
                    ok = any(isinstance(c, ast.Call)
                             and (dn := mod.resolve_dotted(c.func))
                             and dn.split(".")[-1] == "asdict"
                             for c in ast.walk(v))
                    if not ok:
                        yield Finding(
                            mod.path, v.lineno, self.name,
                            "meta 'stdp' must serialize via "
                            "dataclasses.asdict so per-field drift is "
                            "comparable across versions")

    # ---- TableStorage fields round-trip through meta() ----------------
    def _storage_roundtrip(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.ClassDef)
                    and node.name == "TableStorage"):
                continue
            fields = [s.target.id for s in node.body
                      if isinstance(s, ast.AnnAssign)
                      and isinstance(s.target, ast.Name)]
            meta_keys: Set[str] = set()
            meta_fn = None
            for s in node.body:
                if isinstance(s, ast.FunctionDef) and s.name == "meta":
                    meta_fn = s
                    for sub in ast.walk(s):
                        if isinstance(sub, ast.Dict):
                            meta_keys.update(
                                c.value for c in _dict_str_keys(sub))
            if meta_fn is None:
                yield Finding(mod.path, node.lineno, self.name,
                              "TableStorage has no meta() serializer")
                continue
            for f in fields:
                if f not in meta_keys:
                    yield Finding(
                        mod.path, meta_fn.lineno, self.name,
                        f"TableStorage field '{f}' missing from "
                        "meta(): storage drift in it is invisible to "
                        "resume validation")
