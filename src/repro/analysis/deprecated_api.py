"""Deprecated-API pass (``deprecated-api``).

PR 10 retired the ``repro.core.engine.run`` / ``run_plastic`` aliases:
``simulate(state, tables, cfg, n_steps, plasticity=...)`` is the one
entry point, and the ensemble path (``ensemble=``) only exists there.
A resurrected alias would silently fork the API -- new call sites
would miss ensembles and every keyword the aliases never grew.  This
pass keeps them dead:

* **imports** of a retired name (``from repro.core.engine import
  run``, any alias/relative spelling);
* **calls** that resolve to a retired dotted name
  (``engine.run(...)``, ``repro.core.run_plastic(...)``);
* **redefinition**: a top-level ``def run`` / ``def run_plastic``
  reappearing in ``core/engine.py`` itself.

Unrelated ``run`` functions (``SimDriver.run``, ``analyze_run``,
fixtures) are out of scope: only names resolving into
``repro.core.engine`` (or re-exports via ``repro.core``) count.
"""

from __future__ import annotations

import ast
from typing import Iterable

from .core import Checker, Finding, Project

NAME = "deprecated-api"

# retired dotted name -> replacement shown in the finding
RETIRED = {
    "repro.core.engine.run":
        "repro.core.engine.simulate",
    "repro.core.engine.run_plastic":
        "repro.core.engine.simulate(..., plasticity=...)",
    "repro.core.run":
        "repro.core.engine.simulate",
    "repro.core.run_plastic":
        "repro.core.engine.simulate(..., plasticity=...)",
}
RETIRED_NAMES = ("run", "run_plastic")
ENGINE_MODULES = ("repro.core.engine", "repro.core")


class DeprecatedApiChecker(Checker):
    name = NAME
    description = ("retired engine entry points (run/run_plastic) must "
                   "not be imported, called, or redefined -- use "
                   "simulate(..., plasticity=...)")

    def run(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            yield from self._imports(mod)
            yield from self._redefinition(mod)
        for site in project.calls:
            if site.callee in RETIRED:
                mod = (site.enclosing.module if site.enclosing
                       else project._module_of_call(site))
                if mod is None:
                    continue
                yield Finding(
                    mod.path, site.call.lineno, NAME,
                    f"call to retired {site.callee}(); use "
                    f"{RETIRED[site.callee]}")

    def _imports(self, mod) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ImportFrom):
                continue
            if node.level:                     # relative: resolve base
                pkg = mod.modname.split(".")[:-1]
                base = ".".join(pkg[:len(pkg) - (node.level - 1)]
                                + ([node.module] if node.module else []))
            else:
                base = node.module or ""
            for a in node.names:
                if f"{base}.{a.name}" in RETIRED:
                    yield Finding(
                        mod.path, node.lineno, NAME,
                        f"import of retired {base}.{a.name}; use "
                        f"{RETIRED[f'{base}.{a.name}']}")

    def _redefinition(self, mod) -> Iterable[Finding]:
        if mod.modname not in ENGINE_MODULES \
                and not mod.path.replace("\\", "/").endswith(
                    "core/engine.py"):
            return
        for node in mod.tree.body:             # top level only
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in RETIRED_NAMES:
                yield Finding(
                    mod.path, node.lineno, NAME,
                    f"redefinition of retired engine alias "
                    f"{node.name!r}; the one entry point is simulate()")
