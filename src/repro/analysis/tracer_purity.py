"""Tracer-purity pass (``tracer-purity``).

Determinism underpins bit-identical resume: a host RNG draw, wall-clock
read, or Python-level branch on a traced array inside jitted/scanned
code either breaks reproducibility or fails at trace time in a way unit
tests at small sizes may never exercise.  Two families of findings:

1. **Inside the traced set** (functions reachable from ``jax.jit`` /
   ``lax.scan`` / ``shard_map`` / ``pallas_call`` bodies): any call
   into ``numpy.random`` / stdlib ``random`` / ``time`` / ``datetime``,
   telemetry spans/exporters (``repro.obs.telemetry`` /
   ``repro.perf.trace`` -- host-side observers whose clocks would read
   at trace time, see the pure-observer contract), host I/O
   (``open``/``print``/``np.save``/``json.dump``/...), and
   Python ``if``/``while``/``assert``/``bool()``/``.item()`` on a
   value produced by a jax op (light taint propagation through local
   assignments; ``.shape``/``.dtype``/``len()`` reads do not taint).

2. **Anywhere**: *unseeded* host RNG -- legacy ``np.random.<fn>``
   module-level draws and ``np.random.default_rng()`` with no seed,
   plus stdlib ``random`` draws -- which silently break per-seed
   deterministic table realizations even in host-side build code.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from .core import Checker, Finding, FnInfo, Module, Project

NAME = "tracer-purity"

_HOST_MODULE_PREFIXES = ("numpy.random.", "random.", "time.", "datetime.")
# runtime-telemetry spans/exporters are host-side observers by
# contract: inside a traced closure the span's clock would read at
# trace time and "measure" nothing (and the record append is a side
# effect XLA may replay or elide)
_TELEMETRY_PREFIXES = ("repro.obs.telemetry.", "repro.perf.trace.")
_HOST_IO_CALLS = {"open", "print", "input"}
_HOST_IO_PREFIXES = ("os.", "json.dump", "json.load", "pickle.",
                     "numpy.save", "numpy.load", "numpy.savez",
                     "builtins.open", "builtins.print", "shutil.",
                     "pathlib.")
# jax namespaces whose call results are traced values
_TAINT_SOURCES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.",
                  "jax.scipy.", "jax.ops.")
# attribute reads that yield static (python) values even on tracers
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding", "aval"}
_STATIC_CALLS = {"len", "isinstance", "type", "getattr", "hasattr"}

_LEGACY_NP_RANDOM = {
    "rand", "randn", "randint", "random", "random_sample", "normal",
    "uniform", "choice", "shuffle", "permutation", "seed",
    "standard_normal", "poisson", "binomial", "exponential", "gamma",
    "beta",
}
_STDLIB_RANDOM = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "seed", "betavariate",
    "expovariate", "vonmisesvariate",
}


def _fn_body(fn: FnInfo) -> List[ast.stmt]:
    return list(getattr(fn.node, "body", []))


def _walk_skip_nested(stmts: Iterable[ast.stmt]):
    """Walk statements without descending into nested function defs
    (those are separate FnInfos, analyzed if themselves traced)."""
    for stmt in stmts:
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not stmt:
                continue
            yield node


class _Taint:
    """Very light flow-insensitive-within-branches taint tracker."""

    def __init__(self, mod: Module):
        self.mod = mod
        self.tainted: Set[str] = set()

    def tainted_names_in(self, expr: ast.expr) -> List[ast.Name]:
        out: List[ast.Name] = []
        self._scan(expr, out)
        return out

    def _scan(self, node: ast.AST, out: List[ast.Name]):
        if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
            return                       # x.shape is static
        if isinstance(node, ast.Call):
            dn = self.mod.resolve_dotted(node.func)
            if dn in _STATIC_CALLS:
                return                   # len(x) / isinstance(x, T)
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load) \
                and node.id in self.tainted:
            out.append(node)
            return
        for child in ast.iter_child_nodes(node):
            self._scan(child, out)

    def value_is_traced(self, expr: ast.expr) -> bool:
        if isinstance(expr, ast.Call):
            dn = self.mod.resolve_dotted(expr.func)
            if dn and any(dn.startswith(p) for p in _TAINT_SOURCES):
                return True
        return bool(self.tainted_names_in(expr))

    def assign(self, targets: Iterable[ast.expr], traced: bool):
        for t in targets:
            if isinstance(t, ast.Name):
                (self.tainted.add if traced
                 else self.tainted.discard)(t.id)
            elif isinstance(t, (ast.Tuple, ast.List)):
                self.assign(t.elts, traced)


class TracerPurityChecker(Checker):
    name = NAME
    description = ("host RNG/time/IO calls and Python branches on "
                   "traced values inside jit/scan-reachable code; "
                   "unseeded host RNG anywhere")

    def run(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            yield from self._unseeded_rng(mod)
        for fn in project.traced:
            yield from self._check_traced_fn(fn)

    # ---- global unseeded-RNG scan -------------------------------------
    def _unseeded_rng(self, mod: Module) -> Iterable[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = mod.resolve_dotted(node.func)
            if not dn:
                continue
            if dn.startswith("numpy.random."):
                tail = dn[len("numpy.random."):]
                if tail in _LEGACY_NP_RANDOM:
                    yield Finding(
                        mod.path, node.lineno, self.name,
                        f"legacy np.random.{tail}() draws from hidden "
                        "global state; use np.random.default_rng(seed)")
                elif tail == "default_rng" and not node.args \
                        and not node.keywords:
                    yield Finding(
                        mod.path, node.lineno, self.name,
                        "np.random.default_rng() without a seed breaks "
                        "deterministic table realization")
            elif dn.startswith("random.") \
                    and dn[len("random."):] in _STDLIB_RANDOM:
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f"stdlib {dn}() is unseeded global-state RNG; "
                    "use np.random.default_rng(seed) or jax.random")

    # ---- traced-set purity --------------------------------------------
    def _check_traced_fn(self, fn: FnInfo) -> Iterable[Finding]:
        mod = fn.module
        taint = _Taint(mod)
        where = f"traced function {fn.qual}"

        for node in _walk_skip_nested(_fn_body(fn)):
            if isinstance(node, ast.Call):
                dn = mod.resolve_dotted(node.func)
                if dn:
                    yield from self._host_call(mod, node, dn, where)

        # second sweep, statement-ordered, for the taint checks
        yield from self._taint_sweep(fn, _fn_body(fn), taint, where)

    def _host_call(self, mod: Module, node: ast.Call, dn: str,
                   where: str) -> Iterable[Finding]:
        if any(dn.startswith(p) for p in _TELEMETRY_PREFIXES):
            yield Finding(
                mod.path, node.lineno, self.name,
                f"telemetry {dn}() inside {where}: spans are host-side "
                "observers -- in a traced closure the clock reads at "
                "trace time and measures nothing per step; wrap the "
                "jitted call site instead (device-phase attribution "
                "lives in benchmarks.fig_phase_breakdown)")
        elif any(dn.startswith(p) for p in _HOST_MODULE_PREFIXES):
            yield Finding(
                mod.path, node.lineno, self.name,
                f"{dn}() inside {where}: host RNG/clock calls run at "
                "trace time, not per step -- nondeterministic resume")
        elif dn in _HOST_IO_CALLS \
                or any(dn.startswith(p) for p in _HOST_IO_PREFIXES):
            yield Finding(
                mod.path, node.lineno, self.name,
                f"host I/O {dn}() inside {where}: executes at trace "
                "time only; use jax.debug.print / io_callback")

    def _taint_sweep(self, fn: FnInfo, stmts: List[ast.stmt],
                     taint: _Taint, where: str) -> Iterable[Finding]:
        mod = fn.module
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.Assign):
                traced = taint.value_is_traced(stmt.value)
                yield from self._value_escapes(mod, stmt.value, taint, where)
                taint.assign(stmt.targets, traced)
            elif isinstance(stmt, ast.AugAssign):
                if taint.value_is_traced(stmt.value):
                    taint.assign([stmt.target], True)
            elif isinstance(stmt, (ast.If, ast.While)):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                for name in taint.tainted_names_in(stmt.test):
                    yield Finding(
                        mod.path, stmt.lineno, self.name,
                        f"Python `{kind}` on traced value `{name.id}` in "
                        f"{where}: use jax.lax.cond/select (or .shape "
                        "checks) -- a tracer has no runtime truth value")
                yield from self._taint_sweep(fn, list(stmt.body), taint,
                                             where)
                yield from self._taint_sweep(fn, list(stmt.orelse), taint,
                                             where)
            elif isinstance(stmt, ast.Assert):
                for name in taint.tainted_names_in(stmt.test):
                    yield Finding(
                        mod.path, stmt.lineno, self.name,
                        f"`assert` on traced value `{name.id}` in {where}:"
                        " use checkify or a static (shape/dtype) check")
            elif isinstance(stmt, ast.For):
                if taint.value_is_traced(stmt.iter):
                    yield Finding(
                        mod.path, stmt.lineno, self.name,
                        f"Python `for` over a traced value in {where}: "
                        "unrolls at trace time; use lax.scan/fori_loop")
                yield from self._taint_sweep(fn, list(stmt.body), taint,
                                             where)
            elif isinstance(stmt, (ast.Return, ast.Expr)):
                value = stmt.value
                if value is not None:
                    yield from self._value_escapes(mod, value, taint, where)
            elif isinstance(stmt, ast.With):
                yield from self._taint_sweep(fn, list(stmt.body), taint,
                                             where)

    def _value_escapes(self, mod: Module, expr: ast.expr, taint: _Taint,
                       where: str) -> Iterable[Finding]:
        """float()/int()/bool()/.item() force a traced value to host."""
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            dn = mod.resolve_dotted(node.func)
            if dn in ("float", "int", "bool") and node.args \
                    and taint.tainted_names_in(node.args[0]):
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f"{dn}() on a traced value in {where}: forces a "
                    "host transfer at trace time (ConcretizationError)")
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item" \
                    and taint.tainted_names_in(node.func.value):
                yield Finding(
                    mod.path, node.lineno, self.name,
                    f".item() on a traced value in {where}")
