"""Deterministic synthetic data pipelines."""

from .pipeline import (LMBatchPipeline, make_batch_specs, host_shard_slice)
