"""Deterministic synthetic token / frame / patch pipeline.

Stateless, counter-based generation: batch ``i`` is a pure function of
(seed, step), so restarts reproduce the exact stream without data-state
checkpointing -- the restore path only needs the step counter.  Tokens
follow a Zipf-ish marginal plus a bigram structure so losses actually
decrease during the example runs (pure uniform tokens give a flat loss
at ln(V)).

Multi-host sharding: each host materializes only its slice of the global
batch (``host_shard_slice``); on this single-host container that is the
whole batch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..models.config import ModelConfig, ShapeConfig
from ..models.frontends import STUB_WIDTH


def host_shard_slice(global_batch: int, host_id: int, n_hosts: int):
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)


@dataclasses.dataclass
class LMBatchPipeline:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def _tokens(self, rng, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        # zipf marginal folded into vocab + deterministic bigram drift:
        # next ~ (prev * 31 + zipf) % V on half the positions
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % v
        toks = base.copy()
        mix = rng.random((b, s)) < 0.5
        toks[:, 1:] = np.where(mix[:, 1:],
                               (toks[:, :-1] * 31 + base[:, 1:]) % v,
                               base[:, 1:])
        return toks.astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b = self.shape.global_batch // self.n_hosts
        cfg, shape = self.cfg, self.shape

        if shape.kind == "decode":
            return {"token": self._tokens(rng, b, 1)}

        s = shape.seq_len
        out: Dict[str, np.ndarray] = {}
        if cfg.n_patches:
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.n_patches, STUB_WIDTH)).astype(np.float32)
            s = s - cfg.n_patches
        if cfg.encoder_seq:
            out["frames"] = rng.standard_normal(
                (b, cfg.encoder_seq, STUB_WIDTH)).astype(np.float32)
        toks = self._tokens(rng, b, s + 1)
        out["tokens"] = toks[:, :-1]
        if shape.kind == "train":
            out["labels"] = toks[:, 1:].astype(np.int32)
        return out


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules):
    """PartitionSpecs for a batch dict (batch dim -> DP axes)."""
    from ..models.model import input_specs
    specs = input_specs(cfg, shape)
    return {k: rules.pspec("batch", *([None] * (len(v.shape) - 1)))
            for k, v in specs.items()}
