"""whisper-small [audio] -- encoder-decoder with conv frontend stub.

12L d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865
[arXiv:2212.04356; unverified].  The conv frontend is a STUB:
``input_specs()`` provides 1500 precomputed frame embeddings.  Shape
semantics (DESIGN.md section 5): ``seq_len`` is the decoder-side length;
decode shapes cache both self- and cross-attention.  No rope --
sinusoidal absolute positions, whisper-style.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865, use_rope=False, mlp_act="gelu",
    encoder_layers=12, encoder_seq=1500, cross_attn=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="audio",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=512, use_rope=False, mlp_act="gelu",
        encoder_layers=2, encoder_seq=12, cross_attn=True,
        dtype="float32", attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
