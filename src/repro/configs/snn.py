"""The paper's own configurations: column grids x connectivity laws.

Table 1 problem sizes (grid, neurons, recurrent/total synapses):
  24x24  0.7M   0.9G/1.2G (gaussian)   1.5G/1.8G (exponential)
  48x48  2.9M   3.5G/5.0G              5.9G/7.4G
  96x96 11.4M  14.2G/20.4G            23.4G/29.6G
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.connectivity import (exponential_law, gaussian_law,
                                     NEURONS_PER_COLUMN)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.engine import EngineConfig


@dataclasses.dataclass(frozen=True)
class SNNCase:
    name: str
    grid: Tuple[int, int]
    law: str                        # "gaussian" | "exponential"
    n_per_column: int = NEURONS_PER_COLUMN

    def connectivity(self):
        return gaussian_law() if self.law == "gaussian" else \
            exponential_law()

    def engine_config(self, tiles_y: int, tiles_x: int,
                      **overrides) -> EngineConfig:
        law = self.connectivity()
        decomp = TileDecomposition(
            grid=ColumnGrid(self.grid[0], self.grid[1], self.n_per_column),
            tiles_y=tiles_y, tiles_x=tiles_x, radius=law.radius)
        return EngineConfig(decomp=decomp, law=law, **overrides)


GRIDS = ((24, 24), (48, 48), (96, 96))
LAWS = ("gaussian", "exponential")

CASES = {
    f"snn-{g[0]}x{g[1]}-{law}": SNNCase(f"snn-{g[0]}x{g[1]}-{law}", g, law)
    for g in GRIDS for law in LAWS
}


def reduced_case(law: str = "gaussian", grid: int = 8,
                 n_per_column: int = 60) -> SNNCase:
    """Reduced config for CPU-runnable tests/benchmarks."""
    return SNNCase(f"snn-{grid}x{grid}-{law}-reduced", (grid, grid), law,
                   n_per_column=n_per_column)
