"""kimi-k2-1t-a32b [moe] -- trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert) vocab=163840,
MoE 384 experts top-8 [arXiv:2501.kimi2; unverified].

Pool note: the assignment specifies GQA 64H/kv=8 (not Kimi's MLA); we
implement the config exactly as given (DESIGN.md section 9).  Total params
~1.03e12; active ~30e9/token.  Adafactor + FSDP required to fit.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, n_experts=384, moe_top_k=8, head_dim=112,
    rope_theta=5e4,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b-reduced", family="moe",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=32,
        vocab_size=512, n_experts=8, moe_top_k=2, head_dim=8,
        capacity_factor=2.0, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
