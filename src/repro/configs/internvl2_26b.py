"""internvl2-26b [vlm] -- InternViT + InternLM2 backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
[arXiv:2404.16821; hf].  The InternViT tower is a STUB per the
assignment: ``input_specs()`` provides 256 precomputed patch embeddings
(448px / patch-14 with pixel-unshuffle) that a learned adapter projects
to d_model and prepends to the token sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, head_dim=128, n_patches=256, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-reduced", family="vlm",
        n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, d_ff=96,
        vocab_size=512, head_dim=8, n_patches=8, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
