"""qwen3-8b [dense] -- GQA with per-head qk RMSNorm.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
[hf:Qwen/Qwen3-8B; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16, qk_norm=True, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
