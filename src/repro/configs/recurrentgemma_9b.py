"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention, 1:2 pattern.

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427; unverified].  Griffin block pattern: two RG-LRU
recurrent blocks then one local (sliding-window 2048) attention block;
38 = 12 full periods + 2 remainder recurrent layers.
Sub-quadratic -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288,
    vocab_size=256000, head_dim=256,
    pattern=("rglru", "rglru", "attn"), window=2048,
    mlp_act="gelu", rms_offset=True, embed_scale=True,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-reduced", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=512, head_dim=16, pattern=("rglru", "rglru", "attn"),
        window=16, mlp_act="gelu", rms_offset=True, embed_scale=True,
        dtype="float32", attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
        mamba_chunk=16,
    )
