"""granite-moe-1b-a400m [moe] -- 32 experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab_size=49155, n_experts=32, moe_top_k=8,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-reduced", family="moe",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=512, n_experts=4, moe_top_k=2, capacity_factor=2.0,
        dtype="float32", attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
