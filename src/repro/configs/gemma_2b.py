"""gemma-2b [dense] -- MQA, GeGLU, head_dim 256, scaled embeddings.

18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000
[arXiv:2403.08295; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256, mlp_act="gelu",
    rms_offset=True, embed_scale=True, rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced", family="dense",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=512, head_dim=16, mlp_act="gelu", rms_offset=True,
        embed_scale=True, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
