"""qwen2.5-3b [dense] -- GQA with QKV bias.

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
[hf:Qwen/Qwen2.5-0.5B; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_ff=11008,
    vocab_size=151936, qkv_bias=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-reduced", family="dense",
        n_layers=3, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=512, qkv_bias=True, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
