"""qwen2-1.5b [dense] -- GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2407.10671; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151936, qkv_bias=True, rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-reduced", family="dense",
        n_layers=3, d_model=48, n_heads=6, n_kv_heads=2, d_ff=96,
        vocab_size=512, qkv_bias=True, dtype="float32",
        attn_chunk_q=32, attn_chunk_k=32, loss_chunk=32,
    )
