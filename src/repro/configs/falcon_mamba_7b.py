"""falcon-mamba-7b [ssm] -- attention-free mamba1 architecture.

64L d_model=4096 (attn-free) d_ff=0 vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified].  d_inner = 2*d = 8192, conv_k = 4,
dt_rank = ceil(d/16) = 256.  Sub-quadratic -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=65024, pattern=("mamba",), ssm_state=16,
    d_inner_mult=2, conv_k=4, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b-reduced", family="ssm",
        n_layers=4, d_model=48, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=512, pattern=("mamba",), ssm_state=4, conv_k=4,
        dtype="float32", loss_chunk=32, mamba_chunk=16,
    )
