"""Config registry: ``--arch <id>`` resolves here.

LM architectures (the 10 assigned cells) + the paper's own SNN cases.
"""

from __future__ import annotations

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

from . import (falcon_mamba_7b, gemma_2b, granite_moe_1b, internvl2_26b,
               kimi_k2_1t, qwen2_1_5b, qwen2_5_3b, qwen3_8b,
               recurrentgemma_9b, whisper_small)
from . import snn

_MODULES = {
    "recurrentgemma-9b": recurrentgemma_9b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "qwen2-1.5b": qwen2_1_5b,
    "qwen3-8b": qwen3_8b,
    "gemma-2b": gemma_2b,
    "qwen2.5-3b": qwen2_5_3b,
    "internvl2-26b": internvl2_26b,
    "whisper-small": whisper_small,
    "granite-moe-1b-a400m": granite_moe_1b,
    "kimi-k2-1t-a32b": kimi_k2_1t,
}

ARCH_NAMES = tuple(_MODULES)

# Sub-quadratic archs run the long_500k cell; pure full-attention archs
# skip it (and encoder-only would skip decode -- none here are).
LONG_CONTEXT_ARCHS = ("recurrentgemma-9b", "falcon-mamba-7b")


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _MODULES[name].reduced()


def shape_cells(arch: str):
    """The shape cells this arch runs (spec-mandated skips applied)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        cells.append("long_500k")
    return [SHAPES[c] for c in cells]


def all_cells():
    for a in ARCH_NAMES:
        for s in shape_cells(a):
            yield a, s
