"""Activity analysis over spooled spike logs -> JSON report.

Computes the paper-family activity statistics (firing-rate
distributions, ISI CV, population rate with Down/Up segmentation and a
slow-wave vs awake-like regime call) from the spike logs a recorded run
(``python -m repro.launch.sim --record``) spooled under
``<ckpt_dir>/spool``, and -- given several runs -- the comparison table
the connectivity-law studies are built on.

One run::

    PYTHONPATH=src python -m repro.launch.analyze \\
        --run /tmp/snn_ckpt --out results/analysis.json

Gaussian vs exponential comparison (labels are free-form)::

    PYTHONPATH=src python -m repro.launch.analyze \\
        --run gauss=/tmp/snn_gauss --run expo=/tmp/snn_expo \\
        --out results/law_comparison.json

An ensemble run (``--seeds``/``SimJobSpec.seeds``) expands into one
labeled report per member stream -- ``label/member_000``, ... -- plus
the member-vs-member comparison table; ``--member M`` restricts to one
member.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.obs.analysis import analyze_run, compare_runs, strip_private
from repro.obs.spool import member_dirs, member_name
from repro.obs.telemetry import read_jsonl, summarize


def parse_run(spec: str):
    """``label=dir`` or bare ``dir`` (label = basename)."""
    if "=" in spec:
        label, path = spec.split("=", 1)
    else:
        path = spec
        label = os.path.basename(os.path.normpath(spec))
    if not label:
        raise SystemExit(f"--run {spec!r}: empty label")
    return label, path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--run", action="append", required=True,
                    metavar="[LABEL=]DIR",
                    help="recorded run directory (repeatable; the spool/ "
                         "subdirectory is found automatically)")
    ap.add_argument("--out", default=os.path.join("results",
                                                  "analysis.json"))
    ap.add_argument("--steps", type=int, default=None,
                    help="simulated steps (default: inferred from the "
                         "run's checkpoints)")
    ap.add_argument("--bin-steps", type=int, default=5,
                    help="population-rate bin width in steps")
    ap.add_argument("--smooth-bins", type=int, default=5,
                    help="moving-average window for Up/Down thresholding")
    ap.add_argument("--updown-frac", type=float, default=0.3,
                    help="Up threshold as a fraction of the p10-p90 span")
    ap.add_argument("--telemetry", action="append", default=[],
                    metavar="[LABEL=]FILE.jsonl",
                    help="telemetry stream from a traced run "
                         "(--telemetry-out); summarized into the report "
                         "(per-span wall totals, segment throughput)")
    ap.add_argument("--member", type=int, default=None,
                    help="ensemble runs: analyze only this member "
                         "stream (default: every member, labeled "
                         "LABEL/member_NNN)")
    args = ap.parse_args(argv)

    runs = dict(parse_run(s) for s in args.run)
    if len(runs) != len(args.run):
        raise SystemExit("--run labels must be unique")
    runs, plain = {}, runs
    saw_ensemble = False
    for label, path in plain.items():
        members = member_dirs(path)
        if not members:
            runs[label] = path
            continue
        saw_ensemble = True
        if args.member is not None:
            name = member_name(args.member)
            if name not in members:
                raise SystemExit(
                    f"--member {args.member}: {path} has members "
                    f"{sorted(members)}")
            members = {name: members[name]}
        for name, mpath in members.items():
            runs[f"{label}/{name}"] = mpath
    if args.member is not None and not saw_ensemble:
        raise SystemExit("--member: none of the runs is an ensemble")
    reports = {label: analyze_run(path, t_steps=args.steps,
                                  bin_steps=args.bin_steps,
                                  smooth_bins=args.smooth_bins,
                                  updown_frac=args.updown_frac)
               for label, path in runs.items()}
    payload = {"runs": {k: strip_private(r) for k, r in reports.items()}}
    if len(reports) > 1:
        payload["comparison"] = compare_runs(reports)
    if args.telemetry:
        payload["telemetry"] = {
            label: summarize(read_jsonl(path))
            for label, path in (parse_run(s) for s in args.telemetry)}

    for label, r in reports.items():
        ud = r["population"]["updown"]
        cv = r["isi"]["mean_cv"]
        print(f"{label}: events={r['n_events']} "
              f"mean_rate_hz={r['mean_rate_hz']:.2f} "
              f"isi_cv={'n/a' if cv is None else round(cv, 3)} "
              f"regime={ud['regime']} up_fraction={ud['up_fraction']:.2f}")
    if len(reports) > 1:
        for pair, row in payload["comparison"]["pairs"].items():
            ratio = row["mean_rate_ratio"]
            print(f"{pair}: mean_rate_ratio="
                  f"{'n/a' if ratio is None else round(ratio, 3)} "
                  f"rate_ks={row['rate_ks_statistic']}")
    for label, t in payload.get("telemetry", {}).items():
        seg = t.get("segments")
        rate = (f"{seg['steps_per_s_mean']:.1f} steps/s over {seg['n']} "
                "segment(s)" if seg else "no segment metrics")
        print(f"telemetry {label}: {t['processes']} process(es), "
              f"{sum(s['count'] for s in t['spans'].values())} span(s), "
              f"{rate}")

    d = os.path.dirname(args.out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
