"""Segmented, checkpointed long-run SNN simulation launcher.

The paper's DPSNN jobs are multi-hour distributed runs that must survive
preemption and come back on whatever process geometry the scheduler
grants next.  This CLI drives the distributed engine the same way:
fixed-size scan segments, async checkpoints between segments, SIGTERM
preemption, and elastic re-tiling on resume.

Fresh run (1x1 tiling on a single host device)::

    PYTHONPATH=src python -m repro.launch.sim --grid 4 --law gaussian \\
        --steps 200 --segment-steps 50 --ckpt-dir /tmp/snn_ckpt

Preempt it (``kill -TERM <pid>``, or deterministically with
``--preempt-after N`` segments), then resume -- optionally on a
different tiling (needs a mesh with tiles_y*tiles_x devices, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=2``)::

    PYTHONPATH=src python -m repro.launch.sim --grid 4 --law gaussian \\
        --steps 200 --segment-steps 50 --ckpt-dir /tmp/snn_ckpt \\
        --tiles 2x1 --resume --retile

Ensemble: N member realizations vmapped through ONE compiled step,
each member's spikes spooled to its own ``member_NNN/`` stream::

    PYTHONPATH=src python -m repro.launch.sim --grid 4 --law gaussian \\
        --steps 100 --segment-steps 50 --seeds 0,1,2 --record \\
        --ckpt-dir /tmp/snn_ens

Flags parse into the same typed :class:`repro.runtime.SimJobSpec` the
job server (``python -m repro.launch.serve --arch sim``) accepts as
JSON.
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.obs.telemetry import (NULL, Telemetry, enable_json_logging,
                                 set_default)
from repro.perf.trace import jax_profiler_trace, write_chrome_trace
from repro.runtime import JobError, SimDriver, SimJobSpec, build_sim_driver


def enable_sanitizers():
    """Turn on every runtime sanitizer (the ``--sanitize`` flag).

    ``jax_debug_nans`` re-runs any primitive that produced a NaN
    un-jitted and raises at the exact op; ``jax_check_tracer_leaks``
    raises when a tracer escapes its trace (e.g. stashed on ``self``
    from inside a scan body); ``set_thread_asserts`` makes the async
    writers' single-owner contract loud (see ``AsyncWriterThread``).
    CI's resume smoke runs one leg under this mode."""
    import jax

    from repro.checkpoint.store import set_thread_asserts
    jax.config.update("jax_debug_nans", True)
    jax.config.update("jax_check_tracer_leaks", True)
    set_thread_asserts(True)


def parse_tiles(spec):
    if spec is None:
        return None
    try:
        ty, tx = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(
            f"--tiles {spec!r}: expected TYxTX, e.g. 2x1") from None
    return ty, tx


def spec_from_args(args) -> SimJobSpec:
    """CLI flags -> the same typed job spec the job server accepts."""
    stdp = None
    if args.plastic:
        stdp = {k: v for k, v in
                (("a_plus", args.stdp_a_plus),
                 ("a_minus", args.stdp_a_minus)) if v is not None}
        stdp = stdp or None
    seeds = None
    if args.seeds:
        try:
            seeds = tuple(int(s) for s in args.seeds.split(","))
        except ValueError:
            raise SystemExit(
                f"--seeds {args.seeds!r}: expected a comma-separated "
                "list of ints, e.g. 0,1,2") from None
    try:
        return SimJobSpec(
            ckpt_dir=args.ckpt_dir, grid=args.grid,
            n_per_column=args.neurons_per_column, law=args.law,
            seed=args.seed, state_seed=args.state_seed, seeds=seeds,
            t_steps=args.steps, segment_steps=args.segment_steps,
            tiles=parse_tiles(args.tiles),
            ckpt_every=args.ckpt_every, keep=args.keep,
            record=args.record, record_cap=args.record_cap,
            plastic=args.plastic, stdp=stdp,
            resume=args.resume, retile=args.retile,
            preempt_after=args.preempt_after)
    except ValueError as e:
        raise SystemExit(str(e)) from None


def build_driver(args, telemetry: Telemetry = NULL) -> SimDriver:
    try:
        return build_sim_driver(spec_from_args(args), telemetry=telemetry)
    except JobError as e:
        raise SystemExit(str(e)) from None


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--law", default="gaussian",
                    choices=("gaussian", "exponential"))
    ap.add_argument("--grid", type=int, default=8)
    ap.add_argument("--neurons-per-column", type=int, default=60)
    ap.add_argument("--steps", type=int, default=300,
                    help="target sim step (rounded up to whole segments)")
    ap.add_argument("--segment-steps", type=int, default=50)
    ap.add_argument("--tiles", default=None,
                    help="TYxTX tiling (default: host mesh shape)")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="checkpoint every N segments")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0,
                    help="synapse-table realization seed")
    ap.add_argument("--state-seed", type=int, default=None,
                    help="initial-state/noise seed (default: follows "
                         "--seed); lets two runs share one network "
                         "realization under different dynamics")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated member state seeds, e.g. "
                         "0,1,2: run an ensemble of realizations "
                         "through one compiled step, spooled per "
                         "member (mutually exclusive with --state-seed)")
    ap.add_argument("--resume", action="store_true",
                    help="continue from the latest checkpoint")
    ap.add_argument("--retile", action="store_true",
                    help="allow resuming a checkpoint written under a "
                         "different tiling (elastic restart)")
    ap.add_argument("--preempt-after", type=int, default=None,
                    help="simulate a SIGTERM after N segments (testing)")
    ap.add_argument("--metrics-out", default=None,
                    help="write driver metrics_log JSON here")
    ap.add_argument("--record", action="store_true",
                    help="spike observatory: record every (step, neuron) "
                         "spike event and spool it to <ckpt-dir>/spool "
                         "(analyze with python -m repro.launch.analyze)")
    ap.add_argument("--record-cap", type=int, default=None,
                    help="recorder event capacity per shard per segment "
                         "(default: the no-drop bound; overflow is "
                         "counted, never silent)")
    ap.add_argument("--plastic", action="store_true",
                    help="STDP plasticity: weight tables + traces ride "
                         "the scan carry and every checkpoint, and "
                         "elastic retiles relay them by global synapse "
                         "id (a plastic checkpoint only resumes with "
                         "--plastic and identical STDP parameters)")
    ap.add_argument("--stdp-a-plus", type=float, default=None,
                    help="LTP amplitude override (with --plastic)")
    ap.add_argument("--stdp-a-minus", type=float, default=None,
                    help="LTD amplitude override (with --plastic)")
    ap.add_argument("--telemetry-out", default=None,
                    help="append the runtime telemetry stream (spans + "
                         "structured events + per-segment metrics) as "
                         "JSON lines here; a resumed run appends to the "
                         "same file (exactly-once records per process)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace JSON of the run's spans "
                         "here (open in chrome://tracing or "
                         "ui.perfetto.dev; async checkpoint/spool "
                         "writer threads render as their own lanes)")
    ap.add_argument("--trace-dir", default=None,
                    help="opt-in jax.profiler deep profile into this "
                         "directory (XLA/device internals; heavyweight "
                         "-- the span tracer stays cheap and separate)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit repro.* logs as JSON lines (one object "
                         "per record, structured event payload "
                         "attached) instead of human-readable text")
    ap.add_argument("--sanitize", action="store_true",
                    help="debug/CI mode: jax_debug_nans + "
                         "jax_check_tracer_leaks + owning-thread "
                         "assertions on the async writers (slower; "
                         "catches NaNs, leaked tracers and writer "
                         "races at their origin)")
    args = ap.parse_args(argv)

    if args.sanitize:
        enable_sanitizers()
    if args.log_json:
        enable_json_logging()
    tel = NULL
    if args.telemetry_out or args.trace_out:
        tel = Telemetry(jsonl_path=args.telemetry_out)
        set_default(tel)
    driver = build_driver(args, telemetry=tel)
    with jax_profiler_trace(args.trace_dir):
        out = driver.run(args.steps)
    t = int(np.max(np.asarray(out["state"]["t"])))
    rate = driver.firing_rate_hz(out["state"])
    totals = driver.metric_totals(out["state"])
    plastic = plastic_members = None
    if driver.plastic:
        if driver.n_members is None:
            plastic = driver.plastic_summary(out["state"])
        else:
            plastic_members = [driver.plastic_summary(out["state"], member=m)
                               for m in range(driver.n_members)]
            plastic = plastic_members[0]
    extra = (f" plastic_checksum={plastic['weight_checksum'][:12]} "
             f"w_l1_delta={plastic['w_l1_delta']:.4f}"
             if plastic else "")
    if driver.n_members is not None:
        extra += f" members={driver.n_members}"
    print(f"final_step={t} preempted={out['preempted']} "
          f"rate_hz={rate:.2f} "
          f"synapses={driver.table_stats['n_synapses']} "
          f"dropped_events={totals['dropped']:.0f} "
          f"stragglers={len(out['stragglers'])}" + extra)
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        payload = {"final_step": t, "preempted": out["preempted"],
                   "rate_hz": rate,
                   "tiles": list(driver.dist_cfg.tiles),
                   "totals": totals,
                   # active_cap compaction overflow, surfaced explicitly
                   # (nonzero means results undercount synaptic events)
                   "dropped_events": totals["dropped"],
                   "metrics": out["metrics"]}
        if driver.n_members is not None:
            payload["ensemble_seeds"] = list(driver.dist_cfg.ensemble_seeds)
        if plastic_members is not None:
            # per-member learned-weight digests: the ensemble smoke
            # asserts member m's checksum equals the matching solo run
            payload["plastic_members"] = plastic_members
        if driver.spool is not None:
            payload["recording"] = {
                "spooled_events": sum(driver.spool.offsets().values()),
                "recorder_dropped": driver.recorder_dropped,
                "spool_dir": driver.spool.directory}
        if plastic is not None:
            # weight_checksum is tiling-invariant (global synapse ids,
            # canonical order): CI asserts preempt->resume->retile runs
            # against an unpreempted reference with it
            payload["plastic"] = plastic
        with open(args.metrics_out, "w") as f:
            json.dump(payload, f, indent=1)
    if args.telemetry_out:
        tel.flush_jsonl()
    if args.trace_out:
        write_chrome_trace(tel, args.trace_out)
    return out


if __name__ == "__main__":
    main()
