"""Serving launcher: LM batch serving *and* the simulation job service.

Two archs families behind one entry point:

* ``--arch <model>`` (e.g. ``gemma-2b``): the original batched
  prefill + decode LM path, unchanged::

      PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \\
          --batch 4 --prompt-len 32 --gen 16

* ``--arch sim``: a long-lived **simulation job server**.  Clients
  POST typed :class:`~repro.runtime.SimJobSpec` JSON; a single worker
  thread multiplexes the queue onto one resident mesh, and every job
  built with the shared ``sim_cache`` reuses the same compiled segment
  function when only seeds differ (``sim_fingerprint`` normalizes
  them out) -- submit ten 3-member ensembles, compile once::

      PYTHONPATH=src python -m repro.launch.serve --arch sim --port 8321

  Endpoints (JSON over HTTP, loopback by default):

  ``POST /v1/sim/jobs``
      Body: ``SimJobSpec`` JSON.  Returns ``{"job_id", "status"}``.
      Malformed/unknown-field specs are a 400 with the validation
      error, not a silent default.
  ``GET /v1/sim/jobs``
      All jobs, queue order.
  ``GET /v1/sim/jobs/<id>``
      One job: status (``queued|running|done|failed``), spec, result
      (final step, rates, per-member plastic digests, compiled-step
      count) or error.
  ``GET /v1/sim/jobs/<id>/stream[?cursor=<json>]``
      Incremental spike readout while the job runs: serves the records
      appended to the job's spool since ``cursor`` (the per-log record
      offsets returned by the previous call -- the same offsets shape
      the exactly-once checkpoint contract uses), grouped per ensemble
      member and step-ordered.  Stateless on the server: each client
      owns its cursor, so any number of clients stream concurrently at
      their own pace.  Returns ``{"streams", "cursor", "status",
      "done"}``; pass ``cursor`` back verbatim to get only deltas.

The server never runs jax in HTTP handler threads -- simulation
happens on the one worker thread (the mesh's owner); handlers only
read spool files, which the append-only/whole-record contract makes
safe under concurrent writes.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import queue
import threading
import time
import urllib.parse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

import numpy as np

from repro.obs.telemetry import NULL, Telemetry
from repro.runtime.jobs import JobError, SimJobSpec


# --------------------------------------------------------------------------
# LM serving path (unchanged behaviour)
# --------------------------------------------------------------------------

def serve_batch(arch: str, batch: int, prompt_len: int, gen: int,
                mesh=None, seed: int = 0, greedy: bool = True):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.models.transformer import init_decode_state, init_model
    from repro.parallel.sharding import rules_for_mesh

    cfg = get_reduced(arch)
    mesh = mesh or make_host_mesh()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ov = {}
    if cfg.n_heads % axes.get("model", 1):
        ov["heads"] = None
    if cfg.d_ff % axes.get("model", 1) or not cfg.d_ff:
        ov["mlp"] = None
    if cfg.n_experts and cfg.n_experts % axes.get("model", 1):
        ov["experts"] = None
    max_len = prompt_len + gen
    if max_len % axes.get("model", 1):
        ov["kv_seq"] = None
    if batch % (axes.get("data", 1) * axes.get("pod", 1)):
        ov["batch"] = None
    rules = rules_for_mesh(mesh, **ov)

    key = jax.random.PRNGKey(seed)
    params, _ = init_model(key, cfg)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.encoder_seq:
        from repro.models.frontends import STUB_WIDTH
        batch_in["frames"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.encoder_seq, STUB_WIDTH)), jnp.dtype(cfg.dtype))
    if cfg.n_patches:
        from repro.models.frontends import STUB_WIDTH
        batch_in["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_patches, STUB_WIDTH)), jnp.dtype(cfg.dtype))

    state = init_decode_state(cfg, batch, max_len)
    prefill = jax.jit(M.make_prefill(cfg, rules))
    serve_step = jax.jit(M.make_serve_step(cfg, rules))

    t0 = time.perf_counter()
    logits, state = prefill(params, batch_in, state)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen):
        toks.append(np.asarray(tok))
        logits, state = serve_step(params, state, tok,
                                   jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    out_tokens = np.concatenate(toks, axis=1)
    return {
        "tokens": out_tokens,
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / gen,
        "config": cfg.name,
    }


# --------------------------------------------------------------------------
# Simulation job service
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SimJob:
    """One queued/running/finished job: the spec plus its lifecycle."""
    job_id: str
    spec: SimJobSpec
    status: str = "queued"        # queued -> running -> done | failed
    error: Optional[str] = None
    result: Optional[dict] = None

    def public(self) -> dict:
        return {"job_id": self.job_id, "status": self.status,
                "spec": self.spec.job_meta(), "error": self.error,
                "result": self.result}


class SimJobServer:
    """Queue of :class:`SimJobSpec` jobs on one resident mesh.

    One worker thread owns the mesh and runs jobs in submission order;
    ``sim_cache`` is shared across every job it builds, so jobs whose
    traced program is identical (same grid/law/tiling/ensemble width,
    any seeds -- see ``sim_fingerprint``) reuse one compiled step.
    ``compiled_steps()`` exposes the cache size: the CI smoke asserts
    it stays 1 across a multi-job ensemble session.
    """

    def __init__(self, mesh=None, telemetry: Telemetry = NULL):
        if mesh is None:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh()
        self.mesh = mesh
        self.tel = telemetry
        self.sim_cache: dict = {}
        self._jobs: Dict[str, SimJob] = {}
        self._order = []
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._lock = threading.Lock()
        self._n = 0
        self._worker = threading.Thread(target=self._run_jobs,
                                        name="sim-job-worker", daemon=True)
        self._worker.start()

    # ---- submission/introspection (any thread) -----------------------
    def submit(self, spec: SimJobSpec) -> str:
        with self._lock:
            self._n += 1
            job_id = f"job-{self._n:04d}"
            self._jobs[job_id] = SimJob(job_id, spec)
            self._order.append(job_id)
        self._queue.put(job_id)
        return job_id

    def job(self, job_id: str) -> Optional[SimJob]:
        with self._lock:
            return self._jobs.get(job_id)

    def jobs(self):
        with self._lock:
            return [self._jobs[j] for j in self._order]

    def compiled_steps(self) -> int:
        return len(self.sim_cache)

    def wait(self, job_id: str, timeout: float = 600.0) -> SimJob:
        """Block until a job leaves the queue/running states."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            j = self.job(job_id)
            if j is None:
                raise KeyError(job_id)
            if j.status in ("done", "failed"):
                return j
            time.sleep(0.05)
        raise TimeoutError(f"{job_id} still {self.job(job_id).status} "
                           f"after {timeout}s")

    def shutdown(self):
        self._queue.put(None)
        self._worker.join(timeout=60)

    # ---- the worker thread: owns the mesh and all jax work -----------
    def _run_jobs(self):
        from repro.runtime.jobs import build_sim_driver
        while True:
            job_id = self._queue.get()
            if job_id is None:
                return
            job = self.job(job_id)
            job.status = "running"
            try:
                driver = build_sim_driver(job.spec, mesh=self.mesh,
                                          telemetry=self.tel,
                                          sim_cache=self.sim_cache)
                out = driver.run(job.spec.t_steps)
                job.result = self._summarize(driver, out)
                job.status = "done"
            except Exception as e:           # a bad job must not kill
                job.error = f"{type(e).__name__}: {e}"   # the server
                job.status = "failed"

    def _summarize(self, driver, out) -> dict:
        state = out["state"]
        res = {
            "final_step": int(np.max(np.asarray(state["t"]))),
            "preempted": bool(out["preempted"]),
            "rate_hz": float(driver.firing_rate_hz(state)),
            "totals": driver.metric_totals(state),
            "n_synapses": int(driver.table_stats["n_synapses"]),
            "members": driver.n_members,
            "compiled_steps": driver.compiled_step_cache_size(),
            "server_compiled_steps": self.compiled_steps(),
        }
        if driver.spool is not None:
            res["spool_dir"] = driver.spool.directory
            res["spooled_events"] = sum(driver.spool.offsets().values())
        if driver.plastic:
            if driver.n_members is None:
                res["plastic"] = driver.plastic_summary(state)
            else:
                res["plastic_members"] = [
                    driver.plastic_summary(state, member=m)
                    for m in range(driver.n_members)]
        return res

    # ---- streaming read side (HTTP handler threads) ------------------
    def stream(self, job_id: str,
               cursor: Optional[Dict[str, int]] = None) -> dict:
        """Spool records appended since ``cursor``, grouped per member.

        Purely file-backed -- no lock against the worker is needed
        because the logs are append-only and ``read_new_events`` reads
        whole records below the current file size only.
        """
        from repro.obs.spool import read_new_events
        job = self.job(job_id)
        if job is None:
            raise KeyError(job_id)
        if not job.spec.record:
            raise JobError(f"{job_id} was submitted with record=false; "
                           "there is no spike stream to read")
        try:
            new, new_cursor = read_new_events(job.spec.ckpt_dir, cursor)
        except FileNotFoundError:
            # queued job whose spool does not exist yet: empty delta
            new, new_cursor = {}, dict(cursor or {})
        streams: Dict[str, dict] = {}
        for rel, arr in new.items():
            member = rel.split("/", 1)[0] if "/" in rel else "solo"
            g = streams.setdefault(member, {"step": [], "gid": []})
            g["step"].append(arr["step"])
            g["gid"].append(arr["gid"])
        for member, g in streams.items():
            step = np.concatenate(g["step"])
            gid = np.concatenate(g["gid"])
            order = np.lexsort((gid, step))
            streams[member] = {"step": step[order].tolist(),
                               "gid": gid[order].tolist(),
                               "n_new": int(step.size)}
        return {"job_id": job_id, "status": job.status,
                "done": job.status in ("done", "failed"),
                "streams": streams, "cursor": new_cursor}


def _make_handler(server: SimJobServer):
    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):        # quiet; telemetry has spans
            pass

        def _send(self, code: int, payload: dict):
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_POST(self):
            url = urllib.parse.urlparse(self.path)
            if url.path != "/v1/sim/jobs":
                return self._send(404, {"error": f"no route {url.path}"})
            n = int(self.headers.get("Content-Length", 0))
            raw = self.rfile.read(n).decode()
            try:
                spec = SimJobSpec.from_json(raw)
            except (ValueError, TypeError) as e:
                return self._send(400, {"error": str(e)})
            job_id = server.submit(spec)
            self._send(200, {"job_id": job_id, "status": "queued"})

        def do_GET(self):
            url = urllib.parse.urlparse(self.path)
            parts = [p for p in url.path.split("/") if p]
            if parts[:3] != ["v1", "sim", "jobs"]:
                return self._send(404, {"error": f"no route {url.path}"})
            if len(parts) == 3:
                return self._send(200, {"jobs": [j.public()
                                                 for j in server.jobs()]})
            job_id = parts[3]
            if server.job(job_id) is None:
                return self._send(404, {"error": f"unknown job {job_id}"})
            if len(parts) == 4:
                return self._send(200, server.job(job_id).public())
            if len(parts) == 5 and parts[4] == "stream":
                q = urllib.parse.parse_qs(url.query)
                cursor = None
                if "cursor" in q:
                    try:
                        cursor = json.loads(q["cursor"][0])
                    except ValueError as e:
                        return self._send(400, {"error": f"cursor: {e}"})
                try:
                    return self._send(200, server.stream(job_id, cursor))
                except JobError as e:
                    return self._send(400, {"error": str(e)})
            self._send(404, {"error": f"no route {url.path}"})

    return Handler


def serve_sim(host: str = "127.0.0.1", port: int = 0, mesh=None,
              telemetry: Telemetry = NULL):
    """Start the job server + its HTTP front.  Returns ``(httpd,
    jobs)``; the HTTP server runs on a daemon thread, ``httpd.shutdown()``
    then ``jobs.shutdown()`` stops both."""
    jobs = SimJobServer(mesh=mesh, telemetry=telemetry)
    httpd = ThreadingHTTPServer((host, port), _make_handler(jobs))
    httpd.daemon_threads = True
    t = threading.Thread(target=httpd.serve_forever, name="sim-http",
                         daemon=True)
    t.start()
    return httpd, jobs


def main(argv=None):
    from repro.configs import ARCH_NAMES

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="gemma-2b",
                    help="'sim' for the simulation job server, or an "
                         "LM arch name")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address for --arch sim (loopback only "
                         "by default; the service is unauthenticated)")
    ap.add_argument("--port", type=int, default=8321,
                    help="port for --arch sim (0 picks a free one)")
    args = ap.parse_args(argv)

    choices = ("sim",) + tuple(ARCH_NAMES)
    if args.arch == "sim":
        httpd, jobs = serve_sim(args.host, args.port)
        host, port = httpd.server_address[:2]
        print(f"sim job server on http://{host}:{port} "
              f"(POST /v1/sim/jobs)", flush=True)
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            httpd.shutdown()
            jobs.shutdown()
        return
    if args.arch not in ARCH_NAMES:
        # an unknown arch used to die with a bare KeyError from the
        # config registry -- be explicit, and list what would work
        raise SystemExit(
            f"--arch {args.arch!r}: unknown arch; choices: "
            + ", ".join(choices))
    out = serve_batch(args.arch, args.batch, args.prompt_len, args.gen)
    print(f"{out['config']}: prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_s_per_token']*1e3:.2f} ms/token, "
          f"generated shape {out['tokens'].shape}")


if __name__ == "__main__":
    main()
