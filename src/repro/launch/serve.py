"""Runnable serving launcher: batched prefill + decode on host devices.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.transformer import init_decode_state, init_model
from repro.parallel.sharding import rules_for_mesh


def serve_batch(arch: str, batch: int, prompt_len: int, gen: int,
                mesh=None, seed: int = 0, greedy: bool = True):
    cfg = get_reduced(arch)
    mesh = mesh or make_host_mesh()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ov = {}
    if cfg.n_heads % axes.get("model", 1):
        ov["heads"] = None
    if cfg.d_ff % axes.get("model", 1) or not cfg.d_ff:
        ov["mlp"] = None
    if cfg.n_experts and cfg.n_experts % axes.get("model", 1):
        ov["experts"] = None
    max_len = prompt_len + gen
    if max_len % axes.get("model", 1):
        ov["kv_seq"] = None
    if batch % (axes.get("data", 1) * axes.get("pod", 1)):
        ov["batch"] = None
    rules = rules_for_mesh(mesh, **ov)

    key = jax.random.PRNGKey(seed)
    params, _ = init_model(key, cfg)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (batch, prompt_len)).astype(np.int32)
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.encoder_seq:
        from repro.models.frontends import STUB_WIDTH
        batch_in["frames"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.encoder_seq, STUB_WIDTH)), jnp.dtype(cfg.dtype))
    if cfg.n_patches:
        from repro.models.frontends import STUB_WIDTH
        batch_in["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (batch, cfg.n_patches, STUB_WIDTH)), jnp.dtype(cfg.dtype))

    state = init_decode_state(cfg, batch, max_len)
    prefill = jax.jit(M.make_prefill(cfg, rules))
    serve_step = jax.jit(M.make_serve_step(cfg, rules))

    t0 = time.perf_counter()
    logits, state = prefill(params, batch_in, state)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen):
        toks.append(np.asarray(tok))
        logits, state = serve_step(params, state, tok,
                                   jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    out_tokens = np.concatenate(toks, axis=1)
    return {
        "tokens": out_tokens,
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / gen,
        "config": cfg.name,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve_batch(args.arch, args.batch, args.prompt_len, args.gen)
    print(f"{out['config']}: prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_s_per_token']*1e3:.2f} ms/token, "
          f"generated shape {out['tokens'].shape}")


if __name__ == "__main__":
    main()
