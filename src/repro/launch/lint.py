"""repro-lint CLI: run the repo's contract analyzer.

Static passes over the source tree enforcing the invariants the
simulator's guarantees rest on -- tracer purity, dtype/overflow
bounds, donation discipline, checkpoint-meta drift coverage, pytree
aux hygiene, and Pallas kernel geometry (one pass each; see
``repro.analysis``).  CI runs this over ``src tests benchmarks
examples`` and fails on any finding.

Usage::

    PYTHONPATH=src python -m repro.launch.lint                # src only
    PYTHONPATH=src python -m repro.launch.lint src tests benchmarks
    PYTHONPATH=src python -m repro.launch.lint --select donation src
    PYTHONPATH=src python -m repro.launch.lint --list

Suppress a single finding with a reasoned inline pragma::

    x = np.zeros(n, dtype=np.float64)  # repro-lint: ignore[dtype-bounds] host analytic

or a whole file with ``# repro-lint: ignore-file[<check>] <reason>``.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import ALL_CHECKERS, Project


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.lint",
        description="repo-specific contract analyzer (repro-lint)")
    p.add_argument("paths", nargs="*", default=None,
                   help="files or directories to analyze (default: src)")
    p.add_argument("--select", action="append", default=None,
                   metavar="CHECK",
                   help="run only the named check(s); repeatable")
    p.add_argument("--list", action="store_true",
                   help="list available checks and exit")
    p.add_argument("--format", choices=("text", "json"), default="text")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    names = {c.name: c for c in ALL_CHECKERS}

    if args.list:
        for name, cls in sorted(names.items()):
            print(f"{name:16s} {cls.description}")
        return 0

    selected = args.select or sorted(names)
    unknown = [s for s in selected if s not in names]
    if unknown:
        print(f"unknown check(s): {', '.join(unknown)} "
              f"(have: {', '.join(sorted(names))})", file=sys.stderr)
        return 2

    project = Project.from_paths(args.paths or ["src"])
    findings = project.run([names[s]() for s in selected])

    if args.format == "json":
        print(json.dumps(
            [{"path": f.path, "line": f.line, "check": f.check,
              "message": f.message} for f in findings], indent=1))
    else:
        for f in findings:
            print(f)
        n_files = len(project.modules)
        n_traced = len(project.traced)
        status = (f"{len(findings)} finding(s)" if findings
                  else "clean")
        print(f"repro-lint: {status} -- {n_files} file(s), "
              f"{len(selected)} check(s), {n_traced} traced function(s)",
              file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
