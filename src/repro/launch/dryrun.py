import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x shape x mesh) cell
lowers, SPMD-partitions, and compiles on the production mesh.

The two lines above MUST run before any other import (jax locks the
device count at first init); 512 placeholder host devices back both the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh.

Per cell this script:
  1. builds the arch config + logical sharding rules for the mesh;
  2. assembles abstract inputs (ShapeDtypeStructs -- zero allocation):
     params (+ optimizer state + batch) for train cells, params (+
     decode state + token) for decode cells;
  3. ``jax.jit(step, in_shardings=...).lower(...).compile()``;
  4. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()``, and the loop-aware HLO costs (FLOPs / bytes /
     collective wire bytes) into ``results/dryrun/<cell>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all [--force]
  python -m repro.launch.dryrun --snn          # paper's own configs
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

RESULTS = os.path.join(os.path.dirname(__file__),
                       "..", "..", "..", "results", "dryrun")


def _rules_for(cfg, mesh):
    """Divisibility-aware logical rules for this arch on this mesh."""
    from repro.parallel.sharding import rules_for_mesh
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = axes.get("model", 1)
    dp = axes.get("data", 1) * axes.get("pod", 1)
    ov = {}
    ov["heads"] = "model" if cfg.n_heads % model == 0 else None
    ov["mlp"] = "model" if cfg.d_ff and cfg.d_ff % model == 0 else None
    ov["experts"] = "model" if cfg.n_experts % model == 0 and \
        cfg.n_experts else None
    ov["d_inner"] = "model" if cfg.d_inner % model == 0 else None
    ov["vocab"] = "model" if cfg.padded_vocab % model == 0 else None
    return rules_for_mesh(mesh, **ov), dp


def _batch_rules(rules, shape, dp):
    import dataclasses as dc
    if shape.global_batch % dp:
        # e.g. long_500k (B=1): replicate batch, model axis still TPs
        return dc.replace(rules, batch=None)
    return rules


# ---------------------------------------------------------------------------
# Perf-iteration variants (section Perf of EXPERIMENTS.md): each entry is a
# named set of config/step overrides applied on top of the baseline.
# ---------------------------------------------------------------------------

VARIANTS = {
    # hillclimb 1 (worst roofline fraction: small models whose 12/8
    # heads don't divide the model axis, replicating attention 16x):
    # pad query/kv heads to 16 with zeroed extra out-proj rows --
    # function-exact (tests/test_variants.py), shards attention 16-way.
    "padded_heads": {"pad_heads_to": 16},
    # hillclimb 2 (most collective-bound: kimi train): fewer microbatch
    # loops -> 4x fewer FSDP weight all-gathers + grad reductions
    "micro1": {"microbatches": 1},
    "micro2": {"microbatches": 2},
    "micro4": {"microbatches": 4},
    # attention chunk-shape sweeps (memory term knob)
    "chunk512": {"attn_chunk_q": 512, "attn_chunk_k": 512},
    "chunk2048": {"attn_chunk_q": 2048, "attn_chunk_k": 2048},
    # SNN (paper-representative): f32 spike payload (paper-faithful
    # AER-ish baseline) vs 1-bit bitmap; whole-tile vs exact-strip halo
    "snn_f32_spikes": {"pack_spikes": False},
    "snn_block_halo": {"halo_mode": "block", "pack_spikes": False},
    "snn_packed": {"pack_spikes": True},
    # right-size the event-compaction capacity to the law's observed
    # rate (paper: exponential ~38 Hz) x1.5 headroom instead of
    # 100 Hz x8 -- delivery gather shrinks ~9x; drops are counted
    "snn_tight_caps": {"pack_spikes": True, "rate_cap_hz": 60.0,
                       "cap_headroom": 1.5},
    # + bf16 synapse weights: (tgt,w,dslot) row entry 9->7 bytes
    "snn_bf16_w": {"pack_spikes": True, "rate_cap_hz": 60.0,
                   "cap_headroom": 1.5, "weight_dtype": "bfloat16"},
    # combined LM variants
    "padded_chunk512": {"pad_heads_to": 16, "attn_chunk_q": 512,
                        "attn_chunk_k": 512},
    "padded_chunk2048": {"pad_heads_to": 16, "attn_chunk_q": 2048,
                         "attn_chunk_k": 2048},
    "micro2_chunk512": {"microbatches": 2, "attn_chunk_q": 512,
                        "attn_chunk_k": 512},
}


def _apply_cfg_variant(cfg, overrides: dict):
    import dataclasses as dc
    cfg_fields = {f.name for f in dc.fields(cfg)}
    patch = {}
    if overrides.get("pad_heads_to"):
        m = overrides["pad_heads_to"]
        h = -(-cfg.n_heads // m) * m
        kv = cfg.n_kv_heads if h % cfg.n_kv_heads == 0 else \
            -(-cfg.n_kv_heads // m) * m
        hd = cfg.resolved_head_dim
        patch.update(n_heads=h, n_kv_heads=kv, head_dim=hd)
    for k, v in overrides.items():
        if k in cfg_fields:
            patch[k] = v
    return dc.replace(cfg, **patch) if patch else cfg


def build_cell(arch: str, shape_name: str, mesh, variant: str | None = None):
    """Returns (step_fn, abstract_args, in_shardings, donate, meta)."""
    from repro.configs import get_config
    from repro.models.config import SHAPES
    from repro.models import model as M
    from repro.optim import adamw, adafactor, warmup_cosine

    cfg = get_config(arch)
    overrides = VARIANTS.get(variant, {}) if variant else {}
    cfg = _apply_cfg_variant(cfg, overrides)
    shape = SHAPES[shape_name]
    rules, dp = _rules_for(cfg, mesh)
    rules = _batch_rules(rules, shape, dp)

    params_abs, specs = M.abstract_params(cfg)
    param_sh = rules.shardings(specs, mesh)
    meta = {"params": int(sum(l.size for l in jax.tree.leaves(params_abs))),
            "active_params": cfg.active_param_count()}

    if shape.kind == "decode":
        step = M.make_serve_step(cfg, rules)
        state_abs = M.abstract_decode_state(cfg, shape)
        state_specs = M.decode_state_specs(cfg, shape)
        state_sh = rules.shardings(state_specs, mesh)
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        from jax.sharding import NamedSharding, PartitionSpec as P
        tok_sh = NamedSharding(mesh, rules.pspec("batch", None))
        pos_sh = NamedSharding(mesh, P())
        return (step, (params_abs, state_abs, token, pos),
                (param_sh, state_sh, tok_sh, pos_sh), (1,), meta)

    # train / prefill
    big = meta["params"] > 15e9          # adafactor: factored moments
    opt = adafactor(warmup_cosine(1e-4, 100, 10000)) if big else \
        adamw(warmup_cosine(3e-4, 100, 10000))
    batch_abs = M.input_specs(cfg, shape)
    from jax.sharding import NamedSharding
    batch_sh = {k: NamedSharding(mesh, rules.pspec(
        "batch", *([None] * (len(v.shape) - 1))))
        for k, v in batch_abs.items()}

    if shape.kind == "prefill":
        prefill = M.make_prefill(cfg, rules)
        state_abs = M.abstract_decode_state(cfg, shape)
        state_specs = M.decode_state_specs(cfg, shape)
        state_sh = rules.shardings(state_specs, mesh)
        return (prefill, (params_abs, batch_abs, state_abs),
                (param_sh, batch_sh, state_sh), (2,), meta)

    # gradient accumulation: bound saved layer-boundary activations
    # (per-microbatch tokens ~ 64k local) -- the memory knob at scale
    p_count = meta["params"]
    micro = 8 if p_count > 15e9 else (4 if p_count > 4e9 else 1)
    micro = overrides.get("microbatches", micro)
    meta["microbatches"] = micro
    step = M.make_train_step(cfg, rules, opt, microbatches=micro,
                             param_shardings=param_sh)
    opt_abs = opt.abstract_state(params_abs)
    opt_specs = opt.state_specs(specs)
    opt_sh = rules.shardings(opt_specs, mesh)
    return (step, (params_abs, opt_abs, batch_abs),
            (param_sh, opt_sh, batch_sh), (0, 1), meta)


def build_snn_cell(case_name: str, mesh, variant: str | None = None):
    from repro.configs.snn import CASES
    from repro.core.dist_engine import (DistConfig, SimInputs,
                                        abstract_dist_inputs, make_sim_fn)
    case = CASES[case_name]
    overrides = VARIANTS.get(variant, {}) if variant else {}
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ty = axes.get("pod", 1) * axes.get("data", 1)
    tx = axes.get("model", 1)
    eng_kw = {k: v for k, v in overrides.items()
              if k in ("rate_cap_hz", "cap_headroom", "d_ring", "mode",
                       "weight_dtype")}
    ecfg = case.engine_config(ty, tx, **eng_kw)
    dcfg = DistConfig(
        engine=ecfg,
        axis_y=("pod", "data") if "pod" in axes else "data",
        axis_x="model",
        halo_mode=overrides.get("halo_mode", "strip"),
        pack_spikes=overrides.get("pack_spikes", True))
    sim = make_sim_fn(dcfg, mesh, n_steps=10)
    state_abs, tables_abs = abstract_dist_inputs(dcfg)
    spec = ecfg.spec()
    meta = {"neurons": case.grid[0] * case.grid[1] * case.n_per_column,
            "synapses_per_shard": spec.expected_synapses(),
            "table_bytes_per_shard": spec.table_bytes(),
            "halo_radius": ecfg.law.radius,
            "tiles": (ty, tx)}
    return sim, (state_abs, SimInputs(tables=tables_abs)), None, (0,), meta


def analytic_memory(abstract_args, shardings, mesh) -> dict:
    """Exact per-device bytes of every jit INPUT (params, opt state,
    decode state, batch) from the abstract shapes and their
    NamedShardings.  This is the ground-truth state footprint on the
    bf16-native TPU target: XLA:CPU's memory_analysis() overstates
    bf16 models (float-normalization materializes f32 shadows of bf16
    arithmetic, and CPU fusion is weaker), so both numbers are
    reported.  Transient activations come on top -- bounded by the
    microbatch/remat policy."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(abstract_args),
                        jax.tree.leaves(shardings, is_leaf=lambda x:
                                        hasattr(x, "spec"))):
        n_bytes = 1
        for d in leaf.shape:
            n_bytes *= d
        n_bytes *= leaf.dtype.itemsize
        try:
            n_shards = len(set(map(tuple, sh.devices_indices_map(
                leaf.shape).values())))
        except Exception:
            n_shards = 1
        total += n_bytes // max(n_shards, 1)
    return {"input_state_bytes_per_device": int(total)}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = RESULTS, force: bool = False,
             variant: str | None = None) -> dict:
    from repro.launch.mesh import make_production_mesh, mesh_chips
    from repro.perf.hlo_analysis import analyze_hlo
    from repro.perf.roofline import model_flops, roofline_terms

    cell_id = f"{arch}__{shape_name}__{mesh_kind}"
    if variant:
        cell_id += f"__{variant}"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        if arch.startswith("snn-"):
            fn, args, shardings, donate, meta = build_snn_cell(
                arch, mesh, variant)
            jitted = fn  # make_sim_fn already jits (shard_map in_specs)
            lowered = jitted.lower(*args)
        else:
            fn, args, shardings, donate, meta = build_cell(
                arch, shape_name, mesh, variant)
            jitted = jax.jit(fn, in_shardings=shardings,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        }
        if shardings is not None:
            mem_d.update(analytic_memory(args, shardings, mesh))
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, list):       # older jax returns [dict]
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        costs = analyze_hlo(hlo)

        if arch.startswith("snn-"):
            mflops = 0.0
        else:
            from repro.configs import get_config
            from repro.models.config import SHAPES
            mflops = model_flops(get_config(arch), SHAPES[shape_name])
        rep = roofline_terms(arch, shape_name, mesh_kind,
                             mesh_chips(mesh), costs, mflops,
                             peak_bytes=mem_d["peak_bytes"])
        kernelized = None
        if not arch.startswith("snn-"):
            from repro.configs import get_config
            from repro.models.config import SHAPES
            from repro.perf.attention_credit import chunk_traffic_bytes
            from repro.perf.roofline import HW
            cfg_v = _apply_cfg_variant(
                get_config(arch), VARIANTS.get(variant, {}) if variant
                else {})
            credit = chunk_traffic_bytes(
                cfg_v, SHAPES[shape_name], chips=mesh_chips(mesh),
                microbatches=meta.get("microbatches", 1))
            kernelized = {
                "attn_chunk_bytes": credit,
                "memory_s_flash": max(
                    costs.bytes - credit, 0.0) / HW().hbm_bw,
            }
        out = {
            "cell": cell_id, "ok": True,
            "kernelized": kernelized,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "meta": meta, "memory": mem_d,
            "xla_cost": {k: ca.get(k) for k in
                         ("flops", "bytes accessed")},
            "roofline": rep.to_dict(),
            "hlo_bytes_len": len(hlo),
        }
    except Exception as e:  # noqa: BLE001 - recorded as cell failure
        out = {"cell": cell_id, "ok": False, "error": repr(e),
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(out, f, indent=1, default=str)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--snn", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all or (not args.arch and not args.snn):
        from repro.configs import all_cells
        cells = [(a, s.name) for a, s in all_cells()]
    elif args.arch and not args.arch.startswith("snn-"):
        from repro.configs import shape_cells
        shapes = [args.shape] if args.shape else \
            [s.name for s in shape_cells(args.arch)]
        cells = [(args.arch, s) for s in shapes]
    if args.snn:
        from repro.configs.snn import CASES
        cells += [(c, "sim") for c in CASES]
    if args.arch and args.arch.startswith("snn-"):
        cells = [(args.arch, "sim")]

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            r = run_cell(arch, shape, mk, out_dir=args.out,
                         force=args.force, variant=args.variant)
            status = "OK " if r["ok"] else "FAIL"
            extra = ""
            if r["ok"]:
                rl = r["roofline"]
                extra = (f"dom={rl['dominant']:10s} "
                         f"peakGB={r['memory']['peak_bytes']/2**30:7.2f} "
                         f"compile={r['compile_s']:6.1f}s")
            else:
                failures += 1
                extra = r["error"][:120]
            print(f"[{status}] {arch:24s} {shape:12s} {mk:6s} {extra}",
                  flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
