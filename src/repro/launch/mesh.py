"""Production mesh definition.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device query).

  single pod:  (16, 16)    axes ("data", "model")   = 256 chips
  multi  pod:  (2, 16, 16) axes ("pod", "data", "model") = 512 chips

LM models: ("pod","data") shard batch (DP; FSDP stays intra-pod),
"model" shards heads/ffn/experts/vocab/cache-seq (TP/EP).  The SNN maps
("pod","data") x "model" to the spatial (y, x) tile grid of cortical
columns -- the pod axis adds more tile rows, like adding MPI ranks.
"""

from __future__ import annotations

import jax

from ..parallel.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    n = len(jax.devices())
    if shape is None:
        m = 1
        while m * 2 <= n // (m * 2) * (m * 2) and (m * 2) ** 2 <= n:
            m *= 2
        while n % m:
            m //= 2
        shape = (n // m, m)
    return make_mesh(shape, axes)


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
