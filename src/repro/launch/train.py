"""Runnable training launcher (reduced configs on host devices).

The full-scale path is exercised by the dry-run; this driver actually
*runs*: it builds a reduced ``--arch`` variant (or a ~100M custom
config), shards it over the host mesh, and trains with the
fault-tolerant runtime (checkpoints, retry, straggler watchdog).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \\
      --steps 200 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax
from jax.sharding import NamedSharding

from repro.configs import get_reduced
from repro.data.pipeline import LMBatchPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.config import ShapeConfig
from repro.models.transformer import init_model
from repro.optim import adamw, warmup_cosine
from repro.parallel.sharding import rules_for_mesh
from repro.runtime import DriverConfig, TrainDriver


def build_trainer(arch: str, batch: int, seq: int, steps: int,
                  ckpt_dir: str, mesh=None, seed: int = 0,
                  fault_hook=None, lr: float = 3e-4,
                  ckpt_every: int = 50):
    cfg = get_reduced(arch)
    mesh = mesh or make_host_mesh()
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ov = {}
    if cfg.n_heads % axes.get("model", 1):
        ov["heads"] = None
    if cfg.d_ff % axes.get("model", 1) or not cfg.d_ff:
        ov["mlp"] = None
    if cfg.n_experts and cfg.n_experts % axes.get("model", 1):
        ov["experts"] = None
    rules = rules_for_mesh(mesh, **ov)
    shape = ShapeConfig("custom", seq, batch, "train")

    params, specs = init_model(jax.random.PRNGKey(seed), cfg)
    param_sh = rules.shardings(specs, mesh)
    opt = adamw(warmup_cosine(lr, min(50, steps // 4 + 1), steps))
    opt_specs = opt.state_specs(specs)
    opt_sh = rules.shardings(opt_specs, mesh)
    params = jax.device_put(params, param_sh)
    opt_state = jax.device_put(opt.init(params), opt_sh)

    pipe = LMBatchPipeline(cfg=cfg, shape=shape, seed=seed)
    step_fn = M.make_train_step(cfg, rules, opt, param_shardings=param_sh)
    bspec = {}

    def batch_fn(i):
        b = pipe.batch(i)
        return {k: jax.device_put(v, NamedSharding(
            mesh, rules.pspec("batch", *([None] * (v.ndim - 1)))))
            for k, v in b.items()}

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    def driver_step(state, batch):
        params, opt_state = state
        params, opt_state, out = jit_step(params, opt_state, batch)
        return (params, opt_state), out

    # the train step donates its buffers, so a retry-restore before the
    # first checkpoint must REBUILD the state (same seed -> identical
    # params), never hand back the donated originals
    first_state = [(params, opt_state)]

    def init_state_fn():
        if first_state:
            return first_state.pop()
        p, _ = init_model(jax.random.PRNGKey(seed), cfg)
        p = jax.device_put(p, param_sh)
        return p, jax.device_put(opt.init(p), opt_sh)

    driver = TrainDriver(
        DriverConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every),
        step_fn=driver_step, batch_fn=batch_fn,
        init_state_fn=init_state_fn,
        abstract_state=jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            (params, opt_state)),
        fault_hook=fault_hook)
    return driver, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    driver, cfg = build_trainer(args.arch, args.batch, args.seq,
                                args.steps, args.ckpt_dir, lr=args.lr)
    out = driver.run(args.steps)
    losses = [m["loss"] for m in out["metrics"]]
    print(f"arch={cfg.name} steps={out['final_step']} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"stragglers={len(out['stragglers'])}")
    return out


if __name__ == "__main__":
    main()
