"""Metrics of the paper: cost per synaptic event, bytes per synapse,
firing rates, and the analytic strong-scaling model used to project the
measured reduced-scale behaviour to the full problem sizes.

The paper's headline unit is::

    cost = elapsed_sec / (simulated_sec * total_synapses * firing_rate)

i.e. seconds of wall clock per *synaptic event* (one spike crossing one
synapse).  It makes runs of different size/rate directly comparable
(paper Figs. 1-2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from .connectivity import ConnectivityLaw, EXTERNAL_RATE_HZ
from .grid import TileDecomposition
from .synapses import SynapseTableSpec


def cost_per_synaptic_event(elapsed_s: float, simulated_s: float,
                            total_synapses: float, rate_hz: float) -> float:
    """Paper's Figure-1 metric (elapsed sec per synaptic event)."""
    events = simulated_s * total_synapses * rate_hz
    return elapsed_s / max(events, 1e-30)


def speedup_efficiency(cost_1: float, cost_n: float, n: int) -> float:
    """Fraction of ideal strong-scaling speedup reached at n processes."""
    return (cost_1 / cost_n) / n


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ---------------------------------------------------------------------------
# Memory accounting (paper Fig. 3: bytes / synapse)
# ---------------------------------------------------------------------------

def shard_memory_bytes(spec: SynapseTableSpec, storage=None,
                       plastic: bool = False,
                       recorder_capacity: int = 0) -> dict:
    """Exact per-shard buffer bytes of everything the engine holds live.

    ``storage`` (a ``TableStorage``) sizes the synapse tables -- pass a
    materialized (compressed) descriptor to account realized caps and
    narrow dtypes; ``None`` uses the spec's analytic storage.  With
    ``plastic=True`` the STDP carry is added: the live weight tiers in
    the scan state, the local-tier pre-trace (halo replicas are
    exchanged per step, never stored), per-target post-traces, and the
    inverse (target -> synapse slot) index (``cap_pad=1.3`` over the
    mean in-degree, as built by ``core.stdp.build_inverse_index``).
    The static tables' weight leaves are then folded down to the int8
    plastic mask (``dist_engine.fold_plastic_tables``): the carry is
    the single full-width weight copy, and the ``tables`` term shrinks
    accordingly.  ``recorder_capacity`` adds the spike observatory's
    per-segment event buffer (step + gid per slot, plus count/dropped
    scalars)."""
    from .synapses import np_dtype
    n_local = spec.n_local
    if storage is None:
        storage = spec.storage()
    table = spec.table_bytes(storage)
    neuron = n_local * (4 + 4 + 4)          # v, c, refrac
    ring = spec.d_ring * n_local * 4        # delayed-current ring
    active = n_local * 1
    out = {"tables": table, "neuron_state": neuron, "ring": ring,
           "active_mask": active}
    if plastic:
        w_item = np_dtype(storage.weight_dtype).itemsize
        plan = spec.delivery_plan(storage)
        caps = storage.caps()
        w_slots = sum((p.rows + 1) * c for p, c in zip(plan, caps))
        # fold-away: static w leaves hold the 1-byte mask, not weights
        out["tables"] = table - w_slots * (w_item - 1)
        mean_in = spec.expected_synapses() / max(n_local, 1)
        inv_cap = int(np.ceil(1.3 * mean_in))
        out["plastic"] = (w_slots * w_item     # live weight tiers (carry)
                          + (n_local + 1) * 4  # local pre-trace
                          + n_local * 4        # post-traces
                          + n_local * inv_cap * 4)  # inverse index slots
    if recorder_capacity:
        out["recorder"] = recorder_capacity * (4 + 4) + 8
    out["total"] = sum(out.values())
    return out


def bytes_per_synapse(spec: SynapseTableSpec, storage=None,
                      **kw) -> float:
    """Analytic bytes/synapse of one interior shard (paper Fig. 3).

    Counts *all* live per-shard buffers (see ``shard_memory_bytes``),
    not just the synapse tables."""
    mem = shard_memory_bytes(spec, storage, **kw)
    return mem["total"] / max(spec.expected_synapses(), 1.0)


# ---------------------------------------------------------------------------
# Analytic strong-scaling model (projects full-scale behaviour on the
# target TPU hardware from roofline constants; see benchmarks/fig1).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-class constants (per chip)."""

    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    flops_per_event: float = 4.0      # gather w, add to ring (fused)
    bytes_per_event: float = 12.0     # (tgt,w,dslot) read + ring RMW
    bytes_per_neuron_step: float = 24.0   # LIF state RMW (fused kernel)


def step_time_model(spec: SynapseTableSpec, rate_hz: float,
                    hw: HardwareModel = HardwareModel(),
                    pack_spikes: bool = True,
                    ext_rate_hz: float = EXTERNAL_RATE_HZ,
                    ext_synapses: int = 540) -> dict:
    """Roofline step-time terms for one shard at the given firing rate.

    Events per step per shard = stored synapses x rate x dt; halo bytes
    from the exact strip volume.  Returns seconds per simulated step.
    """
    d = spec.decomp
    dt_s = spec.dt_ms * 1e-3
    syn = spec.expected_synapses()
    events = syn * rate_hz * dt_s
    ext_events = spec.n_local * ext_synapses * ext_rate_hz * dt_s

    compute = (events + ext_events) * hw.flops_per_event / hw.peak_flops
    memory = ((events + ext_events) * hw.bytes_per_event
              + spec.n_local * hw.bytes_per_neuron_step) / hw.hbm_bw
    payload = (spec.n_exc_per_col + 7) // 8 if pack_spikes \
        else spec.n_exc_per_col * 4
    halo_cols = d.region_cols - d.tile_cols
    collective = halo_cols * payload / hw.ici_bw
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective,
            "step_s": max(compute, memory) + collective,
            "events_per_step": events}


def strong_scaling_curve(grid_h: int, grid_w: int, law: ConnectivityLaw,
                         shard_counts, rate_hz: float,
                         n_per_column: int,
                         hw: HardwareModel = HardwareModel(),
                         pack_spikes: bool = True) -> list:
    """Analytic cost-per-synaptic-event vs #shards (paper Fig. 1 shape)."""
    from .grid import ColumnGrid
    rows = []
    for n in shard_counts:
        ty = int(np.sqrt(n))
        while n % ty:
            ty -= 1
        tx = n // ty
        dec = TileDecomposition(grid=ColumnGrid(grid_h, grid_w, n_per_column),
                                tiles_y=ty, tiles_x=tx, radius=law.radius)
        spec = SynapseTableSpec(decomp=dec, law=law,
                                single_shard=(n == 1))
        t = step_time_model(spec, rate_hz, hw, pack_spikes)
        events_total = t["events_per_step"] * n
        rows.append({
            "shards": n, "tiles": (ty, tx),
            "step_s": t["step_s"],
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            # all shards step concurrently: wall time per step = step_s,
            # global events per step = events_per_step * n
            "cost_per_event": t["step_s"] / max(events_total, 1e-30),
        })
    return rows
