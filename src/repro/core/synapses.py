"""Per-shard synapse tables: source-major, fixed-capacity, delay-aware.

DPSNN stores synapses target-side in per-process irregular lists and
delivers spikes through an event queue.  The TPU adaptation replaces that
with *source-major padded tables*:

  - ``local`` tier: one row per neuron owned by the tile (excitatory rows
    carry local + remote-into-tile synapses, inhibitory rows local only);
  - ``halo`` tiers: rows for *excitatory* halo neurons (only excitatory
    neurons project laterally, see DESIGN.md section 2), **banded by
    expected in-tile fan-out**.  A halo column adjacent to the tile edge
    projects ~100x more synapses into the tile than one at the stencil rim;
    a single uniform capacity would pad the exponential law's 640-column
    halo by ~7x and destroy the paper's flat bytes/synapse behaviour
    (Fig. 3).  Geometric fan-out bands (cap halved per band) bound the
    padding at ~2x worst-case within a band, ~1.3x average.

Each row holds (tgt local-neuron index, weight, delay-slot) triples padded
to the band capacity with (0, 0.0, 0) entries -- padding is harmless
because a zero weight contributes nothing to the scatter-add.

Event-driven delivery:  compact spiking sources -> gather their rows ->
scatter-add ``w`` into a delayed-current ring buffer at ``(t+dslot) % D``.
Work is proportional to spikes x fan-out, i.e. to *synaptic events*, the
paper's cost unit.

Shapes are fully determined by the spec (no materialization needed), so
the multi-pod dry-run lowers the distributed step from ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .connectivity import ConnectivityLaw, FRAC_EXCITATORY, P_LOCAL
from .grid import TileDecomposition

MAX_HALO_BANDS = 8

# Version of the table-sampling procedure.  ``build_tables(seed)`` is
# deterministic *within* a version, but any change to the rng draw
# sequence (e.g. v2: sampling vectorized across tile columns per
# stencil offset, replacing the per-block loop of v1) or to the stored
# weight values (v3: weights quantized to the spec's ``weight_dtype``
# at sampling time, so storage-dtype casts are value-exact) yields a
# different synapse realization for the same seed.  Rides in checkpoint
# meta so a resume that would silently rebuild a different network is
# refused instead (runtime/sim_driver.py).
TABLE_REALIZATION_VERSION = 3


def np_dtype(name: str) -> np.dtype:
    """numpy dtype for ``name``, including ml_dtypes extensions
    (``np.dtype("bfloat16")`` raises; going through jnp does not)."""
    return np.dtype(jnp.dtype(name))


# --------------------------------------------------------------------------
# Typed storage / plan contract
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TableStorage:
    """How one shard's synapse tables are physically stored.

    The descriptor is identical across shards (SPMD-safe: caps are the
    cross-shard maximum of the realized per-row occupancy), hashable
    (rides pytree treedefs as static aux data), and JSON-serializable
    (rides checkpoint meta so a resume that would silently reinterpret
    the stored bytes is refused instead).

    ``cap_local`` / ``halo_caps`` are the *materialized* row capacities.
    They start at the spec's analytic caps and shrink when
    ``compress_tables`` truncates all-padding trailing columns (bucketed
    row storage for bands whose realized nnz is far below the analytic
    cap).  ``accum_dtype`` is the dtype delivery accumulates in; weights
    are cast up from ``weight_dtype`` before any arithmetic, which keeps
    delivery bit-identical across storage formats because sampled
    weights are quantized to ``weight_dtype`` at build time.
    """
    tgt_dtype: str = "int32"
    weight_dtype: str = "float32"
    accum_dtype: str = "float32"
    cap_local: int = 0
    halo_caps: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.accum_dtype != "float32":
            raise ValueError(
                f"accum_dtype={self.accum_dtype!r}: delivery accumulates "
                "in float32 (kernel MXU contract); other accumulation "
                "dtypes are not supported")

    def meta(self) -> dict:
        """JSON-ready form for checkpoint manifests."""
        return {"tgt_dtype": self.tgt_dtype,
                "weight_dtype": self.weight_dtype,
                "accum_dtype": self.accum_dtype,
                "cap_local": int(self.cap_local),
                "halo_caps": [int(c) for c in self.halo_caps]}

    @classmethod
    def from_meta(cls, d: dict) -> "TableStorage":
        return cls(tgt_dtype=d["tgt_dtype"],
                   weight_dtype=d["weight_dtype"],
                   accum_dtype=d.get("accum_dtype", "float32"),
                   cap_local=int(d["cap_local"]),
                   halo_caps=tuple(int(c) for c in d["halo_caps"]))

    def caps(self) -> List[int]:
        """Per-tier row capacities, local first then each halo band."""
        return [self.cap_local] + list(self.halo_caps)


@dataclasses.dataclass(frozen=True)
class TierPlan:
    """Static sizing of one delivery tier (local, or one halo band)."""
    cap: int           # row capacity (columns of the tier's tables)
    active_cap: int    # event-compaction list size
    rows: int          # source rows (excluding the sink row)
    entries: int       # active_cap * cap
    entries_padded: int  # entries, lane-aligned


@dataclasses.dataclass(frozen=True)
class EntryGeometry:
    """Lane-packed entry-block geometry of the fused delivery launch."""
    lanes: int
    entry_sublanes: int
    entry_block: int
    entries: int
    entries_padded: int
    n_blocks: int
    packed_shape: Tuple[int, int]


@jax.tree_util.register_pytree_node_class
class SynapseTables:
    """One shard's synapse tables as a typed pytree.

    Children are the ``local`` tier dict and the tuple of ``halo`` tier
    dicts (each ``{"tgt", "w", "dslot", "nnz"}``); the ``storage``
    descriptor is static aux data, so two SynapseTables only share a
    treedef when they share a storage format -- shardings, shard_map
    in_specs, and abstract inputs all validate against it for free.

    ``stats`` is a host-side build report (synapse counts, padding); it
    is *not* part of the pytree and is dropped by tree transformations.
    String indexing (``tables["local"]``) is kept so existing
    dict-shaped call sites keep working.
    """

    def __init__(self, local: dict, halo, storage: TableStorage,
                 stats: Optional[dict] = None):
        self.local = local
        self.halo = tuple(halo)
        self.storage = storage
        self.stats = stats

    def tree_flatten(self):
        return (self.local, self.halo), self.storage

    @classmethod
    def tree_unflatten(cls, storage, children):
        local, halo = children
        return cls(local, halo, storage)

    # ---- dict-compatible access -----------------------------------------
    def __getitem__(self, key):
        if key == "local":
            return self.local
        if key == "halo":
            return self.halo
        if key == "stats":
            return self.stats
        raise KeyError(key)

    def get(self, key, default=None):
        try:
            v = self[key]
        except KeyError:
            return default
        return v if v is not None else default

    def tiers(self) -> List[dict]:
        return [self.local] + list(self.halo)

    def replace(self, **kw) -> "SynapseTables":
        out = {"local": self.local, "halo": self.halo,
               "storage": self.storage, "stats": self.stats}
        out.update(kw)
        return SynapseTables(**out)

    def __repr__(self):
        return (f"SynapseTables(tiers={1 + len(self.halo)}, "
                f"storage={self.storage})")


def with_local_tier(tables, local_tier: dict):
    """``tables`` with its local tier replaced; accepts the typed pytree
    or a legacy ``{"local": ..., "halo": [...]}`` dict."""
    if isinstance(tables, SynapseTables):
        return tables.replace(local=local_tier)
    return dict(tables, local=local_tier)


def materialized_table_bytes(tables: SynapseTables,
                             n_shards: int = 1) -> int:
    """Exact per-shard bytes of the materialized table arrays (tables
    may be per-shard or stacked over ``n_shards`` leading entries)."""
    total = sum(int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize
                for tier in tables.tiers() for a in tier.values())
    return total // max(n_shards, 1)


def compress_tables(tables: SynapseTables) -> SynapseTables:
    """Truncate each tier's all-padding trailing columns.

    Row capacities come from the spec's analytic tail bound
    (mean + 4 sigma), but the realized max occupancy of a tier --
    especially outer halo bands at ``halo_floor=0`` -- is often far
    below it.  Columns past the realized max nnz hold only (0, 0.0, 0)
    padding, so dropping them is value-exact: the XLA scatter adds
    zeros from rows that never index there, and the kernel's lane
    stream simply gets shorter.

    Works on per-shard and on stacked (leading shard axes) tables; the
    cap is the max over every shard, so the compressed storage
    descriptor stays identical across shards (SPMD-safe).
    """
    def realized_cap(tier, cap):
        nnz = np.asarray(jax.device_get(tier["nnz"]))
        hi = int(nnz.max()) if nnz.size else 0
        return max(min(hi, cap), 1)

    def cut(tier, cap):
        return {k: (v if k == "nnz" else v[..., :cap])
                for k, v in tier.items()}

    st = tables.storage
    cap_l = realized_cap(tables.local, st.cap_local)
    caps_h = tuple(realized_cap(t, c)
                   for t, c in zip(tables.halo, st.halo_caps))
    new_storage = dataclasses.replace(st, cap_local=cap_l,
                                      halo_caps=caps_h)
    out = SynapseTables(cut(tables.local, cap_l),
                        [cut(t, c) for t, c in zip(tables.halo, caps_h)],
                        new_storage, stats=tables.stats)
    if out.stats is not None:
        n_shards = 1
        nnz = out.local["nnz"]
        if nnz.ndim > 1:                       # stacked over shards
            n_shards = int(np.prod(nnz.shape[:-1]))
        tb = materialized_table_bytes(out, n_shards)
        out.stats = dict(out.stats, table_bytes=tb,
                         bytes_per_synapse=tb * n_shards
                         / max(out.stats.get("n_synapses", 0), 1))
    return out


# --------------------------------------------------------------------------
# Spec: shapes and capacities, computed analytically
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SynapseTableSpec:
    decomp: TileDecomposition
    law: ConnectivityLaw
    frac_exc: float = FRAC_EXCITATORY
    p_local: float = P_LOCAL
    d_ring: int = 8                  # delay ring depth (steps)
    v_axon_um_per_ms: float = 300.0
    dt_ms: float = 1.0
    rate_cap_hz: float = 100.0       # compaction headroom (paper max ~38 Hz)
    cap_headroom: float = 8.0        # event-list size = headroom x mean
    weight_dtype: str = "float32"
    single_shard: bool = False       # 1x1 tiling: drop the (inactive) halo
    # Minimum expected in-tile fan-out for a halo column to get band
    # rows; columns below it are dropped at build time (their expected
    # contribution rounds to nothing, and the rows would be ~all
    # padding).  Plastic runs set it to 0.0: every stencil-reachable
    # column must have a slot, because an elastic retile relays the
    # learned realization by global synapse id and a floor-dropped
    # column would silently discard learned weights.
    halo_floor: float = 0.5

    # ---- derived geometry ---------------------------------------------
    @property
    def n_per_col(self) -> int:
        return self.decomp.grid.n_per_column

    @property
    def n_exc_per_col(self) -> int:
        return int(round(self.frac_exc * self.n_per_col))

    @property
    def n_local(self) -> int:
        return self.decomp.n_local

    # ---- fan-out maps (exact expectations) ------------------------------
    def _remote_fanout_map(self) -> np.ndarray:
        """(region_h, region_w) expected remote in-tile fanout for an
        excitatory source at each region position."""
        d = self.decomp
        off = self.law.stencil_offsets()
        probs = self.law.offset_probs()
        # repro-lint: ignore[dtype-bounds] host-side expected-fanout
        # accumulation; cap sizing must not round before the ceil
        fan = np.zeros((d.region_h, d.region_w), dtype=np.float64)
        r = d.radius
        for (dy, dx), p in zip(off, probs):
            # source at region (ry, rx) hits target (ry+dy, rx+dx); target
            # must lie in the tile window [R, R+tile).
            ys = slice(max(r - dy, 0), min(r - dy + d.tile_h, d.region_h))
            xs = slice(max(r - dx, 0), min(r - dx + d.tile_w, d.region_w))
            if ys.start < ys.stop and xs.start < xs.stop:
                fan[ys, xs] += p * self.n_per_col
        return fan

    @staticmethod
    def _cap(mean: float) -> int:
        return int(math.ceil(mean + 4.0 * math.sqrt(max(mean, 1.0)) + 8.0))

    @property
    def cap_local(self) -> int:
        """Row capacity for tile-resident sources."""
        fan = self._remote_fanout_map()
        d = self.decomp
        r = d.radius
        tile_fan = float(fan[r:r + d.tile_h, r:r + d.tile_w].max())
        return self._cap(self.p_local * self.n_per_col + tile_fan)

    # ---- halo bands -----------------------------------------------------
    def halo_bands(self) -> List[dict]:
        """Static banding of halo columns by expected in-tile fanout.

        Returns a list of dicts with keys:
          ``cols``  -- (n_cols_b,) flat region-column indices (np.int64)
          ``cap``   -- row capacity (int)
          ``rows``  -- n_cols_b * n_exc_per_col
        Band boundaries are geometric (cap halves per band).  Empty bands
        are dropped; band structure depends only on (decomp, law), so it
        is identical across shards (SPMD-safe).
        """
        if self.single_shard:
            return []
        d = self.decomp
        fan = self._remote_fanout_map()
        r = d.radius
        in_tile = np.zeros_like(fan, dtype=bool)
        in_tile[r:r + d.tile_h, r:r + d.tile_w] = True
        halo_fan = np.where(in_tile, -1.0, fan)
        flat = halo_fan.ravel()
        cols_all = np.where(flat >= 0.0)[0]
        f = flat[cols_all]
        # drop halo columns below the expected-fan-out floor (with
        # halo_floor == 0.0, keep every stencil-reachable column)
        keep = f >= self.halo_floor if self.halo_floor > 0.0 else f > 0.0
        cols_all, f = cols_all[keep], f[keep]
        if len(cols_all) == 0:
            return []
        fmax = float(f.max())
        bands = []
        lo_edge = fmax
        for b in range(MAX_HALO_BANDS):
            hi = lo_edge
            lo = fmax / (2.0 ** (b + 1)) if b < MAX_HALO_BANDS - 1 else 0.0
            sel = (f <= hi) & (f > lo) if b > 0 else (f > lo)
            if b == MAX_HALO_BANDS - 1:
                sel = f <= hi
            if sel.any():
                bands.append({
                    "cols": np.sort(cols_all[sel]),
                    "cap": self._cap(float(f[sel].max())),
                    "rows": int(sel.sum()) * self.n_exc_per_col,
                })
            lo_edge = lo
        return bands

    # ---- event-compaction capacities ------------------------------------
    def _active_cap(self, n_src: int) -> int:
        mean = n_src * self.rate_cap_hz * 1e-3 * self.dt_ms
        return min(int(math.ceil(self.cap_headroom * mean + 32.0)),
                   max(n_src, 1))

    @property
    def active_cap_local(self) -> int:
        return self._active_cap(self.n_local)

    def active_cap_band(self, band: dict) -> int:
        return self._active_cap(band["rows"])

    # ---- storage descriptor ---------------------------------------------
    def storage(self) -> TableStorage:
        """Analytic storage descriptor: spec-level dtypes and the
        analytic (uncompressed) row capacities.  Target ids are int16
        whenever a tile holds < 2**15 neurons (every config we run);
        the kernel gather widens them to int32 on the fly.
        """
        tgt_dt = "int16" if self.n_local < 2 ** 15 else "int32"
        return TableStorage(
            tgt_dtype=tgt_dt, weight_dtype=self.weight_dtype,
            accum_dtype="float32", cap_local=self.cap_local,
            halo_caps=tuple(b["cap"] for b in self.halo_bands()))

    def _storage(self, storage: Optional[TableStorage]) -> TableStorage:
        st = storage if storage is not None else self.storage()
        bands = self.halo_bands()
        if len(st.halo_caps) != len(bands):
            raise ValueError(
                f"storage descriptor has {len(st.halo_caps)} halo caps "
                f"but the spec defines {len(bands)} halo bands")
        return st

    # ---- kernel-facing delivery plan ------------------------------------
    def band_caps(self) -> List[int]:
        """Row capacity of each halo fan-out band (kernel block widths)."""
        return [b["cap"] for b in self.halo_bands()]

    def delivery_plan(self, storage: Optional[TableStorage] = None
                      ) -> List[TierPlan]:
        """Static per-tier sizing for the fused banded delivery kernel.

        One ``TierPlan`` per delivery tier, local first then each halo
        band.  Everything the kernel layer needs to lay out its
        lane-packed entry blocks is here -- tables supply only data --
        and the kernel validates the tables it is handed against this
        plan, so the engines compile against a spec-level contract.
        Pass the tables' ``storage`` so the plan sizes against the
        materialized (possibly compressed) caps rather than the
        analytic ones.
        """
        from ..kernels.synaptic_accum import LANES  # layout owner
        st = self._storage(storage)

        def tier(cap, active_cap, rows):
            entries = active_cap * cap
            return TierPlan(cap=cap, active_cap=active_cap, rows=rows,
                            entries=entries,
                            entries_padded=-(-entries // LANES) * LANES)

        plan = [tier(st.cap_local, self.active_cap_local, self.n_local)]
        for b, cap in zip(self.halo_bands(), st.halo_caps):
            plan.append(tier(cap, self.active_cap_band(b), b["rows"]))
        return plan

    def entry_geometry(self, storage: Optional[TableStorage] = None
                       ) -> EntryGeometry:
        """Lane-packed entry-block geometry of the fused delivery launch:
        the ``(E / LANES, LANES)`` packed stream shape and the number of
        ``ENTRY_BLOCK``-entry grid steps the kernel will take.  Shapes
        only (derivable without materializing tables), so the dry-run
        and the engines can size the launch from the spec alone.
        """
        from ..kernels.synaptic_accum import (ENTRY_BLOCK, ENTRY_SUBLANES,
                                              LANES, packed_total)
        total = sum(p.entries_padded for p in self.delivery_plan(storage))
        padded = packed_total(total)
        return EntryGeometry(
            lanes=LANES, entry_sublanes=ENTRY_SUBLANES,
            entry_block=ENTRY_BLOCK, entries=total,
            entries_padded=padded, n_blocks=padded // ENTRY_BLOCK,
            packed_shape=(padded // LANES, LANES))

    # ---- index maps (static numpy constants) ---------------------------
    def local_positions_in_region(self) -> np.ndarray:
        """(n_local,) region-neuron index of each local neuron."""
        d = self.decomp
        r = d.radius
        ly, lx = np.mgrid[0:d.tile_h, 0:d.tile_w]
        rcol = (ly + r) * d.region_w + (lx + r)
        base = rcol.ravel() * self.n_per_col
        return (base[:, None] + np.arange(self.n_per_col)[None, :]).ravel()

    def band_positions_in_region(self, band: dict) -> np.ndarray:
        """(rows_b,) region-neuron index of each band (excitatory) source."""
        base = band["cols"] * self.n_per_col
        return (base[:, None] + np.arange(self.n_exc_per_col)[None, :]).ravel()

    def band_positions_exc(self, band: dict) -> np.ndarray:
        """(rows_b,) index of each band source in the *excitatory-only*
        region layout ``(region_cols, n_exc)`` -- the layout produced by
        the halo exchange (only excitatory spikes travel laterally)."""
        base = band["cols"] * self.n_exc_per_col
        return (base[:, None] + np.arange(self.n_exc_per_col)[None, :]).ravel()

    # ---- abstract shapes for the dry-run --------------------------------
    def _tier_abstract(self, rows: int, cap: int, st: TableStorage):
        return {
            "tgt": jax.ShapeDtypeStruct((rows + 1, cap),
                                        jnp.dtype(st.tgt_dtype)),
            "w": jax.ShapeDtypeStruct((rows + 1, cap),
                                      jnp.dtype(st.weight_dtype)),
            "dslot": jax.ShapeDtypeStruct((rows + 1, cap), jnp.int8),
            "nnz": jax.ShapeDtypeStruct((rows + 1,), jnp.int32),
        }

    def abstract_tables(self, storage: Optional[TableStorage] = None
                        ) -> SynapseTables:
        st = self._storage(storage)
        return SynapseTables(
            self._tier_abstract(self.n_local, st.cap_local, st),
            [self._tier_abstract(b["rows"], cap, st)
             for b, cap in zip(self.halo_bands(), st.halo_caps)],
            st)

    def table_bytes(self, storage: Optional[TableStorage] = None) -> int:
        return materialized_table_bytes(self.abstract_tables(storage))

    def expected_synapses(self) -> float:
        """Expected number of synapses stored in this shard's tables
        (interior shard; used for analytic bytes/synapse at full scale)."""
        d = self.decomp
        fan = self._remote_fanout_map()
        r = d.radius
        local_remote = fan[r:r + d.tile_h, r:r + d.tile_w].sum()
        halo_remote = sum(fan.ravel()[b["cols"]].sum()
                          for b in self.halo_bands())
        local_syn = self.n_local * self.p_local * self.n_per_col
        return float(local_syn
                     + (local_remote + halo_remote) * self.n_exc_per_col)


# --------------------------------------------------------------------------
# Materialization (small configs / real runs)
# --------------------------------------------------------------------------

def _pack_rows(n_rows: int, cap: int, row_ids, tgts, ws, dslots, wdt,
               tdt=np.int32):
    """Group synapse triples by source row and pad each row to ``cap``.

    Row ``n_rows`` (the extra last row) is the all-zero sink row used by
    the event compactor's fill value.
    """
    order = np.argsort(row_ids, kind="stable")
    row_ids, tgts, ws, dslots = (row_ids[order], tgts[order], ws[order],
                                 dslots[order])
    counts = np.bincount(row_ids, minlength=n_rows)
    clipped = int(np.maximum(counts - cap, 0).sum())
    within = np.arange(len(row_ids)) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    keep = within < cap
    tdt = np.dtype(tdt)
    if len(tgts) and tdt.kind == "i" and \
            int(tgts.max(initial=0)) > np.iinfo(tdt).max:
        raise ValueError(
            f"target ids up to {int(tgts.max())} do not fit the "
            f"{tdt.name} storage dtype")
    tgt_a = np.zeros((n_rows + 1, cap), dtype=tdt)
    w_a = np.zeros((n_rows + 1, cap), dtype=wdt)
    d_a = np.zeros((n_rows + 1, cap), dtype=np.int8)
    tgt_a[row_ids[keep], within[keep]] = tgts[keep]
    w_a[row_ids[keep], within[keep]] = ws[keep]
    d_a[row_ids[keep], within[keep]] = dslots[keep]
    nnz = np.minimum(counts, cap).astype(np.int32)
    nnz = np.concatenate([nnz, [0]])
    return {"tgt": tgt_a, "w": w_a, "dslot": d_a, "nnz": nnz}, clipped


def sample_blocks(rng, p: float, n_src: int, n_tgt: int, n_blocks: int):
    """Vectorized sparse Bernoulli(p) over ``n_blocks`` independent
    (n_src, n_tgt) blocks: one batched binomial draw for the per-block
    synapse counts, one batched draw for the flat pair ids.

    Returns (block_id, src, tgt), each (M,) with M the total sampled.
    Distributionally identical to sampling each block separately, but a
    constant number of rng calls regardless of the tile size -- table
    materialization sits on the ``--retile`` restore path, so this is
    user-visible restore latency.
    """
    empty = (np.empty(0, np.int64),) * 3
    if n_blocks == 0:
        return empty
    n_pairs = n_src * n_tgt
    m = rng.binomial(n_pairs, p, size=n_blocks)
    total = int(m.sum())
    if total == 0:
        return empty
    blk = np.repeat(np.arange(n_blocks), m)
    flat = rng.integers(0, n_pairs, size=total)
    return blk, flat // n_tgt, flat % n_tgt


def build_tables(spec: SynapseTableSpec, tile_y: int, tile_x: int,
                 j_exc: float, j_inh: float, seed: int = 0,
                 w_jitter: float = 0.25) -> SynapseTables:
    """Materialize the synapse tables of one shard (numpy, host-side).

    Only usable at reduced scale; full-scale configurations are exercised
    through ``abstract_tables()`` by the dry-run.  Weights are quantized
    to the storage dtype here, at sampling time, so later casts between
    storage formats are value-exact (the v3 realization contract).
    Returns tables at the *analytic* caps (identical shapes across
    shards, so per-shard builds can be stacked); run ``compress_tables``
    afterwards to truncate all-padding columns.
    """
    d = spec.decomp
    N = spec.n_per_col
    n_exc = spec.n_exc_per_col
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, tile_y, tile_x]))
    storage = spec.storage()
    wdt = np_dtype(storage.weight_dtype)
    tdt = np_dtype(storage.tgt_dtype)

    region_active = d.region_active_mask(tile_y, tile_x)
    r = d.radius
    bands = spec.halo_bands()

    # region col -> (local col | (band, band col)) lookups
    ry, rx = np.mgrid[0:d.region_h, 0:d.region_w]
    in_tile = ((ry >= r) & (ry < r + d.tile_h) & (rx >= r) & (rx < r + d.tile_w))
    local_col_of_region = np.full((d.region_h, d.region_w), -1, dtype=np.int64)
    local_col_of_region[in_tile] = np.arange(d.tile_cols)
    band_of_region = np.full(d.region_cols, -1, dtype=np.int64)
    bandcol_of_region = np.full(d.region_cols, -1, dtype=np.int64)
    for bi, b in enumerate(bands):
        band_of_region[b["cols"]] = bi
        bandcol_of_region[b["cols"]] = np.arange(len(b["cols"]))

    off = spec.law.stencil_offsets()
    probs = spec.law.offset_probs()
    delays = spec.law.offset_delays(spec.v_axon_um_per_ms, spec.dt_ms,
                                    spec.d_ring)

    loc = {"rows": [], "tgts": [], "ws": [], "ds": []}
    hal = [{"rows": [], "tgts": [], "ws": [], "ds": []} for _ in bands]

    # ---- local (same-column) synapses: all neurons project --------------
    # One batched draw across every active tile column.
    ly, lx = (g.ravel() for g in np.mgrid[0:d.tile_h, 0:d.tile_w])
    cols = (ly * d.tile_w + lx)[region_active[ly + r, lx + r]]
    blk, src, tgt = sample_blocks(rng, spec.p_local, N, N, len(cols))
    if len(src):
        col = cols[blk]
        w = (np.where(src < n_exc, j_exc, j_inh)
             * rng.uniform(1.0 - w_jitter, 1.0 + w_jitter, size=len(src)))
        loc["rows"].append(col * N + src)
        loc["tgts"].append(col * N + tgt)
        loc["ws"].append(w)
        loc["ds"].append(np.ones(len(src), dtype=np.int8))

    # ---- remote synapses: excitatory sources only -----------------------
    # Per stencil offset, one batched draw across every target tile
    # column whose source column is in-region and active.
    ty, tx = (g.ravel() for g in np.mgrid[0:d.tile_h, 0:d.tile_w])
    for (dy, dx), p, dl in zip(off, probs, delays):
        sy, sx = ty + r - dy, tx + r - dx
        ok = (sy >= 0) & (sy < d.region_h) & (sx >= 0) & (sx < d.region_w)
        ok[ok] &= region_active[sy[ok], sx[ok]]
        tyv, txv, syv, sxv = ty[ok], tx[ok], sy[ok], sx[ok]
        blk, src, tgt = sample_blocks(rng, p, n_exc, N, len(tyv))
        if len(src) == 0:
            continue
        w = j_exc * rng.uniform(1.0 - w_jitter, 1.0 + w_jitter,
                                size=len(src))
        tgt_flat = (tyv[blk] * d.tile_w + txv[blk]) * N + tgt
        dlv = np.full(len(src), dl, dtype=np.int8)
        lcol = local_col_of_region[syv[blk], sxv[blk]]
        is_local = lcol >= 0
        if is_local.any():
            loc["rows"].append(lcol[is_local] * N + src[is_local])
            loc["tgts"].append(tgt_flat[is_local])
            loc["ws"].append(w[is_local])
            loc["ds"].append(dlv[is_local])
        rc = syv[blk] * d.region_w + sxv[blk]
        bi = band_of_region[rc]
        rem = ~is_local & (bi >= 0)   # bi < 0: below the 0.5-synapse floor
        for b_i in np.unique(bi[rem]):
            sel = rem & (bi == b_i)
            hal[b_i]["rows"].append(bandcol_of_region[rc[sel]] * n_exc
                                    + src[sel])
            hal[b_i]["tgts"].append(tgt_flat[sel])
            hal[b_i]["ws"].append(w[sel])
            hal[b_i]["ds"].append(dlv[sel])

    def cat(parts, dtype):
        if not parts:
            return np.empty(0, dtype)
        return np.concatenate(parts).astype(dtype)

    local_tab, clipped = _pack_rows(
        spec.n_local, spec.cap_local,
        cat(loc["rows"], np.int64), cat(loc["tgts"], np.int64),
        cat(loc["ws"], wdt), cat(loc["ds"], np.int8), wdt, tdt)
    halo_tabs = []
    for b, h in zip(bands, hal):
        tab, cl = _pack_rows(
            b["rows"], b["cap"],
            cat(h["rows"], np.int64), cat(h["tgts"], np.int64),
            cat(h["ws"], wdt), cat(h["ds"], np.int8), wdt, tdt)
        clipped += cl
        halo_tabs.append(tab)

    n_syn = int(local_tab["nnz"].sum()
                + sum(t["nnz"].sum() for t in halo_tabs))
    tb = spec.table_bytes(storage)
    return SynapseTables(
        {k: jnp.asarray(v) for k, v in local_tab.items()},
        [{k: jnp.asarray(v) for k, v in t.items()} for t in halo_tabs],
        storage,
        stats={
            "n_synapses": n_syn,
            "clipped": clipped,
            "table_bytes": tb,
            "bytes_per_synapse": tb / max(n_syn, 1),
        })


# --------------------------------------------------------------------------
# Delivery (the hot loop; the Pallas kernel mirrors these semantics)
# --------------------------------------------------------------------------

def deliver_gather_all(tables: dict, spikes_src: jnp.ndarray,
                       i_ring: jnp.ndarray, t_slot: jnp.ndarray,
                       d_ring: int) -> jnp.ndarray:
    """Time-driven baseline: touch every synapse, gate by source spike.

    ``spikes_src`` is (n_rows,) f32 in the row order of ``tables``.
    """
    tgt, w, dslot = tables["tgt"], tables["w"], tables["dslot"]
    n_rows = tgt.shape[0] - 1
    gate = spikes_src[:n_rows].astype(jnp.float32)
    # cast weights up to the accumulation dtype *before* any arithmetic:
    # with v3 sampling-time quantization the cast is value-exact, so
    # delivery is bit-identical across weight storage dtypes
    contrib = w[:n_rows].astype(jnp.float32) * gate[:, None]
    slots = (t_slot + dslot[:n_rows].astype(jnp.int32)) % d_ring
    rows_t = tgt[:n_rows].astype(jnp.int32)
    return i_ring.at[slots.ravel(), rows_t.ravel()].add(contrib.ravel())


def deliver_events(tables: dict, spikes_src: jnp.ndarray,
                   i_ring: jnp.ndarray, t_slot: jnp.ndarray,
                   d_ring: int, active_cap: int):
    """Event-driven delivery: compact spiking sources, gather only their
    rows, scatter-add into the delayed-current ring.

    Returns (i_ring, n_events, n_dropped).
    """
    tgt, w, dslot, nnz = (tables["tgt"], tables["w"], tables["dslot"],
                          tables["nnz"])
    n_rows = tgt.shape[0] - 1  # last row is the all-zero sink
    spk = spikes_src[:n_rows]
    (idx,) = jnp.nonzero(spk > 0, size=active_cap, fill_value=n_rows)
    rows_t = tgt[idx].astype(jnp.int32)   # (A, cap); widen int16 storage
    rows_w = w[idx].astype(jnp.float32)
    rows_d = dslot[idx].astype(jnp.int32)
    slots = (t_slot + rows_d) % d_ring
    i_ring = i_ring.at[slots.ravel(), rows_t.ravel()].add(rows_w.ravel())
    n_spikes = jnp.sum(spk > 0)
    n_events = jnp.sum(nnz[idx])
    n_dropped = jnp.maximum(n_spikes - active_cap, 0)
    return i_ring, n_events, n_dropped
