"""Leaky Integrate-and-Fire neuron with Spike-Frequency Adaptation.

Model (Gigante, Mattia, Del Giudice 2007 form, discretized at dt):

    V[t+1] = V_rest + (V[t] - V_rest) * exp(-dt/tau_m)
             + I_syn + I_ext - g_sfa * c[t] * dt        (if not refractory)
    c[t+1] = c[t] * exp(-dt/tau_c) + alpha_c * spiked
    spike  : V >= theta  ->  V <- V_reset, refractory for tau_arp

Synaptic inputs are delta-currents (instantaneous membrane jumps, in mV),
as in the Perseo/DPSNN lineage.  All state is float32 except the
refractory counter (int32).  Shapes are flat (n_neurons,).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LIFParams:
    dt_ms: float = 1.0
    tau_m_ms: float = 20.0      # membrane time constant
    v_rest_mv: float = 0.0
    v_reset_mv: float = 0.0
    theta_mv: float = 20.0      # firing threshold
    tau_arp_ms: float = 2.0     # absolute refractory period
    tau_c_ms: float = 120.0     # SFA time constant
    alpha_c: float = 1.0        # SFA increment per spike
    g_sfa: float = 0.025        # SFA conductance (mV per unit c per ms)
    # synaptic efficacies (delta-current jumps, mV)
    j_exc_mv: float = 0.35
    j_inh_mv: float = -1.6      # ~4.5x exc (inhibition-dominated balance)
    j_ext_mv: float = 0.50

    @property
    def leak_decay(self) -> float:
        return float(np.exp(-self.dt_ms / self.tau_m_ms))

    @property
    def sfa_decay(self) -> float:
        return float(np.exp(-self.dt_ms / self.tau_c_ms))

    @property
    def refrac_steps(self) -> int:
        return int(round(self.tau_arp_ms / self.dt_ms))


def init_state(n: int, params: LIFParams, rng: np.random.Generator | None = None):
    """Initial membrane state; small voltage jitter to break symmetry."""
    rng = rng or np.random.default_rng(0)
    v0 = rng.uniform(params.v_rest_mv, 0.5 * params.theta_mv, size=n)
    return {
        "v": jnp.asarray(v0, dtype=jnp.float32),
        "c": jnp.zeros((n,), dtype=jnp.float32),
        "refrac": jnp.zeros((n,), dtype=jnp.int32),
    }


def lif_sfa_step(state: dict, i_total_mv, params: LIFParams,
                 active_mask=None):
    """One dt update.  ``i_total_mv`` is the summed synaptic + external
    delta-current for this step (mV).  Returns (new_state, spikes f32)."""
    v, c, refrac = state["v"], state["c"], state["refrac"]
    p = params

    refractory = refrac > 0
    v_int = (p.v_rest_mv + (v - p.v_rest_mv) * p.leak_decay
             + i_total_mv - p.g_sfa * c * p.dt_ms)
    v_new = jnp.where(refractory, p.v_reset_mv, v_int)

    spiked = v_new >= p.theta_mv
    if active_mask is not None:
        spiked = jnp.logical_and(spiked, active_mask)

    v_new = jnp.where(spiked, p.v_reset_mv, v_new)
    c_new = c * p.sfa_decay + p.alpha_c * spiked.astype(jnp.float32)
    refrac_new = jnp.where(
        spiked, jnp.int32(p.refrac_steps),
        jnp.maximum(refrac - 1, 0).astype(jnp.int32))

    new_state = {"v": v_new.astype(jnp.float32), "c": c_new,
                 "refrac": refrac_new}
    return new_state, spiked.astype(jnp.float32)
