"""The paper's primary contribution: the DPSNN spiking-network simulator
re-architected for TPU meshes -- connectivity laws, column-grid domain
decomposition, synapse tables, LIF+SFA dynamics, halo-exchange spike
communication, STDP, and the paper's cost/memory metrics."""

from .connectivity import (ConnectivityLaw, exponential_law, gaussian_law,
                           expected_synapse_counts)
from .grid import ColumnGrid, TileDecomposition, choose_tiling
from .neuron import LIFParams, init_state, lif_sfa_step
from .synapses import (EntryGeometry, SynapseTables, SynapseTableSpec,
                       TableStorage, TierPlan, build_tables, compress_tables)
from .engine import (EngineConfig, init_sim_state, init_ensemble_state,
                     build_shard_tables, init_plasticity, firing_rate_hz)
from .dist_engine import DistConfig, SimInputs, make_sim_fn, simulate
from .retile import retile_config, retile_state
from .stdp import STDPParams
from . import metrics
