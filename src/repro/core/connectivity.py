"""Lateral connectivity laws from the paper.

Two distance-dependent connection-probability laws over a 2D grid of
cortical columns (grid step ``alpha`` microns):

* Gaussian (short range):   p(r) = A * exp(-r^2 / (2 sigma^2))
* Exponential (long range): p(r) = A * exp(-r / lambda)

with a hard cutoff: offsets whose probability falls below ``cutoff``
(paper: 1/1000) are not connected at all.  The cutoff induces a square
*stencil* of connected columns: 7x7 for the paper's Gaussian parameters
(A=0.05, sigma=100um) and 21x21 for the exponential ones (A=0.03,
lambda=290um).

Local (same-column) connectivity is a separate uniform probability
``p_local`` calibrated so that each neuron projects ~990 local synapses
(80% of the Gaussian-case total).

Only excitatory neurons project laterally (see DESIGN.md section 2 -- this
is the reading that reproduces the paper's ~250 / >1000 remote-synapse
counts and Table 1 totals).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# Paper constants
ALPHA_UM = 100.0          # columnar grid step (um)
CUTOFF = 1.0e-3           # connection-probability cutoff
NEURONS_PER_COLUMN = 1240
FRAC_EXCITATORY = 0.8
P_LOCAL = 990.0 / NEURONS_PER_COLUMN   # ~0.7984 -> ~990 local syn / neuron
EXTERNAL_SYNAPSES = 540
EXTERNAL_RATE_HZ = 3.0


@dataclasses.dataclass(frozen=True)
class ConnectivityLaw:
    """A lateral connection-probability law p(r)."""

    kind: str                 # "gaussian" | "exponential"
    amplitude: float          # A, peak connection probability
    scale_um: float           # sigma (gaussian) or lambda (exponential)
    cutoff: float = CUTOFF
    alpha_um: float = ALPHA_UM

    def prob(self, r_um) -> np.ndarray:
        """Connection probability at distance r (um). Applies the cutoff."""
        # repro-lint: ignore[dtype-bounds] host-side analytic: p(r) feeds
        # the deterministic table build, never a device buffer
        r = np.asarray(r_um, dtype=np.float64)
        if self.kind == "gaussian":
            p = self.amplitude * np.exp(-(r ** 2) / (2.0 * self.scale_um ** 2))
        elif self.kind == "exponential":
            p = self.amplitude * np.exp(-r / self.scale_um)
        else:
            raise ValueError(f"unknown connectivity kind: {self.kind}")
        return np.where(p > self.cutoff, p, 0.0)

    @property
    def r_cut_um(self) -> float:
        """Distance at which p(r) crosses the cutoff."""
        if self.amplitude <= self.cutoff:
            return 0.0
        if self.kind == "gaussian":
            return self.scale_um * math.sqrt(2.0 * math.log(self.amplitude / self.cutoff))
        return self.scale_um * math.log(self.amplitude / self.cutoff)

    @property
    def radius(self) -> int:
        """Stencil radius in grid steps (paper: 3 -> 7x7, 10 -> 21x21)."""
        return int(math.ceil(self.r_cut_um / self.alpha_um))

    @property
    def stencil_width(self) -> int:
        return 2 * self.radius + 1

    def stencil_offsets(self) -> np.ndarray:
        """All (dy, dx) integer offsets with p > cutoff, excluding (0, 0).

        Returns an int array of shape (K, 2).  (0, 0) is excluded because
        local (same-column) connectivity follows the separate uniform
        ``P_LOCAL`` rule.
        """
        rad = self.radius
        dy, dx = np.mgrid[-rad:rad + 1, -rad:rad + 1]
        dy, dx = dy.ravel(), dx.ravel()
        r = self.alpha_um * np.hypot(dy, dx)
        keep = (self.prob(r) > 0.0) & ~((dy == 0) & (dx == 0))
        return np.stack([dy[keep], dx[keep]], axis=-1).astype(np.int32)

    def offset_probs(self) -> np.ndarray:
        """p(r) for each stencil offset, aligned with stencil_offsets()."""
        off = self.stencil_offsets()
        r = self.alpha_um * np.hypot(off[:, 0], off[:, 1])
        return self.prob(r)

    def offset_delays(self, v_axon_um_per_ms: float = 300.0,
                      dt_ms: float = 1.0, d_max: int = 8) -> np.ndarray:
        """Distance-dependent axonal delay per stencil offset, in dt steps.

        delay = 1 step (synaptic) + r / v_axon, quantized, clipped to d_max-1.
        """
        off = self.stencil_offsets()
        r = self.alpha_um * np.hypot(off[:, 0], off[:, 1])
        d = 1.0 + r / v_axon_um_per_ms / dt_ms
        return np.clip(np.round(d).astype(np.int32), 1, d_max - 1)

    def expected_remote_fanout(self, n_per_column: int = NEURONS_PER_COLUMN) -> float:
        """Expected remote synapses projected by one *excitatory* neuron
        sitting in the interior of an infinite grid."""
        return float(self.offset_probs().sum() * n_per_column)


def gaussian_law() -> ConnectivityLaw:
    """Paper's short-range law: A=0.05, sigma=100um -> 7x7 stencil."""
    return ConnectivityLaw(kind="gaussian", amplitude=0.05, scale_um=100.0)


def exponential_law() -> ConnectivityLaw:
    """Paper's long-range law: A=0.03, lambda=290um -> 21x21 stencil."""
    return ConnectivityLaw(kind="exponential", amplitude=0.03, scale_um=290.0)


def expected_synapse_counts(
    law: ConnectivityLaw,
    grid_h: int,
    grid_w: int,
    n_per_column: int = NEURONS_PER_COLUMN,
    frac_exc: float = FRAC_EXCITATORY,
    p_local: float = P_LOCAL,
    external_per_neuron: int = EXTERNAL_SYNAPSES,
) -> dict:
    """Exact expected synapse counts for a finite grid (with edge effects).

    Reproduces the paper's Table 1.  Local synapses: every neuron projects
    to every same-column neuron with p_local.  Remote synapses: every
    *excitatory* neuron projects to every neuron of each stencil column
    (inside the grid) with p(r).
    """
    n_cols = grid_h * grid_w
    n_neurons = n_cols * n_per_column
    n_exc_per_col = int(round(frac_exc * n_per_column))

    local = n_cols * n_per_column * p_local * n_per_column

    # Edge-aware remote count: for each offset, the number of (source col,
    # target col) pairs inside the grid is (H-|dy|)*(W-|dx|).
    off = law.stencil_offsets()
    probs = law.offset_probs()
    pairs = (np.maximum(grid_h - np.abs(off[:, 0]), 0)
             # repro-lint: ignore[dtype-bounds] host analytic: ~1e10-synapse
             # counts overflow f32's 24-bit integer range
             * np.maximum(grid_w - np.abs(off[:, 1]), 0)).astype(np.float64)
    remote = float((pairs * probs).sum() * n_exc_per_col * n_per_column)

    recurrent = local + remote
    external = float(n_neurons * external_per_neuron)
    return {
        "grid": (grid_h, grid_w),
        "columns": n_cols,
        "neurons": n_neurons,
        "local_synapses": local,
        "remote_synapses": remote,
        "recurrent_synapses": recurrent,
        "external_synapses": external,
        "total_synapses": recurrent + external,
        "recurrent_per_neuron": recurrent / n_neurons,
        "remote_per_neuron": remote / n_neurons,
        "stencil_width": law.stencil_width,
    }
