"""Single-shard DPSNN engine: time-driven outer loop, event-driven delivery.

One engine instance simulates one tile of the column grid.  The
distributed engine (``dist_engine.py``) runs this per-shard body inside a
``shard_map`` with a halo exchange supplying remote spikes.

Step structure (dt = 1 ms):

  1. read the delayed-current ring slot for t, add external Poisson drive
  2. LIF+SFA update -> spikes
  3. zero the consumed ring slot
  4. deliver local+halo spikes through the synapse tables into future
     ring slots (event mode: cost ~ spikes x fan-out = synaptic events)

State is a pytree; ``simulate`` is a ``lax.scan`` and jit-compatible.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .connectivity import (ConnectivityLaw, EXTERNAL_RATE_HZ,
                           EXTERNAL_SYNAPSES)
from .grid import TileDecomposition
from .neuron import LIFParams, init_state, lif_sfa_step
from .synapses import (SynapseTableSpec, SynapseTables, build_tables,
                       compress_tables, deliver_events, deliver_gather_all,
                       with_local_tier)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    decomp: TileDecomposition
    law: ConnectivityLaw
    lif: LIFParams = LIFParams()
    d_ring: int = 8
    mode: str = "event"              # "event" | "gather_all"
    ext_synapses: int = EXTERNAL_SYNAPSES
    ext_rate_hz: float = EXTERNAL_RATE_HZ
    rate_cap_hz: float = 100.0
    cap_headroom: float = 8.0        # event-list sizing (perf knob)
    seed: int = 0
    # Weight *storage* dtype.  bfloat16 by default: sampled weights are
    # quantized to this dtype at build time (realization v3) and cast
    # up to float32 before any delivery arithmetic, so event delivery
    # is bit-identical to a float32 copy of the same tables while the
    # weight array -- the largest memory term after target ids --
    # halves.  Plastic runs override to float32 (see ``spec()``).
    weight_dtype: str = "bfloat16"
    # Pallas kernel routing for LIF + event delivery:
    #   "auto" (default) -- kernels everywhere: compiled on TPU,
    #       interpret-mode on CPU/GPU so every environment exercises the
    #       identical kernel code path;
    #   True  -- same as "auto" (kept for older call sites);
    #   False -- pure-XLA reference path (deliver_events / lif_sfa_step).
    use_kernels: Union[bool, str] = "auto"
    stdp: object = None              # Optional[STDPParams]; plastic when set
    # Seed for the *state* realization (membrane init + per-step Poisson
    # drive).  ``None`` (default) follows ``seed``, which also fixes the
    # synapse-table realization.  Ensemble runs share one table
    # realization (``seed``) across members while varying ``state_seed``
    # per member, so ensemble member m is bit-identical to a solo run
    # with the same ``seed`` and ``state_seed=member_seed_m``.
    state_seed: Optional[int] = None

    @property
    def state_seed_value(self) -> int:
        return self.seed if self.state_seed is None else self.state_seed

    @property
    def kernels_enabled(self) -> bool:
        if isinstance(self.use_kernels, str):
            if self.use_kernels != "auto":
                raise ValueError(
                    f"use_kernels={self.use_kernels!r}: expected 'auto' "
                    "or a bool")
            return True
        return bool(self.use_kernels)

    def spec(self) -> SynapseTableSpec:
        single = self.decomp.tiles_y == 1 and self.decomp.tiles_x == 1
        # plastic runs keep band rows for every stencil-reachable halo
        # column (floor 0.0): the learned realization must relay across
        # tilings without a floor-dropped column orphaning its weights.
        # They also force float32 weights: STDP increments (a_plus ~
        # 5e-3 of j_exc) fall below the bfloat16 ulp at typical weight
        # magnitudes and would silently round away.
        plastic = self.stdp is not None
        return SynapseTableSpec(
            decomp=self.decomp, law=self.law, d_ring=self.d_ring,
            dt_ms=self.lif.dt_ms, rate_cap_hz=self.rate_cap_hz,
            cap_headroom=self.cap_headroom,
            weight_dtype="float32" if plastic else self.weight_dtype,
            single_shard=single,
            halo_floor=0.0 if plastic else 0.5)


def init_sim_state(cfg: EngineConfig, tile_y: int = 0, tile_x: int = 0,
                   seed_offset: int = 0) -> dict:
    spec = cfg.spec()
    n_local = spec.n_local
    sseed = cfg.state_seed_value
    rng = np.random.default_rng(
        np.random.SeedSequence([sseed, 7 + seed_offset, tile_y, tile_x]))
    neuron = init_state(n_local, cfg.lif, rng)
    active_cols = cfg.decomp.active_mask(tile_y, tile_x).ravel()
    active = np.repeat(active_cols, cfg.decomp.grid.n_per_column)
    return {
        "neuron": neuron,
        "i_ring": jnp.zeros((cfg.d_ring, n_local), dtype=jnp.float32),
        "t": jnp.zeros((), dtype=jnp.int32),
        "rng": jax.random.PRNGKey(sseed + 1000 * seed_offset
                                  + 17 * tile_y + tile_x),
        "active": jnp.asarray(active),
        "metrics": {
            "spikes": jnp.zeros((), jnp.float32),
            "events": jnp.zeros((), jnp.float32),
            "dropped": jnp.zeros((), jnp.float32),
        },
    }


def init_ensemble_state(cfg: EngineConfig, seeds) -> dict:
    """Stack ``len(seeds)`` member states on a leading ensemble axis.

    Member ``m`` is ``init_sim_state`` of the same config with
    ``state_seed=seeds[m]`` -- every member shares the table realization
    (``cfg.seed``) but draws its own membrane init and Poisson stream,
    so ``simulate(..., ensemble=M)`` over this state reproduces each
    member's solo run bit-for-bit.
    """
    seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("ensemble needs at least one member seed")
    members = [init_sim_state(dataclasses.replace(cfg, state_seed=s))
               for s in seeds]
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *members)


def build_shard_tables(cfg: EngineConfig, tile_y: int = 0,
                       tile_x: int = 0,
                       compress: bool = True) -> SynapseTables:
    """Materialize (and by default compress) one shard's tables.

    Compression truncates all-padding trailing columns per tier
    (value-exact; see ``synapses.compress_tables``).  The returned
    tables carry the realized ``storage`` descriptor -- pass it to
    anything that sizes launches from the spec (``delivery_plan``,
    ``make_sim_fn``, shardings).
    """
    spec = cfg.spec()
    tabs = build_tables(spec, tile_y, tile_x, j_exc=cfg.lif.j_exc_mv,
                        j_inh=cfg.lif.j_inh_mv, seed=cfg.seed)
    return compress_tables(tabs) if compress else tabs


def external_drive(rng_key, n_local: int, cfg: EngineConfig):
    """Poisson thalamo-cortical drive: ext_synapses firing at ext_rate."""
    lam = cfg.ext_synapses * cfg.ext_rate_hz * 1e-3 * cfg.lif.dt_ms
    events = jax.random.poisson(rng_key, lam, (n_local,))
    return events.astype(jnp.float32) * cfg.lif.j_ext_mv


def deliver_event_tiers(tables, spikes, halo_band_spikes, spec, i_ring,
                        slot, d_ring: int, kernels_enabled: bool,
                        plan: Optional[list] = None):
    """Event-driven delivery of the local tier + every halo band.

    The single source of truth for both step bodies (single-shard
    ``step`` and the distributed ``shard_step``): tier sizing comes from
    ``spec.delivery_plan()`` (precompute it once per trace and pass it
    as ``plan``), and the kernel path hands all tiers plus the plan to
    one fused ``synaptic_accum_banded`` launch -- the kernel validates
    its tables against the plan's lane-packed entry geometry -- while
    the XLA path loops ``deliver_events`` per tier.  Returns (i_ring,
    events, dropped) as f32 scalars.
    """
    if plan is None:
        plan = spec.delivery_plan(getattr(tables, "storage", None))
    halo = list(zip(plan[1:], tables["halo"], halo_band_spikes))
    if kernels_enabled:
        from ..kernels import ops as kops
        tiers = [(tables["local"], spikes, plan[0].active_cap)]
        tiers += [(tab, spk, p.active_cap) for p, tab, spk in halo]
        i_ring, ev, dr = kops.synaptic_accum_banded(
            tiers, i_ring, slot, d_ring, plan=plan)
        return i_ring, ev.astype(jnp.float32), dr.astype(jnp.float32)
    i_ring, ev, dr = deliver_events(
        tables["local"], spikes, i_ring, slot, d_ring,
        plan[0].active_cap)
    ev = ev.astype(jnp.float32)
    dr = dr.astype(jnp.float32)
    for p, tab, spk in halo:
        i_ring, ev_b, dr_b = deliver_events(
            tab, spk, i_ring, slot, d_ring, p.active_cap)
        ev = ev + ev_b.astype(jnp.float32)
        dr = dr + dr_b.astype(jnp.float32)
    return i_ring, ev, dr


def plastic_delivery_stdp(tiers, masks, inv, traces, spike_tiers, spec,
                          i_ring, slot, cfg: EngineConfig, plan):
    """One plastic update: event delivery + STDP over ``tiers``.

    The single source of truth for both plastic step bodies
    (``_run_plastic`` and the distributed ``shard_step``).  Routing:

      * kernels enabled and the shard fits the resident-ring kernel
        (``kernels.plastic_step.fused_supported``): ONE Pallas launch
        applies delivery and the LTD weight update in the same pass
        over the lane-packed entry stream, then the shared XLA
        ``stdp_ltp_finalize`` adds LTP / clamp / trace increments;
      * otherwise: the two-pass reference -- ``deliver_event_tiers``
        (which itself routes kernel vs XLA delivery) followed by the
        full ``stdp_step``.

    Both routes are bit-identical (kernel contract, tested at tier-1
    sizes).  ``tiers`` carry the *live* weights (the scan carry is the
    single weight source); ``traces`` is ``{"x_pre": [per tier],
    "x_post"}``.  Returns (i_ring, new_tiers, new_traces, events,
    dropped) with events/dropped as f32 scalars.
    """
    from .stdp import stdp_ltp_finalize, stdp_step
    p = cfg.stdp
    spikes_local = spike_tiers[0]
    post_cap = spec.active_cap_local
    if cfg.kernels_enabled:
        from ..kernels import ops as kops
        from ..kernels.plastic_step import fused_supported
        if fused_supported(spec.n_local):
            # decay first (updates read *previous* activity), exactly as
            # stdp_step does; the kernel consumes the decayed post trace
            x_pre_d = [xp * p.decay_plus for xp in traces["x_pre"]]
            x_post_d = traces["x_post"] * p.decay_minus
            tier_args = [(t, spk, tp.active_cap)
                         for t, spk, tp in zip(tiers, spike_tiers, plan)]
            i_ring, new_w, ev, dr = kops.plastic_step_banded(
                tier_args, masks, x_post_d, i_ring, slot, cfg.d_ring,
                -p.a_minus, plan=plan)
            new_tiers = [dict(t, w=w) for t, w in zip(tiers, new_w)]
            new_tiers, new_traces = stdp_ltp_finalize(
                new_tiers, masks, inv, x_pre_d, x_post_d, spike_tiers,
                spikes_local, p, post_cap)
            return (i_ring, new_tiers, new_traces,
                    ev.astype(jnp.float32), dr.astype(jnp.float32))
    tabs = {"local": tiers[0], "halo": list(tiers[1:])}
    i_ring, ev, dr = deliver_event_tiers(
        tabs, spikes_local, list(spike_tiers[1:]), spec, i_ring, slot,
        cfg.d_ring, cfg.kernels_enabled, plan=plan)
    new_tiers, new_traces = stdp_step(
        tiers, masks, inv, traces, spike_tiers, spikes_local, p,
        [tp.active_cap for tp in plan], post_cap)
    return i_ring, new_tiers, new_traces, ev, dr


def step(state: dict, tables: dict, cfg: EngineConfig,
         halo_band_spikes: Optional[list] = None, deliver: bool = True):
    """One simulation step.

    ``halo_band_spikes``: list of per-band (rows_b,) spike vectors for the
    halo excitatory sources this step (None when running single-shard).
    ``deliver=False`` stops after the LIF update and ring-slot consume --
    the plastic scan body uses it so delivery can run fused with the
    STDP update (``plastic_delivery_stdp``) instead of here.
    Returns (new_state, local_spikes).
    """
    spec = cfg.spec()
    n_local = spec.n_local
    plan = (spec.delivery_plan(getattr(tables, "storage", None))
            if cfg.mode == "event" and deliver else None)
    key, k_ext = jax.random.split(state["rng"])
    slot = state["t"] % cfg.d_ring

    i_now = state["i_ring"][slot] + external_drive(k_ext, n_local, cfg)
    if cfg.kernels_enabled:
        from ..kernels import ops as kops
        neuron, spikes = kops.lif_step(state["neuron"], i_now, cfg.lif,
                                       state["active"])
    else:
        neuron, spikes = lif_sfa_step(state["neuron"], i_now, cfg.lif,
                                      state["active"])

    i_ring = state["i_ring"].at[slot].set(0.0)

    halo_band_spikes = halo_band_spikes or []
    metrics = state["metrics"]
    if not deliver:
        metrics = dict(metrics, spikes=metrics["spikes"] + jnp.sum(spikes))
    elif cfg.mode == "event":
        i_ring, ev, dr = deliver_event_tiers(
            tables, spikes, halo_band_spikes, spec, i_ring, slot,
            cfg.d_ring, cfg.kernels_enabled, plan=plan)
        metrics = {
            "spikes": metrics["spikes"] + jnp.sum(spikes),
            "events": metrics["events"] + ev,
            "dropped": metrics["dropped"] + dr,
        }
    elif cfg.mode == "gather_all":
        i_ring = deliver_gather_all(tables["local"], spikes, i_ring, slot,
                                    cfg.d_ring)
        nnz_l = tables["local"]["nnz"][:n_local].astype(jnp.float32)
        ev = jnp.sum(nnz_l * spikes)
        for tab, spk in zip(tables["halo"], halo_band_spikes):
            i_ring = deliver_gather_all(tab, spk, i_ring, slot, cfg.d_ring)
            nnz_h = tab["nnz"][:-1].astype(jnp.float32)
            ev = ev + jnp.sum(nnz_h * spk)
        metrics = {
            "spikes": metrics["spikes"] + jnp.sum(spikes),
            "events": metrics["events"] + ev,
            "dropped": metrics["dropped"],
        }
    else:
        raise ValueError(f"unknown mode {cfg.mode}")

    new_state = {
        "neuron": neuron, "i_ring": i_ring, "t": state["t"] + 1,
        "rng": key, "active": state["active"], "metrics": metrics,
    }
    return new_state, spikes


def simulate(state: dict, tables, cfg: EngineConfig, n_steps: int,
             plasticity: Optional[dict] = None,
             record_spikes: bool = False, recorder=None,
             ensemble: Optional[int] = None):
    """Scan ``n_steps`` of single-shard simulation (no halo sources).

    The one entry point for both static and plastic runs:

      - ``plasticity=None`` (static): returns ``(state, out)`` where
        ``out`` is the per-step spike count, or the full spike raster
        with ``record_spikes=True``.
      - ``plasticity=init_plasticity(tables, cfg)``: STDP is applied
        each step and the synapse tables join the scan carry; returns
        ``((state, tables, traces), per_step_spike_counts)``.

    ``recorder``: optional ``obs.record.RecorderSpec`` (static runs
    only) -- when given, every spike is also appended as a
    ``(sim_step, global_neuron_id)`` event to a bounded buffer carried
    through the scan, and the return becomes ``(state, out,
    recorder_state)``.  Recording is a pure observer: the spike trains
    are bit-identical with it on or off.

    ``ensemble``: number of member realizations stacked on the leading
    axis of every ``state`` leaf (see ``init_ensemble_state``).  The
    solo scan is vmapped over the member axis -- one trace, one
    compiled step, M realizations sharing the same ``tables`` -- and
    every return leaf (final state, per-step outputs, recorder buffers,
    plastic tables/traces) grows the matching leading member axis.
    Member m's outputs are bit-identical to the solo run seeded with
    that member's ``state_seed``.
    """
    if ensemble is not None:
        m = int(ensemble)
        lead = {leaf.shape[0] for leaf in jax.tree_util.tree_leaves(state)}
        if lead != {m}:
            raise ValueError(
                f"ensemble={m} but state leading axes are {sorted(lead)}; "
                "build the state with init_ensemble_state(cfg, seeds)")
        return jax.vmap(lambda st: simulate(
            st, tables, cfg, n_steps, plasticity=plasticity,
            record_spikes=record_spikes, recorder=recorder))(state)
    if plasticity is not None:
        if recorder is not None or record_spikes:
            raise ValueError("plastic runs do not support recorder/"
                             "record_spikes (use the distributed driver)")
        return _run_plastic(state, tables, plasticity, cfg, n_steps)
    if recorder is not None:
        from ..obs.record import (init_recorder_state, record_step,
                                  tile_gid_map)
        gids = jnp.asarray(tile_gid_map(cfg.decomp, 0, 0))

        def body_rec(carry, _):
            st, rec = carry
            new_state, spikes = step(st, tables, cfg, halo_band_spikes=None)
            rec = record_step(rec, spikes, gids, st["t"], recorder)
            out = spikes if record_spikes else jnp.sum(spikes)
            return (new_state, rec), out

        (state, rec), out = jax.lax.scan(
            body_rec, (state, init_recorder_state(recorder)), None,
            length=n_steps)
        return state, out, rec

    def body(carry, _):
        new_state, spikes = step(carry, tables, cfg, halo_band_spikes=None)
        out = spikes if record_spikes else jnp.sum(spikes)
        return new_state, out

    return jax.lax.scan(body, state, None, length=n_steps)


def _run_plastic(state: dict, tables, stdp_aux: dict,
                 cfg: EngineConfig, n_steps: int):
    """Scan with STDP enabled: synapse tables join the carry.

    ``stdp_aux`` comes from ``init_plasticity`` (inverse index, masks,
    trace state).  Single-shard only: there is no halo source here, so
    only the local tier is stepped -- halo tiers in ``stdp_aux`` (a
    multi-tile config's tables) are ignored, exactly like delivery
    ignores them without halo spikes.  The distributed plastic path is
    ``dist_engine.make_sim_fn`` with ``EngineConfig.stdp`` set.

    Delivery and STDP run through ``plastic_delivery_stdp`` -- one
    fused Pallas launch when kernels are enabled, the two-pass
    reference otherwise.
    """
    if cfg.mode != "event":
        raise ValueError(
            f"plastic runs require mode='event' (got {cfg.mode!r}): the "
            "STDP update is event-driven on the same compaction as "
            "delivery")
    spec = cfg.spec()
    plan = spec.delivery_plan(getattr(tables, "storage", None))[:1]
    masks = stdp_aux["masks"][:1]
    traces_init = {"x_pre": stdp_aux["traces"]["x_pre"][:1],
                   "x_post": stdp_aux["traces"]["x_post"]}

    def body(carry, _):
        st, tabs, traces = carry
        slot = st["t"] % cfg.d_ring
        new_state, spikes = step(st, tabs, cfg, halo_band_spikes=None,
                                 deliver=False)
        i_ring, tiers, traces, ev, dr = plastic_delivery_stdp(
            [tabs["local"]], masks, stdp_aux["inv"], traces, [spikes],
            spec, new_state["i_ring"], slot, cfg, plan)
        m = new_state["metrics"]
        new_state = dict(new_state, i_ring=i_ring,
                         metrics=dict(m, events=m["events"] + ev,
                                      dropped=m["dropped"] + dr))
        tabs = with_local_tier(tabs, tiers[0])
        return (new_state, tabs, traces), jnp.sum(spikes)

    return jax.lax.scan(body, (state, tables, traces_init), None,
                        length=n_steps)


def init_plasticity(tables: dict, cfg: EngineConfig) -> dict:
    """Build the STDP auxiliaries (inverse index, plastic masks, traces).

    Covers every tier the tables carry -- local plus any halo bands --
    so post-spikes reach their cross-tile incoming synapses through the
    inverse index.  Single-shard tables have no halo tiers, so this
    reduces to the local-only index the plastic ``simulate`` path
    consumes; the distributed engine builds the same structures per
    shard via ``dist_engine.build_dist_inverse_index``.
    """
    from .stdp import (build_inverse_index, check_weight_invariant,
                       init_stdp_state, plastic_masks)

    tiers = [tables["local"]] + list(tables.get("halo", []))
    n_local = cfg.spec().n_local
    check_weight_invariant(tiers, cfg.stdp)
    return {
        "inv": build_inverse_index(tiers, n_local),
        "masks": plastic_masks(tiers),
        "traces": init_stdp_state(tiers, n_local),
    }


def firing_rate_hz(state: dict, cfg: EngineConfig,
                   n_steps: Optional[int] = None) -> float:
    """Mean firing rate over the simulated window (active neurons only).

    ``n_steps=None`` derives the window from the state's own step
    counter ``t`` -- correct for same-tiling resumed/segmented runs and
    for stacked ``(TY, TX, ...)`` distributed state (the metrics are
    per-tile partial sums; ``jnp.sum`` totals them).  NOT
    retile-proof: an elastic retile zeroes the per-tile metrics (the
    history moves to the checkpoint manifest), so for runs that may
    have retiled use ``SimDriver.firing_rate_hz``, which re-adds the
    manifest-carried base.
    """
    if n_steps is None:
        n_steps = int(np.asarray(jnp.max(state["t"])))
    n_active = float(np.asarray(jnp.sum(state["active"])))
    spikes = float(np.asarray(jnp.sum(state["metrics"]["spikes"])))
    sim_sec = n_steps * cfg.lif.dt_ms * 1e-3
    return spikes / max(n_active, 1.0) / max(sim_sec, 1e-9)
