"""Elastic re-tiling of checkpointed distributed SNN state.

A DPSNN-style job checkpointed on a ``tiles_y x tiles_x`` decomposition
must be able to come back on a *different* one (the MPI analogue:
resubmitting the same slab on a different process geometry).  The global
model is tiling-invariant -- a neuron is identified by its **global
column id** ``gy * W + gx`` plus its within-column index -- so restore
is a pure relayout:

  * ``v``, ``c``, ``refrac``, ``active``: permuted per neuron by global
    column id (padded slots of the new tiling get inert fill values);
  * ``i_ring``: the delay ring is *target*-indexed, so every in-flight
    delayed current moves with its target column; the slot axis is kept
    as-is and ``t`` is preserved, so the ``t % d_ring`` alignment
    survives the move exactly;
  * ``t``: broadcast unchanged to the new tile array;
  * ``metrics``: **zeroed**.  Cumulative run totals are global scalars,
    not relayout-able per-tile state: parking them on an arbitrary tile
    (the old behaviour put the whole history on tile (0, 0)) made
    per-tile metric reads tiling-dependent.  The totals accumulated
    before the retile travel in the checkpoint *manifest*
    (``SimDriver`` saves ``metric_base`` / ``metric_totals`` meta and
    re-adds the base to everything it reports), and the relaid state's
    metrics restart at zero -- post-retile per-tile metrics describe
    post-retile activity only;
  * ``rng``: per-tile streams are re-derived (``fold_in`` of the old
    (0, 0) key by new tile index) -- the resumed dynamics are a valid
    continuation, not a bitwise replay of the old tiling's stream.

Synapse tables are **not** relaid out: they are rebuilt
deterministically for the new decomposition from the same engine seed
(``build_dist_tables``), exactly like DPSNN re-deriving its connectivity
from the configuration on restart.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .grid import TileDecomposition


def global_column_ids(d: TileDecomposition) -> np.ndarray:
    """(tiles_y, tiles_x, tile_cols) global column id; -1 for padded
    columns that lie outside the logical grid."""
    H, W = d.grid.height, d.grid.width
    out = np.full((d.tiles_y, d.tiles_x, d.tile_cols), -1, np.int64)
    for ty in range(d.tiles_y):
        for tx in range(d.tiles_x):
            oy, ox = d.tile_origin(ty, tx)
            ys = oy + np.arange(d.tile_h)[:, None]
            xs = ox + np.arange(d.tile_w)[None, :]
            gid = np.where((ys < H) & (xs < W), ys * W + xs, -1)
            out[ty, tx] = gid.ravel()
    return out


def neuron_gather_map(old: TileDecomposition,
                      new: TileDecomposition) -> np.ndarray:
    """Per-neuron relayout map between two tilings of the same grid.

    Returns ``src`` of shape ``(new.tiles_y, new.tiles_x, new.n_local)``:
    for each neuron slot of the new layout, the flat index of the same
    global neuron in the old layout flattened to
    ``(old.tiles_y * old.tiles_x * old.n_local,)``, or -1 for slots in
    padded columns (no logical neuron lives there).
    """
    if old.grid != new.grid:
        raise ValueError(f"grid mismatch: {old.grid} != {new.grid}")
    n_per = old.grid.n_per_column
    # flat old column position of each global column id
    gid_old = global_column_ids(old).reshape(-1)
    src_col = np.full(old.grid.n_columns, -1, np.int64)
    pos = np.where(gid_old >= 0)[0]
    src_col[gid_old[pos]] = pos
    # new slot -> old flat column -> old flat neuron
    gid_new = global_column_ids(new)
    col_src = np.where(gid_new >= 0, src_col[np.maximum(gid_new, 0)], -1)
    src = col_src[..., None] * n_per + np.arange(n_per)
    src = np.where(col_src[..., None] >= 0, src, -1)
    return src.reshape(new.tiles_y, new.tiles_x, new.n_local)


def retile_config(cfg, tiles_y: int, tiles_x: int):
    """A DistConfig identical to ``cfg`` but on a different tiling."""
    decomp = dataclasses.replace(cfg.engine.decomp, tiles_y=tiles_y,
                                 tiles_x=tiles_x)
    return dataclasses.replace(
        cfg, engine=dataclasses.replace(cfg.engine, decomp=decomp))


def retile_state(state: dict, old: TileDecomposition,
                 new: TileDecomposition) -> dict:
    """Relayout a (host-side) distributed sim state onto a new tiling.

    ``state`` is the pytree produced by ``init_dist_state`` /
    ``restore_checkpoint`` with every leaf carrying leading
    ``(old.tiles_y, old.tiles_x)`` tile dims.  Returns the same pytree
    shaped for ``new``.  Pure host-side numpy; callers ``device_put``
    the result with the new mesh's shardings.
    """
    src = neuron_gather_map(old, new)          # (TY2, TX2, n_local2)
    valid = src >= 0
    idx = np.maximum(src, 0)
    ty2, tx2 = new.tiles_y, new.tiles_x

    def permute(leaf, fill):
        flat = np.asarray(leaf).reshape(-1)
        return np.where(valid, flat[idx], flat.dtype.type(fill))

    neuron = {
        "v": permute(state["neuron"]["v"], 0.0),
        "c": permute(state["neuron"]["c"], 0.0),
        "refrac": permute(state["neuron"]["refrac"], 0),
    }
    active = permute(state["active"], False)

    # delay ring: (TY1, TX1, D, n1) -> per-slot neuron permutation
    ring = np.asarray(state["i_ring"])
    d_ring = ring.shape[2]
    ring_flat = np.moveaxis(ring, 2, 0).reshape(d_ring, -1)
    new_ring = np.where(valid[None], ring_flat[:, idx],
                        ring_flat.dtype.type(0))
    new_ring = np.moveaxis(new_ring, 0, 2)     # (TY2, TX2, D, n2)

    t_old = np.asarray(state["t"]).reshape(-1)[0]
    t = np.full((ty2, tx2), t_old, dtype=np.asarray(state["t"]).dtype)

    # cumulative metric totals are carried as global scalars in the
    # checkpoint manifest (see module docstring), not smeared over tiles
    metrics = {k: np.zeros((ty2, tx2), dtype=np.asarray(v).dtype)
               for k, v in state["metrics"].items()}

    base_key = jnp.asarray(np.asarray(state["rng"]).reshape(-1, 2)[0])
    rng = np.stack([
        np.stack([np.asarray(jax.random.fold_in(base_key, y * tx2 + x))
                  for x in range(tx2)])
        for y in range(ty2)])

    return {
        "neuron": {k: jnp.asarray(v) for k, v in neuron.items()},
        "i_ring": jnp.asarray(new_ring),
        "t": jnp.asarray(t),
        "rng": jnp.asarray(rng),
        "active": jnp.asarray(active),
        "metrics": {k: jnp.asarray(v) for k, v in metrics.items()},
    }
