"""Elastic re-tiling of checkpointed distributed SNN state.

A DPSNN-style job checkpointed on a ``tiles_y x tiles_x`` decomposition
must be able to come back on a *different* one (the MPI analogue:
resubmitting the same slab on a different process geometry).  The global
model is tiling-invariant -- a neuron is identified by its **global
column id** ``gy * W + gx`` plus its within-column index -- so restore
is a pure relayout:

  * ``v``, ``c``, ``refrac``, ``active``: permuted per neuron by global
    column id (padded slots of the new tiling get inert fill values);
  * ``i_ring``: the delay ring is *target*-indexed, so every in-flight
    delayed current moves with its target column; the slot axis is kept
    as-is and ``t`` is preserved, so the ``t % d_ring`` alignment
    survives the move exactly;
  * ``t``: broadcast unchanged to the new tile array;
  * ``metrics``: **zeroed**.  Cumulative run totals are global scalars,
    not relayout-able per-tile state: parking them on an arbitrary tile
    (the old behaviour put the whole history on tile (0, 0)) made
    per-tile metric reads tiling-dependent.  The totals accumulated
    before the retile travel in the checkpoint *manifest*
    (``SimDriver`` saves ``metric_base`` / ``metric_totals`` meta and
    re-adds the base to everything it reports), and the relaid state's
    metrics restart at zero -- post-retile per-tile metrics describe
    post-retile activity only;
  * ``rng``: per-tile streams are re-derived (``fold_in`` of the old
    (0, 0) key by new tile index) -- the resumed dynamics are a valid
    continuation, not a bitwise replay of the old tiling's stream.

Synapse tables of **static** runs are not relaid out: they are rebuilt
deterministically for the new decomposition from the same engine seed
(``build_dist_tables``), exactly like DPSNN re-deriving its connectivity
from the configuration on restart.

**Plastic** runs cannot re-sample: the weights ARE the learned state.
``retile_tables`` therefore relays the whole synapse realization across
tilings by global ``(pre, post)`` synapse identity -- every synapse is
gathered as a ``(pre_gid, post_gid, weight, delay)`` record, re-grouped
by the tile that owns its *target* under the new decomposition, and
re-packed into the new tiling's local/halo-band row structure in a
canonical order (sorted by ``(row, post_gid, dslot)``, input position
as the tie-break for duplicate pairs, so relays compose: born->A->B
lands bit-identically to born->B).  A synapse that cannot be placed
(new-tiling row capacity overflow, or a pre column below the new
tiling's halo-band fan-out floor) raises instead of being dropped --
silently discarding learned weights is exactly the failure mode this
path exists to prevent.  ``retile_plastic`` relays the plastic carry
(per-tier weights + STDP traces) alongside: pre-traces travel by pre
neuron id (halo copies are exact replicas of the home shard's trace, so
re-deriving them from the home value is lossless), post-traces by the
same per-neuron permutation as the membrane state.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .grid import TileDecomposition


def global_column_ids(d: TileDecomposition) -> np.ndarray:
    """(tiles_y, tiles_x, tile_cols) global column id; -1 for padded
    columns that lie outside the logical grid."""
    H, W = d.grid.height, d.grid.width
    out = np.full((d.tiles_y, d.tiles_x, d.tile_cols), -1, np.int64)
    for ty in range(d.tiles_y):
        for tx in range(d.tiles_x):
            oy, ox = d.tile_origin(ty, tx)
            ys = oy + np.arange(d.tile_h)[:, None]
            xs = ox + np.arange(d.tile_w)[None, :]
            gid = np.where((ys < H) & (xs < W), ys * W + xs, -1)
            out[ty, tx] = gid.ravel()
    return out


def neuron_gather_map(old: TileDecomposition,
                      new: TileDecomposition) -> np.ndarray:
    """Per-neuron relayout map between two tilings of the same grid.

    Returns ``src`` of shape ``(new.tiles_y, new.tiles_x, new.n_local)``:
    for each neuron slot of the new layout, the flat index of the same
    global neuron in the old layout flattened to
    ``(old.tiles_y * old.tiles_x * old.n_local,)``, or -1 for slots in
    padded columns (no logical neuron lives there).
    """
    if old.grid != new.grid:
        raise ValueError(f"grid mismatch: {old.grid} != {new.grid}")
    n_per = old.grid.n_per_column
    # flat old column position of each global column id
    gid_old = global_column_ids(old).reshape(-1)
    src_col = np.full(old.grid.n_columns, -1, np.int64)
    pos = np.where(gid_old >= 0)[0]
    src_col[gid_old[pos]] = pos
    # new slot -> old flat column -> old flat neuron
    gid_new = global_column_ids(new)
    col_src = np.where(gid_new >= 0, src_col[np.maximum(gid_new, 0)], -1)
    src = col_src[..., None] * n_per + np.arange(n_per)
    src = np.where(col_src[..., None] >= 0, src, -1)
    return src.reshape(new.tiles_y, new.tiles_x, new.n_local)


def retile_config(cfg, tiles_y: int, tiles_x: int):
    """A DistConfig identical to ``cfg`` but on a different tiling."""
    decomp = dataclasses.replace(cfg.engine.decomp, tiles_y=tiles_y,
                                 tiles_x=tiles_x)
    return dataclasses.replace(
        cfg, engine=dataclasses.replace(cfg.engine, decomp=decomp))


def retile_state(state: dict, old: TileDecomposition,
                 new: TileDecomposition) -> dict:
    """Relayout a (host-side) distributed sim state onto a new tiling.

    ``state`` is the pytree produced by ``init_dist_state`` /
    ``restore_checkpoint`` with every leaf carrying leading
    ``(old.tiles_y, old.tiles_x)`` tile dims.  Returns the same pytree
    shaped for ``new``.  Pure host-side numpy; callers ``device_put``
    the result with the new mesh's shardings.
    """
    src = neuron_gather_map(old, new)          # (TY2, TX2, n_local2)
    valid = src >= 0
    idx = np.maximum(src, 0)
    ty2, tx2 = new.tiles_y, new.tiles_x

    def permute(leaf, fill):
        flat = np.asarray(leaf).reshape(-1)
        return np.where(valid, flat[idx], flat.dtype.type(fill))

    neuron = {
        "v": permute(state["neuron"]["v"], 0.0),
        "c": permute(state["neuron"]["c"], 0.0),
        "refrac": permute(state["neuron"]["refrac"], 0),
    }
    active = permute(state["active"], False)

    # delay ring: (TY1, TX1, D, n1) -> per-slot neuron permutation
    ring = np.asarray(state["i_ring"])
    d_ring = ring.shape[2]
    ring_flat = np.moveaxis(ring, 2, 0).reshape(d_ring, -1)
    new_ring = np.where(valid[None], ring_flat[:, idx],
                        ring_flat.dtype.type(0))
    new_ring = np.moveaxis(new_ring, 0, 2)     # (TY2, TX2, D, n2)

    t_old = np.asarray(state["t"]).reshape(-1)[0]
    t = np.full((ty2, tx2), t_old, dtype=np.asarray(state["t"]).dtype)

    # cumulative metric totals are carried as global scalars in the
    # checkpoint manifest (see module docstring), not smeared over tiles
    metrics = {k: np.zeros((ty2, tx2), dtype=np.asarray(v).dtype)
               for k, v in state["metrics"].items()}

    base_key = jnp.asarray(np.asarray(state["rng"]).reshape(-1, 2)[0])
    rng = np.stack([
        np.stack([np.asarray(jax.random.fold_in(base_key, y * tx2 + x))
                  for x in range(tx2)])
        for y in range(ty2)])

    return {
        "neuron": {k: jnp.asarray(v) for k, v in neuron.items()},
        "i_ring": jnp.asarray(new_ring),
        "t": jnp.asarray(t),
        "rng": jnp.asarray(rng),
        "active": jnp.asarray(active),
        "metrics": {k: jnp.asarray(v) for k, v in metrics.items()},
    }


# ---------------------------------------------------------------------------
# Plastic-table relay: the synapse realization travels across tilings
# ---------------------------------------------------------------------------

def local_gid_map(d: TileDecomposition, ty: int, tx: int) -> np.ndarray:
    """(n_local,) global neuron id of each local slot; -1 in padded
    columns.  (No trailing compaction-sink slot -- cf. the observatory's
    ``obs.record.tile_gid_map``, which appends one.)"""
    gcol = global_column_ids(d)[ty, tx]
    n_per = d.grid.n_per_column
    g = gcol[:, None] * n_per + np.arange(n_per)[None, :]
    return np.where(gcol[:, None] >= 0, g, -1).ravel()


def band_gid_map(d: TileDecomposition, band_cols: np.ndarray,
                 ty: int, tx: int, n_exc: int) -> np.ndarray:
    """(n_cols_b * n_exc,) global neuron id of each halo-band source
    row (excitatory sources only); -1 for region columns outside the
    logical grid."""
    H, W = d.grid.height, d.grid.width
    oy, ox = d.tile_origin(ty, tx)
    ry, rx = band_cols // d.region_w, band_cols % d.region_w
    gy, gx = oy - d.radius + ry, ox - d.radius + rx
    ok = (gy >= 0) & (gy < H) & (gx >= 0) & (gx < W)
    gcol = np.where(ok, gy * W + gx, -1)
    n_per = d.grid.n_per_column
    g = gcol[:, None] * n_per + np.arange(n_exc)[None, :]
    return np.where(gcol[:, None] >= 0, g, -1).ravel()


def gather_synapse_stream(tables: dict, d: TileDecomposition,
                          spec) -> dict:
    """Flatten stacked per-shard tables into one global synapse stream.

    Every stored synapse appears exactly once (it lives in its target's
    shard); iteration order is (shard-major, tier, row, slot), giving a
    deterministic input position used as the relay's duplicate-pair
    tie-break.  Returns 1-D arrays ``pre`` / ``post`` (global neuron
    ids), ``w``, ``dslot``.
    """
    bands = spec.halo_bands()
    n_exc = spec.n_exc_per_col
    host = {
        "local": {k: np.asarray(v) for k, v in tables["local"].items()},
        "halo": [{k: np.asarray(v) for k, v in t.items()}
                 for t in tables["halo"]],
    }
    pres, posts, ws, ds = [], [], [], []
    for ty in range(d.tiles_y):
        for tx in range(d.tiles_x):
            lmap = local_gid_map(d, ty, tx)
            pre_maps = [lmap] + [band_gid_map(d, b["cols"], ty, tx, n_exc)
                                 for b in bands]
            tiers = [host["local"]] + host["halo"]
            for tier, pmap in zip(tiers, pre_maps):
                tgt = tier["tgt"][ty, tx]
                nnz = tier["nnz"][ty, tx]
                cap = tgt.shape[1]
                valid = np.arange(cap)[None, :] < nnz[:, None]
                rr, kk = np.nonzero(valid)
                pres.append(pmap[rr])
                posts.append(lmap[tgt[rr, kk]])
                ws.append(tier["w"][ty, tx][rr, kk])
                ds.append(tier["dslot"][ty, tx][rr, kk])

    def cat(parts, dtype=None):
        out = (np.concatenate(parts) if parts
               else np.empty(0, dtype or np.int64))
        return out

    # weights travel as float32 regardless of the storage dtype (the
    # cast is value-exact under the v3 sampling-time quantization), so
    # canonical stream digests are storage-format invariant
    stream = {"pre": cat(pres), "post": cat(posts),
              "w": cat(ws, np.float32).astype(np.float32),
              "dslot": cat(ds, np.int8)}
    if len(stream["pre"]) and (stream["pre"].min() < 0
                               or stream["post"].min() < 0):
        raise ValueError("synapse stream references a padded (non-"
                         "logical) neuron slot -- corrupt tables")
    return stream


def pack_synapse_stream(stream: dict, d: TileDecomposition, spec,
                        storage=None):
    """Pack a global synapse stream into ``d``'s stacked table layout.

    ``storage``: target ``TableStorage``; defaults to the spec's
    analytic descriptor.  Pass a compressed descriptor to pack straight
    into truncated caps (safe whenever the stream is the relay of a
    realization those caps were derived from -- relaying preserves
    per-row occupancy exactly).

    Refuses (raises) rather than drops: a row whose relaid synapse
    count exceeds the target capacity, or a pre column falling below
    the new tiling's halo-band fan-out floor, would silently lose
    learned weights.
    """
    from .synapses import SynapseTables, np_dtype
    if storage is None:
        storage = spec.storage()
    H, W = d.grid.height, d.grid.width
    n_per = d.grid.n_per_column
    n_exc = spec.n_exc_per_col
    bands = spec.halo_bands()
    wdt = np_dtype(storage.weight_dtype)
    tdt = np_dtype(storage.tgt_dtype)
    band_of = np.full(d.region_cols, -1, np.int64)
    bandcol_of = np.full(d.region_cols, -1, np.int64)
    for bi, b in enumerate(bands):
        band_of[b["cols"]] = bi
        bandcol_of[b["cols"]] = np.arange(len(b["cols"]))

    pre, post = stream["pre"], stream["post"]
    w, dslot = stream["w"], stream["dslot"]
    idx = np.arange(len(pre))

    post_col, post_n = post // n_per, post % n_per
    gy, gx = post_col // W, post_col % W
    ty2, tx2 = gy // d.tile_h, gx // d.tile_w
    ly, lx = gy - ty2 * d.tile_h, gx - tx2 * d.tile_w
    tgt_local = (ly * d.tile_w + lx) * n_per + post_n

    pre_col, pre_n = pre // n_per, pre % n_per
    py, px = pre_col // W, pre_col % W
    ry = py - (ty2 * d.tile_h - d.radius)
    rx = px - (tx2 * d.tile_w - d.radius)
    if len(pre) and ((ry < 0).any() or (ry >= d.region_h).any()
                     or (rx < 0).any() or (rx >= d.region_w).any()):
        raise ValueError(
            "a relaid synapse reaches beyond the stencil radius of the "
            "new tiling -- the stream does not belong to this model")
    in_tile = ((ry >= d.radius) & (ry < d.radius + d.tile_h)
               & (rx >= d.radius) & (rx < d.radius + d.tile_w))
    row_local = ((ry - d.radius) * d.tile_w + (rx - d.radius)) * n_per \
        + pre_n
    rc = ry * d.region_w + rx
    bi = np.where(in_tile, -1, band_of[np.clip(rc, 0, d.region_cols - 1)])
    unplaced = ~in_tile & (bi < 0)
    if unplaced.any():
        raise ValueError(
            f"{int(unplaced.sum())} learned synapse(s) have no slot "
            f"under the {d.tiles_y}x{d.tiles_x} tiling: their pre "
            "columns fall below the new halo-band fan-out floor.  "
            "Retiling a plastic run must never drop weights; resume on "
            "a tiling whose halo bands cover every learned source "
            "column (usually: fewer, larger tiles)")
    if (~in_tile & (pre_n >= n_exc)).any():
        raise ValueError("inhibitory synapse stored across tiles -- "
                         "corrupt stream (inhibitory sources only "
                         "project within their own column)")
    row_band = bandcol_of[np.clip(rc, 0, d.region_cols - 1)] * n_exc + pre_n

    def pack(sel, n_rows, cap, rows_of, what):
        rows = rows_of[sel]
        counts = np.bincount(rows, minlength=n_rows) if len(rows) \
            else np.zeros(n_rows, np.int64)
        if (counts > cap).any():
            worst = int(counts.max())
            raise ValueError(
                f"{what}: {worst} relaid synapses in one source row "
                f"exceed the new tiling's row capacity {cap} -- "
                "refusing to drop learned weights")
        # canonical within-row order: (post, dslot, input position)
        order = np.lexsort((idx[sel], dslot[sel], post[sel], rows))
        rows_s = rows[order]
        within = np.arange(len(rows_s)) - np.repeat(
            np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
        tgt_a = np.zeros((n_rows + 1, cap), tdt)
        w_a = np.zeros((n_rows + 1, cap), wdt)
        d_a = np.zeros((n_rows + 1, cap), np.int8)
        sidx = np.nonzero(sel)[0][order]
        tgt_a[rows_s, within] = tgt_local[sidx]
        w_a[rows_s, within] = w[sidx].astype(wdt)
        d_a[rows_s, within] = dslot[sidx]
        nnz = np.concatenate([counts, [0]]).astype(np.int32)
        return {"tgt": tgt_a, "w": w_a, "dslot": d_a, "nnz": nnz}

    band_caps = list(storage.halo_caps)
    out = {"local": [], "halo": [[] for _ in bands]}
    for y in range(d.tiles_y):
        row_out, halo_rows = [], [[] for _ in bands]
        for x in range(d.tiles_x):
            here = (ty2 == y) & (tx2 == x)
            row_out.append(pack(
                here & in_tile, spec.n_local, storage.cap_local,
                row_local, f"tile ({y},{x}) local tier"))
            for b_i, b in enumerate(bands):
                halo_rows[b_i].append(pack(
                    here & ~in_tile & (bi == b_i), b["rows"],
                    band_caps[b_i], row_band,
                    f"tile ({y},{x}) halo band {b_i}"))
        out["local"].append(row_out)
        for b_i in range(len(bands)):
            out["halo"][b_i].append(halo_rows[b_i])

    def stack(grid_of_tiers):
        return {k: jnp.asarray(np.stack(
            [np.stack([t[k] for t in row]) for row in grid_of_tiers]))
            for k in ("tgt", "w", "dslot", "nnz")}

    return SynapseTables(stack(out["local"]),
                         [stack(g) for g in out["halo"]], storage)


def retile_tables(tables, old_d: TileDecomposition, old_spec,
                  new_d: TileDecomposition, new_spec, storage=None):
    """Relay a (stacked) synapse realization onto a new tiling by
    global (pre, post) synapse identity -- weights travel, nothing is
    re-sampled.  Pure host-side; callers ``device_put`` the result.
    ``storage`` selects the packed layout (default: ``new_spec``'s
    analytic descriptor)."""
    if old_d.grid != new_d.grid:
        raise ValueError(f"grid mismatch: {old_d.grid} != {new_d.grid}")
    stream = gather_synapse_stream(tables, old_d, old_spec)
    return pack_synapse_stream(stream, new_d, new_spec, storage)


def retile_plastic(plastic: dict, old_tables,
                   old_d: TileDecomposition, old_spec,
                   new_d: TileDecomposition, new_spec, storage=None):
    """Relay the plastic carry (per-tier weights + STDP traces).

    ``old_tables`` supplies the old tiling's realization *structure*
    (targets/delays/occupancy); the live weights come from
    ``plastic["w"]`` and override the structural weights entry-for-entry
    (same shapes by construction), so the relaid layout is identical to
    relaying the structure itself -- the canonical order never keys on
    the weight value.
    """
    carried = {
        "local": dict(old_tables["local"],
                      w=np.asarray(plastic["w"][0])),
        "halo": [dict(t, w=np.asarray(pw)) for t, pw in
                 zip(old_tables["halo"], plastic["w"][1:])],
    }
    new_tabs = pack_synapse_stream(
        gather_synapse_stream(carried, old_d, old_spec), new_d, new_spec,
        storage)
    w_new = [new_tabs["local"]["w"]] + [t["w"] for t in new_tabs["halo"]]

    # pre-traces: per pre-neuron values, carried for the local tier only
    # (band replicas are exchanged per step, never stored -- see
    # ``dist_engine.make_sim_fn``); relaid like the membrane state, by
    # global neuron id
    n_per = old_d.grid.n_per_column
    trace = np.zeros((old_d.grid.n_neurons,), np.float32)
    xp_local = np.asarray(plastic["x_pre"][0])
    for ty in range(old_d.tiles_y):
        for tx in range(old_d.tiles_x):
            lmap = local_gid_map(old_d, ty, tx)
            live = lmap >= 0
            trace[lmap[live]] = xp_local[ty, tx, :len(lmap)][live]

    def lift_traces(gid_map_fn, rows):
        out = np.zeros((new_d.tiles_y, new_d.tiles_x, rows + 1),
                       np.float32)
        for ty in range(new_d.tiles_y):
            for tx in range(new_d.tiles_x):
                g = gid_map_fn(ty, tx)
                out[ty, tx, :rows] = np.where(g >= 0,
                                              trace[np.maximum(g, 0)], 0.0)
        return jnp.asarray(out)

    x_pre = [lift_traces(lambda y, x: local_gid_map(new_d, y, x),
                         new_spec.n_local)]

    # post-trace: a per-local-neuron quantity, same permutation as v
    src = neuron_gather_map(old_d, new_d)
    valid = src >= 0
    xpost_flat = np.asarray(plastic["x_post"]).reshape(-1)
    x_post = np.where(valid, xpost_flat[np.maximum(src, 0)],
                      np.float32(0.0)).astype(np.float32)

    assert n_per == new_d.grid.n_per_column
    return {"w": w_new, "x_pre": x_pre, "x_post": jnp.asarray(x_post)}
