"""2D halo exchange of spike blocks over the TPU torus via ppermute.

DPSNN sends MPI point-to-point spike messages to every process whose
stencil overlaps the sender.  On a TPU mesh the same communication pattern
is a *stencil halo exchange*: each shard owns a ``tile_h x tile_w`` block
of columns and must import the spikes of all columns within the stencil
radius R around its tile.  ``collective-permute`` (``jax.lax.ppermute``)
is the native ICI primitive for neighbour shifts on the torus.

Two modes:

* ``strip`` (default; exact-bytes): each hop sends only the rows/cols the
  halo actually needs -- ``min(tile, R - (k-1)*tile)`` wide strips.  Total
  import volume per shard = exact halo area x payload width.  This is the
  analogue of DPSNN's "send spikes only to stencil-reachable processes".
* ``block`` (baseline; simple): each hop forwards whole neighbour tiles
  and the region window is sliced afterwards.  Strictly more bytes when
  R < tile; kept as the naive reference for the perf comparison.

The simulated slab is *flat* (not periodic): boundary shards must see
zero spikes outside the grid.  ``ppermute`` conveniently zero-fills
destinations that receive no message, so we simply omit wrapping pairs
from the permutation.

Payload layout: ``(tile_h, tile_w, F)`` where F is the per-column feature
width (e.g. ``n_exc`` spike lanes, optionally bit-packed -- see
``pack_bits``/``unpack_bits``).  Only excitatory neurons project laterally
so only their spikes travel.
"""

from __future__ import annotations

import math
from typing import Sequence, Union

import jax
import jax.numpy as jnp

AxisName = Union[str, Sequence[str]]


def _one_axis_size(a) -> int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)      # static int on pre-axis_size jax


def _axis_size(axis_name: AxisName) -> int:
    if isinstance(axis_name, (tuple, list)):
        s = 1
        for a in axis_name:
            s *= _one_axis_size(a)
        return s
    return _one_axis_size(axis_name)


def shift(x: jnp.ndarray, axis_name: AxisName, k: int) -> jnp.ndarray:
    """Bring data from the shard ``k`` positions *before* this one.

    After ``shift(x, ax, k)`` shard ``i`` holds what shard ``i - k`` had
    (zeros when ``i - k`` is outside the axis -- flat, non-periodic grid).
    """
    if k == 0:
        return x
    n = _axis_size(axis_name)
    names = tuple(axis_name) if isinstance(axis_name, (tuple, list)) else axis_name
    perm = [(i, i + k) for i in range(n) if 0 <= i + k < n]
    return jax.lax.ppermute(x, names, perm)


def _halo_strips(x: jnp.ndarray, axis_name: AxisName, radius: int,
                 tile: int, dim: int, before: bool) -> list:
    """Strips assembling the halo on one side of ``dim``.

    ``before=True``: the halo rows/cols that precede the tile (imported
    from shards with smaller index along ``axis_name``).  Returned in
    top-to-bottom (left-to-right) region order.
    """
    hops = int(math.ceil(radius / tile))
    parts = []
    for k in range(hops, 0, -1) if before else range(1, hops + 1):
        take = min(tile, radius - (k - 1) * tile)
        if before:
            # neighbour i-k contributes its *last* ``take`` rows
            strip = jax.lax.slice_in_dim(x, tile - take, tile, axis=dim)
            parts.append(shift(strip, axis_name, k))
        else:
            # neighbour i+k contributes its *first* ``take`` rows
            strip = jax.lax.slice_in_dim(x, 0, take, axis=dim)
            parts.append(shift(strip, axis_name, -k))
    return parts


def exchange_halo_2d(x: jnp.ndarray, *, radius: int,
                     axis_y: AxisName, axis_x: AxisName,
                     mode: str = "strip") -> jnp.ndarray:
    """Assemble the dilated region block from per-shard tiles.

    Args:
      x: per-shard ``(tile_h, tile_w, ...)`` block (leading 2 dims spatial).
      radius: stencil radius R in columns.
      axis_y / axis_x: mesh axis name(s) for the tile rows / cols.  A tuple
        (e.g. ``("pod", "data")``) folds multiple mesh axes into one
        logical tile axis (pod-major), which is how the multi-pod mesh
        splits the y dimension across pods.
      mode: "strip" (exact bytes) or "block" (whole-tile hops, naive).

    Returns:
      ``(tile_h + 2R, tile_w + 2R, ...)`` region block; out-of-grid halo
      cells are zero.
    """
    if radius == 0:
        return x
    tile_h, tile_w = x.shape[0], x.shape[1]
    if mode == "strip":
        top = _halo_strips(x, axis_y, radius, tile_h, 0, before=True)
        bot = _halo_strips(x, axis_y, radius, tile_h, 0, before=False)
        xy = jnp.concatenate(top + [x] + bot, axis=0)
        left = _halo_strips(xy, axis_x, radius, tile_w, 1, before=True)
        right = _halo_strips(xy, axis_x, radius, tile_w, 1, before=False)
        return jnp.concatenate(left + [xy] + right, axis=1)
    if mode == "block":
        hy = int(math.ceil(radius / tile_h))
        hx = int(math.ceil(radius / tile_w))
        cols_y = [shift(x, axis_y, k) for k in range(hy, -hy - 1, -1)]
        xy = jnp.concatenate(cols_y, axis=0)
        lo = hy * tile_h - radius
        xy = jax.lax.slice_in_dim(xy, lo, lo + tile_h + 2 * radius, axis=0)
        cols_x = [shift(xy, axis_x, k) for k in range(hx, -hx - 1, -1)]
        xx = jnp.concatenate(cols_x, axis=1)
        lo = hx * tile_w - radius
        return jax.lax.slice_in_dim(xx, lo, lo + tile_w + 2 * radius, axis=1)
    raise ValueError(f"unknown halo mode {mode!r}")


# ---------------------------------------------------------------------------
# Spike payload bit-packing (beyond-paper optimization of the collective
# term: 1 bit/neuron on the wire instead of 4 bytes/neuron).
# ---------------------------------------------------------------------------

def packed_width(n: int) -> int:
    return (n + 7) // 8


def pack_bits(spikes: jnp.ndarray) -> jnp.ndarray:
    """Pack a trailing axis of {0,1} f32/bool spikes into uint8 bitmap.

    (..., F) -> (..., ceil(F/8)); bit j of byte b = lane 8*b + j.
    """
    f = spikes.shape[-1]
    pad = (-f) % 8
    bits = spikes.astype(jnp.uint8)
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (packed_width(f), 8))
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint8)


def unpack_bits(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of ``pack_bits``: (..., ceil(n/8)) uint8 -> (..., n) f32."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)
    flat = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * 8,))
    return flat[..., :n].astype(jnp.float32)


def halo_import_bytes(tile_h: int, tile_w: int, radius: int,
                      payload_bytes_per_col: int, mode: str = "strip") -> int:
    """Analytic per-shard import volume (for the roofline collective term)."""
    rh, rw = tile_h + 2 * radius, tile_w + 2 * radius
    if mode == "strip":
        halo_cols = rh * rw - tile_h * tile_w
        return halo_cols * payload_bytes_per_col
    hy = int(math.ceil(radius / tile_h))
    hx = int(math.ceil(radius / tile_w))
    y_cols = 2 * hy * tile_h * tile_w
    x_cols = 2 * hx * tile_w * (tile_h + 2 * radius)
    return (y_cols + x_cols) * payload_bytes_per_col
