"""Spike-timing-dependent plasticity (the "P" in DPSNN).

Pair-based STDP with exponential traces, applied event-driven on the
source-major synapse tables:

  * every step:  x_pre <- x_pre * exp(-dt/tau+) + pre_spike
                 x_post <- x_post * exp(-dt/tau-) + post_spike
  * LTD, at pre-spike time: for each spiking source row (event-compacted,
    same compaction as delivery), every synapse in the row depresses by
    ``a_minus * x_post[target]``.
  * LTP, at post-spike time: for each spiking target, every *incoming*
    synapse potentiates by ``a_plus * x_pre[source row]``.  Incoming
    synapses are reached through a target-major *inverse index* built
    once at table-construction time (flat "virtual slot" pointers into
    the tiered tables).

Only excitatory synapses are plastic (mask fixed at build time; DPSNN's
convention).  Weights clamp to [0, w_max].

The inverse index adds 4 B/synapse when plasticity is enabled; it is the
TPU-shaped replacement for DPSNN's target-side synapse lists, which give
the CPU code LTP access for free.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class STDPParams:
    tau_plus_ms: float = 20.0
    tau_minus_ms: float = 20.0
    a_plus: float = 0.005        # LTP amplitude (mV of efficacy per pair)
    a_minus: float = 0.00525     # LTD amplitude (slightly dominant)
    w_max: float = 1.0
    dt_ms: float = 1.0

    @property
    def decay_plus(self) -> float:
        return float(math.exp(-self.dt_ms / self.tau_plus_ms))

    @property
    def decay_minus(self) -> float:
        return float(math.exp(-self.dt_ms / self.tau_minus_ms))


def _tier_sizes(tiers: Sequence[dict]) -> Tuple[np.ndarray, np.ndarray]:
    sizes = np.array([int(np.prod(t["tgt"].shape)) for t in tiers])
    bases = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return bases, sizes


def build_inverse_index(tiers: Sequence[dict], n_targets: int,
                        cap_pad: float = 1.3) -> dict:
    """Target-major index over all tiers (host-side, numpy).

    Returns dict with:
      ``slots``  -- (n_targets, K_in) int32 virtual flat slots, padded
                    with ``total_size`` (a sentinel beyond every tier);
      ``n_in``   -- (n_targets,) int32 actual in-degree (clipped to K_in);
      ``bases``  -- per-tier virtual base offsets.

    Fully vectorized: the distributed engine builds one index per shard
    (and rebuilds them on every elastic retile), so this sits on the
    restore path.  Per-target slot order is (tier, row, k) ascending --
    the same order a per-synapse append loop would produce -- so the
    LTP scatter's floating-point accumulation order is deterministic.
    """
    bases, sizes = _tier_sizes(tiers)
    total = int(bases[-1] + sizes[-1]) if len(sizes) else 0
    tgt_parts: List[np.ndarray] = []
    slot_parts: List[np.ndarray] = []
    for t, base in zip(tiers, bases):
        tgt = np.asarray(t["tgt"])
        nnz = np.asarray(t["nnz"])
        rows, cap = tgt.shape
        k = np.arange(cap)[None, :]
        valid = k < nnz[:, None]
        rr, kk = np.nonzero(valid)
        tgt_parts.append(tgt[rr, kk].astype(np.int64))
        slot_parts.append(base + rr * cap + kk)
    tgts = (np.concatenate(tgt_parts) if tgt_parts
            else np.empty(0, np.int64))
    vslots = (np.concatenate(slot_parts) if slot_parts
              else np.empty(0, np.int64))
    counts = np.bincount(tgts, minlength=max(n_targets, 1))[:n_targets]
    mean_in = max(1.0, len(tgts) / max(n_targets, 1))
    maxdeg = int(counts.max()) if n_targets else 1
    k_in = int(math.ceil(cap_pad * max(mean_in, maxdeg)))
    slots = np.full((n_targets, k_in), total, dtype=np.int32)
    n_in = np.minimum(counts, k_in).astype(np.int32)
    if len(tgts):
        order = np.argsort(tgts, kind="stable")
        ts, vs = tgts[order], vslots[order]
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        within = np.arange(len(ts)) - np.repeat(starts, counts)
        keep = within < k_in
        slots[ts[keep], within[keep]] = vs[keep]
    clipped = int(len(tgts) - n_in.sum())
    return {"slots": jnp.asarray(slots), "n_in": jnp.asarray(n_in),
            "bases": bases, "sizes": sizes, "total": total,
            "clipped": clipped}


def init_stdp_state(tiers: Sequence[dict], n_local: int) -> dict:
    return {
        "x_pre": [jnp.zeros((t["tgt"].shape[0],), jnp.float32)
                  for t in tiers],
        "x_post": jnp.zeros((n_local,), jnp.float32),
    }


def plastic_masks(tiers: Sequence[dict]) -> list:
    """Excitatory (w>0 at build time) synapses are plastic.

    Accepts either full-weight tiers or the int8-folded mask tables of
    the distributed engine (``dist_engine.fold_plastic_tables``) -- the
    mask is returned as float32 either way, the dtype every STDP
    product reads it at."""
    return [(t["w"] > 0).astype(jnp.float32) for t in tiers]


def check_weight_invariant(tiers: Sequence[dict], params: STDPParams):
    """Refuse build weights above ``w_max`` at plasticity init.

    The one-launch kernel path scatters the in-kernel LTD result
    straight back (``kernels.plastic_step``); its bitwise equivalence
    to the reference ``stdp_step`` relies on the full-tier
    ``clip(None, w_max)`` being a no-op, i.e. every weight starting
    (and inductively staying) <= w_max.  Default parameters satisfy it
    with wide margin (j_exc ~ 0.44 mV at the jitter ceiling vs
    w_max = 1.0); a config that violates it must raise here, not
    silently diverge between the two paths.
    """
    hi = max(float(jnp.max(t["w"].astype(jnp.float32))) for t in tiers)
    if hi > params.w_max:
        raise ValueError(
            f"build weight {hi} exceeds STDP w_max={params.w_max}; the "
            "plastic step requires w <= w_max at init (raise w_max or "
            "lower j_exc_mv)")


def stdp_step(tiers: Sequence[dict], masks: Sequence[jnp.ndarray],
              inv: dict, state: dict,
              spike_tiers: Sequence[jnp.ndarray],
              spikes_local: jnp.ndarray,
              params: STDPParams,
              pre_caps: Sequence[int], post_cap: int):
    """One STDP update.  Returns (new_tiers, new_state).

    ``spike_tiers[i]`` is the (rows_i,) pre-spike vector of tier i (the
    same vectors delivery used); ``spikes_local`` the (n_local,) post
    spikes of this step.  Composed of the LTD phase below plus
    ``stdp_ltp_finalize`` -- the fused-kernel path replaces only the
    former (in-launch with delivery) and shares the latter verbatim.
    """
    p = params
    new_tiers = [dict(t) for t in tiers]

    # ---- traces (decay first: updates see *previous* activity) ---------
    x_pre = [xp * p.decay_plus for xp in state["x_pre"]]
    x_post = state["x_post"] * p.decay_minus

    # ---- LTD: pre spike => depress by post trace -----------------------
    for i, (t, mask, spk, cap) in enumerate(
            zip(tiers, masks, spike_tiers, pre_caps)):
        n_rows = t["tgt"].shape[0] - 1
        (rows,) = jnp.nonzero(spk[:n_rows] > 0, size=cap,
                              fill_value=n_rows)
        tgt_rows = t["tgt"][rows]                    # (cap_a, cap)
        dw = -p.a_minus * x_post[tgt_rows] * mask[rows]
        w = new_tiers[i]["w"].at[rows].add(dw.astype(t["w"].dtype))
        new_tiers[i]["w"] = jnp.clip(
            jnp.where(mask > 0, w, new_tiers[i]["w"]), None, p.w_max)

    return stdp_ltp_finalize(new_tiers, masks, inv, x_pre, x_post,
                             spike_tiers, spikes_local, params, post_cap)


def stdp_ltp_finalize(tiers: Sequence[dict], masks: Sequence[jnp.ndarray],
                      inv: dict, x_pre: Sequence[jnp.ndarray],
                      x_post: jnp.ndarray,
                      spike_tiers: Sequence[jnp.ndarray],
                      spikes_local: jnp.ndarray,
                      params: STDPParams, post_cap: int):
    """LTP + final clamp + trace increments on post-LTD tiers.

    ``x_pre`` / ``x_post`` are the *decayed* traces (pre-increment: the
    values this step's updates read).  Shared verbatim between the
    two-pass reference (``stdp_step``) and the one-launch kernel path,
    which applies LTD inside the delivery launch and hands the
    depressed tiers here.
    """
    p = params
    new_tiers = [dict(t) for t in tiers]

    # ---- LTP: post spike => potentiate incoming by pre trace -----------
    n_local = spikes_local.shape[0]
    (tgts,) = jnp.nonzero(spikes_local > 0, size=post_cap,
                          fill_value=n_local)
    safe_tgts = jnp.minimum(tgts, n_local - 1)
    live = (tgts < n_local)[:, None]
    vslots = jnp.where(live, inv["slots"][safe_tgts], inv["total"])
    for i, (t, mask) in enumerate(zip(tiers, masks)):
        base, size = int(inv["bases"][i]), int(inv["sizes"][i])
        cap = t["tgt"].shape[1]
        sel = (vslots >= base) & (vslots < base + size)
        local_v = jnp.where(sel, vslots - base, 0)
        rows, ks = local_v // cap, local_v % cap
        dw = jnp.where(sel, p.a_plus * x_pre[i][rows] * mask[rows, ks], 0.0)
        w = new_tiers[i]["w"].at[rows.ravel(), ks.ravel()].add(
            dw.ravel().astype(t["w"].dtype))
        new_tiers[i]["w"] = jnp.clip(w, None, p.w_max)

    # final clamp to [0, w_max] on plastic synapses
    for i, mask in enumerate(masks):
        w = new_tiers[i]["w"]
        new_tiers[i]["w"] = jnp.where(
            mask > 0, jnp.clip(w, 0.0, p.w_max), w)

    # ---- trace increments ----------------------------------------------
    x_pre = [xp.at[: spk.shape[0]].add(spk)
             for xp, spk in zip(x_pre, spike_tiers)]
    new_state = {"x_pre": x_pre, "x_post": x_post + spikes_local}
    return new_tiers, new_state
