"""Distributed DPSNN step: shard_map over the TPU mesh + halo exchange.

The DPSNN process <-> column-set mapping becomes: one mesh shard owns one
``tile_h x tile_w`` rectangle of cortical columns.  Every state / table
array carries two leading *tile* dims ``(TY, TX)`` sharded over the mesh
axes -- ``("data", "model")`` on the single-pod 16x16 mesh, and
``(("pod","data"), "model")`` on the multi-pod 2x16x16 mesh (the pod axis
splits the slab's y dimension further, exactly like adding more rows of
MPI processes in DPSNN).

Step structure per shard (dt = 1 ms):

  1. read ring slot t, add external Poisson drive
  2. LIF+SFA update -> local spikes
  3. halo-exchange excitatory spike blocks (``ppermute`` strips)
  4. event-driven delivery through local + per-band halo synapse tables
     into future ring slots

The per-step spike exchange is the paper's communication cost: Gaussian
law -> radius 3 halo, exponential law -> radius 10 halo.  Everything else
is local.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (EngineConfig, deliver_event_tiers, external_drive,
                     init_sim_state)
from .halo import exchange_halo_2d, pack_bits, unpack_bits
from .neuron import lif_sfa_step
from .synapses import build_tables, deliver_gather_all

AxisName = Union[str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Distribution settings layered on an EngineConfig."""

    engine: EngineConfig
    axis_y: AxisName = "data"        # ("pod","data") on the multi-pod mesh
    axis_x: AxisName = "model"
    halo_mode: str = "strip"         # "strip" (exact) | "block" (naive)
    pack_spikes: bool = True         # bit-pack halo payload (1 bit/neuron)

    @property
    def tiles(self) -> Tuple[int, int]:
        d = self.engine.decomp
        return d.tiles_y, d.tiles_x

    def pspec(self, extra_dims: int = 0) -> P:
        return P(self.axis_y, self.axis_x, *([None] * extra_dims))


# ---------------------------------------------------------------------------
# Global (stacked) state / tables
# ---------------------------------------------------------------------------

def init_dist_state(cfg: DistConfig) -> dict:
    """Stack per-tile states into (TY, TX, ...) host arrays."""
    ty, tx = cfg.tiles
    states = [[init_sim_state(cfg.engine, y, x, seed_offset=y * tx + x)
               for x in range(tx)] for y in range(ty)]

    def stack(path_leaves):
        return jnp.stack([jnp.stack(row) for row in path_leaves])

    flat = [[jax.tree.leaves(states[y][x]) for x in range(tx)]
            for y in range(ty)]
    treedef = jax.tree.structure(states[0][0])
    leaves = [stack([[flat[y][x][i] for x in range(tx)] for y in range(ty)])
              for i in range(len(flat[0][0]))]
    st = jax.tree.unflatten(treedef, leaves)
    # PRNGKey leaves stack to (TY,TX,2) automatically via tree structure
    return st


def build_dist_tables(cfg: DistConfig) -> dict:
    """Materialize all shards' synapse tables stacked on (TY, TX)."""
    ty, tx = cfg.tiles
    e = cfg.engine
    tabs = [[build_tables(e.spec(), y, x, j_exc=e.lif.j_exc_mv,
                          j_inh=e.lif.j_inh_mv, seed=e.seed)
             for x in range(tx)] for y in range(ty)]
    stats = [[tabs[y][x].pop("stats") for x in range(tx)] for y in range(ty)]

    def stack_tree(trees):
        return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    rows = [stack_tree([tabs[y][x] for x in range(tx)]) for y in range(ty)]
    out = stack_tree(rows)
    out_stats = {
        "n_synapses": int(sum(s["n_synapses"] for r in stats for s in r)),
        "clipped": int(sum(s["clipped"] for r in stats for s in r)),
        "table_bytes_per_shard": stats[0][0]["table_bytes"],
    }
    return out, out_stats


def abstract_dist_inputs(cfg: DistConfig):
    """ShapeDtypeStructs for (state, tables) -- dry-run inputs, no alloc."""
    ty, tx = cfg.tiles
    e = cfg.engine
    spec = e.spec()
    n_local = spec.n_local

    def sd(shape, dt):
        return jax.ShapeDtypeStruct((ty, tx) + shape, dt)

    state = {
        "neuron": {"v": sd((n_local,), jnp.float32),
                   "c": sd((n_local,), jnp.float32),
                   "refrac": sd((n_local,), jnp.int32)},
        "i_ring": sd((e.d_ring, n_local), jnp.float32),
        "t": sd((), jnp.int32),
        "rng": sd((2,), jnp.uint32),
        "active": sd((n_local,), jnp.bool_),
        "metrics": {"spikes": sd((), jnp.float32),
                    "events": sd((), jnp.float32),
                    "dropped": sd((), jnp.float32)},
    }
    abst = spec.abstract_tables()

    def lift(t):
        return {k: jax.ShapeDtypeStruct((ty, tx) + v.shape, v.dtype)
                for k, v in t.items()}

    tables = {"local": lift(abst["local"]),
              "halo": [lift(t) for t in abst["halo"]]}
    return state, tables


def dist_shardings(cfg: DistConfig, mesh: Mesh):
    """NamedSharding pytrees matching ``abstract_dist_inputs``."""
    state, tables = abstract_dist_inputs(cfg)

    def shard(leaf):
        return NamedSharding(mesh, cfg.pspec(len(leaf.shape) - 2))

    return jax.tree.map(shard, state), jax.tree.map(shard, tables)


# ---------------------------------------------------------------------------
# The distributed step / run
# ---------------------------------------------------------------------------

def make_sim_fn(cfg: DistConfig, mesh: Mesh, n_steps: int,
                record_rate: bool = True, recorder=None):
    """Build the jitted multi-shard simulation function.

    Returns ``sim(state, tables) -> (state, per_step_spikes (TY,TX,S))``.
    The whole ``n_steps`` scan runs inside one ``shard_map`` call so the
    halo exchanges appear as ``collective-permute`` ops inside the scan
    body -- one lowered program, n_steps iterations, no per-step dispatch.

    The state argument is **donated**: callers must rebind to the
    returned state and drop every other reference.  For arbitrarily long
    runs, build once with ``n_steps = segment_steps`` and call
    repeatedly -- the state carries ``t``, so each call continues
    seamlessly where the last segment stopped (this is the segmented
    pattern ``runtime.sim_driver.SimDriver`` drives, with checkpoints
    between segments).

    ``recorder``: optional ``obs.record.RecorderSpec``.  When given the
    signature becomes ``sim(state, tables, gids) -> (state, per_step,
    recorder_state)`` -- ``gids`` is the stacked ``(TY, TX, n_local+1)``
    global-neuron-id map (``obs.record.stacked_gid_maps``) and
    ``recorder_state`` holds each shard's per-segment ``(step, gid)``
    event buffer, valid-prefix ``count`` and overflow ``dropped``
    counter, freshly zeroed at the start of every call (the host spooler
    drains it between segments).  Recording is a pure observer of the
    spike vector: dynamics and ``per_step`` outputs are bit-identical
    with or without it.
    """
    e = cfg.engine
    spec = e.spec()
    d = e.decomp
    n_local, n_per_col = spec.n_local, spec.n_per_col
    n_exc = spec.n_exc_per_col
    bands = spec.halo_bands()
    band_idx = [jnp.asarray(spec.band_positions_exc(b)) for b in bands]
    radius = d.radius
    # Hoisted: the static lane-packed delivery sizing the kernel layer
    # compiles against (recomputing it per scan trace re-runs the
    # numpy fan-out analysis behind halo_bands()).
    plan = spec.delivery_plan() if e.mode == "event" else None

    def shard_step(state, tables):
        key, k_ext = jax.random.split(state["rng"])
        slot = state["t"] % e.d_ring
        i_now = state["i_ring"][slot] + external_drive(k_ext, n_local, e)
        if e.kernels_enabled:
            from ..kernels import ops as kops
            neuron, spikes = kops.lif_step(state["neuron"], i_now, e.lif,
                                           state["active"])
        else:
            neuron, spikes = lif_sfa_step(state["neuron"], i_now, e.lif,
                                          state["active"])
        i_ring = state["i_ring"].at[slot].set(0.0)

        # --- halo exchange: excitatory spikes only --------------------
        exc_blk = spikes.reshape(d.tile_h, d.tile_w, n_per_col)[..., :n_exc]
        payload = pack_bits(exc_blk) if cfg.pack_spikes else exc_blk
        region = exchange_halo_2d(payload, radius=radius,
                                  axis_y=cfg.axis_y, axis_x=cfg.axis_x,
                                  mode=cfg.halo_mode)
        if cfg.pack_spikes:
            region = unpack_bits(region, n_exc)
        region_flat = region.reshape(-1)
        halo_spikes = [region_flat[idx] for idx in band_idx]

        # --- delivery --------------------------------------------------
        m = state["metrics"]
        if e.mode == "event":
            i_ring, ev, dr = deliver_event_tiers(
                tables, spikes, halo_spikes, spec, i_ring, slot,
                e.d_ring, e.kernels_enabled, plan=plan)
        else:
            i_ring = deliver_gather_all(tables["local"], spikes, i_ring,
                                        slot, e.d_ring)
            ev = jnp.sum(tables["local"]["nnz"][:n_local].astype(jnp.float32)
                         * spikes)
            dr = jnp.zeros((), jnp.float32)
            for tab, spk in zip(tables["halo"], halo_spikes):
                i_ring = deliver_gather_all(tab, spk, i_ring, slot, e.d_ring)
                ev += jnp.sum(tab["nnz"][:-1].astype(jnp.float32) * spk)

        new_state = {
            "neuron": neuron, "i_ring": i_ring, "t": state["t"] + 1,
            "rng": key, "active": state["active"],
            "metrics": {"spikes": m["spikes"] + jnp.sum(spikes),
                        "events": m["events"] + ev,
                        "dropped": m["dropped"] + dr},
        }
        return new_state, spikes

    state_sp = jax.tree.map(
        lambda leaf: cfg.pspec(len(leaf.shape) - 2),
        abstract_dist_inputs(cfg)[0])
    table_sp = jax.tree.map(
        lambda leaf: cfg.pspec(len(leaf.shape) - 2),
        abstract_dist_inputs(cfg)[1])

    from ..parallel.compat import shard_map

    if recorder is not None:
        from ..obs.record import init_recorder_state, record_step

        def shard_body_rec(state_blk, tables_blk, gids_blk):
            state = jax.tree.map(lambda a: a[0, 0], state_blk)
            tables = jax.tree.map(lambda a: a[0, 0], tables_blk)
            gids = gids_blk[0, 0]

            def body(carry, _):
                st, rec = carry
                new_state, spikes = shard_step(st, tables)
                rec = record_step(rec, spikes, gids, st["t"], recorder)
                return (new_state, rec), jnp.sum(spikes)

            (state, rec), per_step = jax.lax.scan(
                body, (state, init_recorder_state(recorder)), None,
                length=n_steps)
            lift = lambda a: a[None, None]                      # noqa: E731
            return (jax.tree.map(lift, state),
                    per_step[None, None] if record_rate else None,
                    jax.tree.map(lift, rec))

        rec_sp = jax.tree.map(lambda leaf: cfg.pspec(leaf.ndim),
                              init_recorder_state(recorder))
        mapped = shard_map(
            shard_body_rec, mesh=mesh,
            in_specs=(state_sp, table_sp, cfg.pspec(1)),
            out_specs=(state_sp, cfg.pspec(1) if record_rate else None,
                       rec_sp))
        return jax.jit(mapped, donate_argnums=(0,))

    def shard_body(state_blk, tables_blk):
        state = jax.tree.map(lambda a: a[0, 0], state_blk)
        tables = jax.tree.map(lambda a: a[0, 0], tables_blk)

        def body(carry, _):
            st, spikes = shard_step(carry, tables)
            return st, jnp.sum(spikes)

        state, per_step = jax.lax.scan(body, state, None, length=n_steps)
        state = jax.tree.map(lambda a: a[None, None], state)
        return state, per_step[None, None] if record_rate else None

    out_sp = (state_sp, cfg.pspec(1) if record_rate else None)
    mapped = shard_map(shard_body, mesh=mesh,
                       in_specs=(state_sp, table_sp),
                       out_specs=out_sp)
    return jax.jit(mapped, donate_argnums=(0,))


def simulate(cfg: DistConfig, mesh: Mesh, n_steps: int, timed: bool = False):
    """Convenience driver (small configs): build, run, report.

    ``timed=True`` runs a warm-up segment first (compile excluded) and
    reports ``elapsed_s`` for a second same-length segment.
    """
    import time

    state = init_dist_state(cfg)
    tables, stats = build_dist_tables(cfg)
    sharding_state, sharding_tables = dist_shardings(cfg, mesh)
    state = jax.device_put(state, sharding_state)
    tables = jax.device_put(tables, sharding_tables)
    sim = make_sim_fn(cfg, mesh, n_steps)
    elapsed = None
    # ``sim`` donates its state argument (donate_argnums=(0,)): always
    # rebind to the returned state and keep no other reference, or a
    # later read would touch a donated buffer.
    state, per_step = sim(state, tables)
    if timed:
        jax.block_until_ready(per_step)
        before = float(jnp.sum(state["metrics"]["events"]))
        t0 = time.perf_counter()
        state, per_step = sim(state, tables)
        jax.block_until_ready(per_step)
        elapsed = time.perf_counter() - t0
    n_active = float(jnp.sum(state["active"]))
    spikes = float(jnp.sum(state["metrics"]["spikes"]))
    total_steps = n_steps * (2 if timed else 1)
    sim_sec = total_steps * cfg.engine.lif.dt_ms * 1e-3
    out = {
        "state": state,
        "per_step_spikes": per_step,
        "stats": stats,
        "rate_hz": spikes / max(n_active, 1.0) / max(sim_sec, 1e-9),
        "events": float(jnp.sum(state["metrics"]["events"])),
        "dropped": float(jnp.sum(state["metrics"]["dropped"])),
    }
    if timed:
        out["elapsed_s"] = elapsed
        out["events_timed"] = out["events"] - before
    return out
