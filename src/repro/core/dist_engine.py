"""Distributed DPSNN step: shard_map over the TPU mesh + halo exchange.

The DPSNN process <-> column-set mapping becomes: one mesh shard owns one
``tile_h x tile_w`` rectangle of cortical columns.  Every state / table
array carries two leading *tile* dims ``(TY, TX)`` sharded over the mesh
axes -- ``("data", "model")`` on the single-pod 16x16 mesh, and
``(("pod","data"), "model")`` on the multi-pod 2x16x16 mesh (the pod axis
splits the slab's y dimension further, exactly like adding more rows of
MPI processes in DPSNN).

Step structure per shard (dt = 1 ms):

  1. read ring slot t, add external Poisson drive
  2. LIF+SFA update -> local spikes
  3. halo-exchange excitatory spike blocks (``ppermute`` strips)
  4. event-driven delivery through local + per-band halo synapse tables
     into future ring slots

The per-step spike exchange is the paper's communication cost: Gaussian
law -> radius 3 halo, exponential law -> radius 10 halo.  Everything else
is local.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .engine import (EngineConfig, deliver_event_tiers, external_drive,
                     init_sim_state, plastic_delivery_stdp)
from .halo import exchange_halo_2d, pack_bits, unpack_bits
from .neuron import lif_sfa_step
from .synapses import (SynapseTables, TableStorage, build_tables,
                       compress_tables, deliver_gather_all)

AxisName = Union[str, Tuple[str, ...]]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SimInputs:
    """The non-donated inputs of the distributed sim function, named.

    ``make_sim_fn``'s second argument: synapse ``tables`` always,
    ``inv_slots`` (the stacked target-major inverse index) when the
    engine is plastic, ``gids`` (the stacked global-neuron-id maps)
    when a recorder is attached.  Replaces the old positional
    ``sim(state, tables[, inv_slots][, gids])`` sprawl -- unused fields
    stay ``None`` and vanish from the pytree, so sharding/in_specs
    trees built with the same ``None``s always line up.
    """
    tables: Any
    inv_slots: Any = None
    gids: Any = None

    def tree_flatten(self):
        return (self.tables, self.inv_slots, self.gids), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Distribution settings layered on an EngineConfig.

    ``ensemble_seeds``: member state seeds for an ensemble run.  When
    set, every state leaf grows a member axis at position 2 --
    ``(TY, TX, M, ...)``, *after* the sharded tile dims so the member
    axis rides unsharded (replicated layout specs stay ``pspec(ndim-2)``)
    -- and the per-shard scan is vmapped over it: one table
    realization (``engine.seed``), one compiled step, M realizations.
    Member m is bit-identical to a solo run with
    ``engine.state_seed = ensemble_seeds[m]``.
    """

    engine: EngineConfig
    axis_y: AxisName = "data"        # ("pod","data") on the multi-pod mesh
    axis_x: AxisName = "model"
    halo_mode: str = "strip"         # "strip" (exact) | "block" (naive)
    pack_spikes: bool = True         # bit-pack halo payload (1 bit/neuron)
    ensemble_seeds: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.ensemble_seeds is not None:
            seeds = tuple(int(s) for s in self.ensemble_seeds)
            if not seeds:
                raise ValueError("ensemble_seeds must be a non-empty "
                                 "tuple (or None for a solo run)")
            object.__setattr__(self, "ensemble_seeds", seeds)

    @property
    def tiles(self) -> Tuple[int, int]:
        d = self.engine.decomp
        return d.tiles_y, d.tiles_x

    @property
    def n_members(self) -> Optional[int]:
        """Ensemble width M, or None for a solo run."""
        if self.ensemble_seeds is None:
            return None
        return len(self.ensemble_seeds)

    def pspec(self, extra_dims: int = 0) -> P:
        return P(self.axis_y, self.axis_x, *([None] * extra_dims))


# ---------------------------------------------------------------------------
# Global (stacked) state / tables
# ---------------------------------------------------------------------------

def init_dist_state(cfg: DistConfig) -> dict:
    """Stack per-tile states into (TY, TX, ...) host arrays.

    Ensemble runs (``cfg.ensemble_seeds``) stack one such tree per
    member seed along axis 2: ``(TY, TX, M, ...)``.
    """
    ty, tx = cfg.tiles

    def init_tiles(e: EngineConfig):
        states = [[init_sim_state(e, y, x, seed_offset=y * tx + x)
                   for x in range(tx)] for y in range(ty)]

        def stack(path_leaves):
            return jnp.stack([jnp.stack(row) for row in path_leaves])

        flat = [[jax.tree.leaves(states[y][x]) for x in range(tx)]
                for y in range(ty)]
        treedef = jax.tree.structure(states[0][0])
        leaves = [stack([[flat[y][x][i] for x in range(tx)]
                         for y in range(ty)])
                  for i in range(len(flat[0][0]))]
        # PRNGKey leaves stack to (TY,TX,2) automatically via structure
        return jax.tree.unflatten(treedef, leaves)

    if cfg.ensemble_seeds is None:
        return init_tiles(cfg.engine)
    members = [init_tiles(dataclasses.replace(cfg.engine, state_seed=s))
               for s in cfg.ensemble_seeds]
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=2), *members)


def build_dist_tables(cfg: DistConfig,
                      compress: bool = True) -> Tuple[SynapseTables, dict]:
    """Materialize all shards' synapse tables stacked on (TY, TX).

    Per-shard builds happen at the analytic caps (identical shapes, so
    stacking is trivial), then ``compress_tables`` truncates the
    all-padding trailing columns jointly across shards -- the realized
    caps are cross-shard maxima, so the compressed storage descriptor
    is identical on every shard (SPMD-safe).
    """
    ty, tx = cfg.tiles
    e = cfg.engine
    tabs = [[build_tables(e.spec(), y, x, j_exc=e.lif.j_exc_mv,
                          j_inh=e.lif.j_inh_mv, seed=e.seed)
             for x in range(tx)] for y in range(ty)]
    stats = [[tabs[y][x].stats for x in range(tx)] for y in range(ty)]

    def stack_tree(trees):
        return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    rows = [stack_tree([tabs[y][x] for x in range(tx)]) for y in range(ty)]
    out = stack_tree(rows)
    if compress:
        out = compress_tables(out)
    from .synapses import materialized_table_bytes
    out_stats = {
        "n_synapses": int(sum(s["n_synapses"] for r in stats for s in r)),
        "clipped": int(sum(s["clipped"] for r in stats for s in r)),
        "table_bytes_per_shard": materialized_table_bytes(out, ty * tx),
    }
    return out, out_stats


def abstract_dist_inputs(cfg: DistConfig,
                         storage: Optional[TableStorage] = None):
    """ShapeDtypeStructs for (state, tables) -- dry-run inputs, no alloc.

    ``storage``: the materialized tables' storage descriptor.  Leave it
    ``None`` for the spec's analytic (uncompressed) layout -- the
    dry-run case; pass ``tables.storage`` when shapes must match
    compressed tables (shardings, checkpoint restore).

    When the engine is plastic (``cfg.engine.stdp`` set) the state grows
    a ``plastic`` subtree -- per-tier synaptic weights plus the STDP
    pre/post traces -- because plastic weights are *dynamics*, carried
    through the scan and checkpointed with the neuron state.  The carry
    is then the *single* live float copy of the weights: the static
    ``tables`` argument only supplies the realization's structure
    (targets, delays, occupancy) and its ``w`` leaves fold down to the
    int8 plastic mask (``fold_plastic_tables``).  Pre-traces are
    local-tier only -- halo replicas arrive per step through the halo
    exchange, never stored.
    """
    ty, tx = cfg.tiles
    e = cfg.engine
    spec = e.spec()
    n_local = spec.n_local
    # member axis sits between the sharded tile dims and the per-shard
    # shape so pspec(ndim-2) keeps it unsharded/replicated-free
    mdim = () if cfg.n_members is None else (cfg.n_members,)

    def sd(shape, dt):
        return jax.ShapeDtypeStruct((ty, tx) + mdim + shape, dt)

    state = {
        "neuron": {"v": sd((n_local,), jnp.float32),
                   "c": sd((n_local,), jnp.float32),
                   "refrac": sd((n_local,), jnp.int32)},
        "i_ring": sd((e.d_ring, n_local), jnp.float32),
        "t": sd((), jnp.int32),
        "rng": sd((2,), jnp.uint32),
        "active": sd((n_local,), jnp.bool_),
        "metrics": {"spikes": sd((), jnp.float32),
                    "events": sd((), jnp.float32),
                    "dropped": sd((), jnp.float32)},
    }
    abst = spec.abstract_tables(storage)
    if e.stdp is not None:
        # carry abstracts read the *full-width* weight dtype before the
        # tables fold to masks below -- the carry is the live copy
        tiers = abst.tiers()
        state["plastic"] = {
            "w": [sd(t["w"].shape, t["w"].dtype) for t in tiers],
            "x_pre": [sd((tiers[0]["tgt"].shape[0],), jnp.float32)],
            "x_post": sd((n_local,), jnp.float32),
        }
        abst = fold_plastic_tables(abst)

    def lift(t):
        return {k: jax.ShapeDtypeStruct((ty, tx) + v.shape, v.dtype)
                for k, v in t.items()}

    tables = SynapseTables(lift(abst.local),
                           [lift(t) for t in abst.halo], abst.storage)
    return state, tables


def init_dist_plastic_state(cfg: DistConfig, tables: dict) -> dict:
    """Fresh plastic carry: weights copied from the stacked build tables
    (copies, never views -- the sim donates its state argument, and the
    static tables must survive every segment), traces at zero.

    ``tables`` must carry the *build weights* (float), not the folded
    int8 masks the device tables hold (``fold_plastic_tables``) -- the
    carry initialized here becomes the run's single live weight copy.
    The pre-trace is local-tier only: halo replicas are exchanged per
    step, never carried."""
    ty, tx = cfg.tiles
    n_local = cfg.engine.spec().n_local
    tiers = [tables["local"]] + list(tables["halo"])
    if any(np.dtype(t["w"].dtype) == np.int8 for t in tiers):
        raise ValueError(
            "init_dist_plastic_state needs the build-weight tables "
            "(float w); got int8-folded mask tables -- pass the host "
            "copy taken before fold_plastic_tables")
    from .stdp import check_weight_invariant
    check_weight_invariant(tiers, cfg.engine.stdp)
    m = cfg.n_members

    def member_w(t):
        w = np.asarray(t["w"])
        if m is None:
            return jnp.asarray(w)
        # every member starts from the same build realization; weights
        # diverge per member through the carried STDP dynamics
        return jnp.asarray(np.ascontiguousarray(np.broadcast_to(
            w[:, :, None], (ty, tx, m) + w.shape[2:])))

    mdim = () if m is None else (m,)
    pre_rows = tiers[0]["tgt"].shape[-2]
    return {
        "w": [member_w(t) for t in tiers],
        "x_pre": [jnp.zeros((ty, tx) + mdim + (pre_rows,), jnp.float32)],
        "x_post": jnp.zeros((ty, tx) + mdim + (n_local,), jnp.float32),
    }


def fold_plastic_tables(tables: SynapseTables) -> SynapseTables:
    """Fold the static tables' weight leaves down to the int8 plastic
    mask (``w > 0``: excitatory-at-build = plastic, DPSNN's convention).

    Plastic runs read live weights exclusively from the scan carry
    (``init_dist_plastic_state``), so keeping the build weights resident
    on device would duplicate every weight tier at full width; after the
    fold the device tables cost 1 B/synapse for the mask instead of
    ``weight_dtype`` bytes.  Accepts abstract (ShapeDtypeStruct) or
    materialized tables; host-side for the latter."""

    def fold(t):
        w = t["w"]
        if isinstance(w, jax.ShapeDtypeStruct):
            return dict(t, w=jax.ShapeDtypeStruct(w.shape, jnp.int8))
        return dict(t, w=jnp.asarray((np.asarray(w) > 0).astype(np.int8)))

    return tables.replace(local=fold(tables.local),
                          halo=[fold(t) for t in tables.halo])


def build_dist_inverse_index(cfg: DistConfig, tables: dict):
    """Per-shard target-major inverse indices, stacked on (TY, TX).

    Each shard's index maps a local (target) neuron to the virtual flat
    slots of its incoming synapses across *all* tiers -- local and halo
    -- which is how a post-spike reaches the cross-tile synapses it must
    potentiate.  In-degree padding (``K_in``) differs per shard, so
    slots are padded to the max with each shard's ``total`` sentinel
    (already the "no synapse" value the LTP scatter masks on).

    Returns ``(slots, aux)``: ``slots`` a (TY, TX, n_local, K) int32
    array, ``aux`` the tier geometry (``bases``/``sizes``/``total``),
    identical across shards by construction.
    """
    from .stdp import build_inverse_index
    ty, tx = cfg.tiles
    n_local = cfg.engine.spec().n_local
    invs = []
    for y in range(ty):
        row = []
        for x in range(tx):
            tiers = [{k: np.asarray(v[y, x])
                      for k, v in tables["local"].items()}]
            tiers += [{k: np.asarray(v[y, x]) for k, v in t.items()}
                      for t in tables["halo"]]
            row.append(build_inverse_index(tiers, n_local))
        invs.append(row)
    aux = {"bases": invs[0][0]["bases"], "sizes": invs[0][0]["sizes"],
           "total": invs[0][0]["total"]}
    k_max = max(int(np.asarray(i["slots"]).shape[1])
                for r in invs for i in r)
    stacked = np.full((ty, tx, n_local, k_max), aux["total"], np.int32)
    for y in range(ty):
        for x in range(tx):
            s = np.asarray(invs[y][x]["slots"])
            stacked[y, x, :, :s.shape[1]] = s
    return jnp.asarray(stacked), aux


def dist_shardings(cfg: DistConfig, mesh: Mesh,
                   storage: Optional[TableStorage] = None):
    """NamedSharding pytrees matching ``abstract_dist_inputs``.

    Pass the materialized tables' ``storage`` so the table sharding
    tree shares the compressed tables' treedef (the descriptor is the
    pytree's static aux data)."""
    state, tables = abstract_dist_inputs(cfg, storage)

    def shard(leaf):
        return NamedSharding(mesh, cfg.pspec(len(leaf.shape) - 2))

    return jax.tree.map(shard, state), jax.tree.map(shard, tables)


# ---------------------------------------------------------------------------
# The distributed step / run
# ---------------------------------------------------------------------------

def make_sim_fn(cfg: DistConfig, mesh: Mesh, n_steps: int,
                record_rate: bool = True, recorder=None,
                storage: Optional[TableStorage] = None):
    """Build the jitted multi-shard simulation function.

    Returns ``sim(state, inputs) -> (state, per_step_spikes (TY,TX,S))``
    where ``inputs`` is a ``SimInputs`` pytree (``tables`` always,
    ``inv_slots`` for plastic engines, ``gids`` when recording).
    The whole ``n_steps`` scan runs inside one ``shard_map`` call so the
    halo exchanges appear as ``collective-permute`` ops inside the scan
    body -- one lowered program, n_steps iterations, no per-step dispatch.

    ``storage``: the tables' storage descriptor.  Defaults to the
    spec's analytic layout; pass ``tables.storage`` when driving
    compressed tables (``build_dist_tables`` output) so the delivery
    plan, the plastic weight shapes, and the shard_map in_specs all
    size against the materialized caps.

    The state argument is **donated**: callers must rebind to the
    returned state and drop every other reference (analyzer-checked:
    repro-lint's ``donation`` pass tracks this factory and flags reads
    of an already-donated argument).  For arbitrarily long
    runs, build once with ``n_steps = segment_steps`` and call
    repeatedly -- the state carries ``t``, so each call continues
    seamlessly where the last segment stopped (this is the segmented
    pattern ``runtime.sim_driver.SimDriver`` drives, with checkpoints
    between segments).

    ``recorder``: optional ``obs.record.RecorderSpec``.  When given the
    signature grows a trailing ``gids`` argument -- the stacked ``(TY,
    TX, n_local+1)`` global-neuron-id map (``obs.record.
    stacked_gid_maps``) -- and a trailing ``recorder_state`` output
    holding each shard's per-segment ``(step, gid)`` event buffer,
    valid-prefix ``count`` and overflow ``dropped`` counter, freshly
    zeroed at the start of every call (the host spooler drains it
    between segments).  Recording is a pure observer of the spike
    vector: dynamics and ``per_step`` outputs are bit-identical with or
    without it.

    **Plasticity** (``cfg.engine.stdp`` set): the STDP weight tables
    and pre/post trace arrays join the scan carry as
    ``state["plastic"]`` (see ``abstract_dist_inputs``) and
    ``inputs.inv_slots`` must carry the stacked per-shard target-major
    inverse index from ``build_dist_inverse_index``.  The carry is the
    single live weight copy -- ``inputs.tables`` supplies structure
    plus the int8 plastic mask (``fold_plastic_tables``) -- and each
    step routes through ``engine.plastic_delivery_stdp``: one fused
    Pallas launch applying delivery + LTD in the same pass over the
    entry stream when kernels are on (two-pass reference otherwise),
    then the shared LTP/clamp/trace finalize.  Cross-tile synapses
    depress from the halo spike vectors delivery consumed and
    potentiate through the inverse index; the per-band halo
    *pre-traces* they need arrive through the same halo exchange as
    the spikes (the owner's local trace, bit-identical to a
    locally-maintained replica), so only the local trace is carried.

    **Ensemble** (``cfg.ensemble_seeds`` set, M members): state leaves
    carry the member axis at position 2 (``init_dist_state``), the
    per-shard scan is vmapped over it inside the shard body -- one
    compiled step shared by all members and by every job with the same
    shapes -- and the outputs grow the matching axis: ``per_step``
    becomes ``(TY, TX, M, S)`` and every recorder leaf gains a leading
    member dim after the tile dims.  The tables/inverse-index/gid
    inputs stay member-free: all members share one realization.
    """
    e = cfg.engine
    spec = e.spec()
    d = e.decomp
    n_local, n_per_col = spec.n_local, spec.n_per_col
    n_exc = spec.n_exc_per_col
    bands = spec.halo_bands()
    band_idx = [jnp.asarray(spec.band_positions_exc(b)) for b in bands]
    radius = d.radius
    # Hoisted: the static lane-packed delivery sizing the kernel layer
    # compiles against (recomputing it per scan trace re-runs the
    # numpy fan-out analysis behind halo_bands()).
    plan = spec.delivery_plan(storage) if e.mode == "event" else None
    plastic = e.stdp is not None
    if plastic:
        from .stdp import _tier_sizes
        abst = spec.abstract_tables(storage)
        inv_bases, inv_sizes = _tier_sizes(abst.tiers())
        inv_total = (int(inv_bases[-1] + inv_sizes[-1])
                     if len(inv_sizes) else 0)
        pre_caps = [spec.active_cap_local] \
            + [spec.active_cap_band(b) for b in bands]

    def shard_step(state, tables, masks, inv):
        key, k_ext = jax.random.split(state["rng"])
        slot = state["t"] % e.d_ring
        i_now = state["i_ring"][slot] + external_drive(k_ext, n_local, e)
        if e.kernels_enabled:
            from ..kernels import ops as kops
            neuron, spikes = kops.lif_step(state["neuron"], i_now, e.lif,
                                           state["active"])
        else:
            neuron, spikes = lif_sfa_step(state["neuron"], i_now, e.lif,
                                          state["active"])
        i_ring = state["i_ring"].at[slot].set(0.0)

        # --- halo exchange: excitatory spikes only --------------------
        exc_blk = spikes.reshape(d.tile_h, d.tile_w, n_per_col)[..., :n_exc]
        payload = pack_bits(exc_blk) if cfg.pack_spikes else exc_blk
        region = exchange_halo_2d(payload, radius=radius,
                                  axis_y=cfg.axis_y, axis_x=cfg.axis_x,
                                  mode=cfg.halo_mode)
        if cfg.pack_spikes:
            region = unpack_bits(region, n_exc)
        region_flat = region.reshape(-1)
        halo_spikes = [region_flat[idx] for idx in band_idx]

        # --- delivery (plastic runs read weights from the carry) ------
        if plastic:
            pl = state["plastic"]
            tabs = {"local": dict(tables["local"], w=pl["w"][0]),
                    "halo": [dict(t, w=w) for t, w in
                             zip(tables["halo"], pl["w"][1:])]}
            # halo pre-trace replicas ride the same halo path as the
            # spikes: each band row's trace is the owner's local x_pre
            # carry at the start of this step -- bit-identical to the
            # replica a shard would maintain itself (same decay/increment
            # recurrence in the same order), so the carry only holds the
            # local tier.  Sent pre-decay; stdp decays uniformly in-step.
            xpre0 = pl["x_pre"][0]
            x_pre_tiers = [xpre0]
            if band_idx:
                xpre_blk = xpre0[:n_local].reshape(
                    d.tile_h, d.tile_w, n_per_col)[..., :n_exc]
                xpre_region = exchange_halo_2d(
                    xpre_blk, radius=radius, axis_y=cfg.axis_y,
                    axis_x=cfg.axis_x, mode=cfg.halo_mode).reshape(-1)
                sink = jnp.zeros((1,), jnp.float32)
                x_pre_tiers += [
                    jnp.concatenate([xpre_region[idx], sink])
                    for idx in band_idx]
            traces_in = {"x_pre": x_pre_tiers, "x_post": pl["x_post"]}
            tiers = [tabs["local"]] + list(tabs["halo"])
        else:
            tabs = tables
        m = state["metrics"]
        new_plastic = None
        if e.mode == "event":
            if plastic:
                i_ring, new_tiers, traces, ev, dr = plastic_delivery_stdp(
                    tiers, masks, inv, traces_in, [spikes] + halo_spikes,
                    spec, i_ring, slot, e, plan)
                new_plastic = {"w": [t["w"] for t in new_tiers],
                               "x_pre": traces["x_pre"][:1],
                               "x_post": traces["x_post"]}
            else:
                i_ring, ev, dr = deliver_event_tiers(
                    tabs, spikes, halo_spikes, spec, i_ring, slot,
                    e.d_ring, e.kernels_enabled, plan=plan)
        else:
            i_ring = deliver_gather_all(tabs["local"], spikes, i_ring,
                                        slot, e.d_ring)
            ev = jnp.sum(tabs["local"]["nnz"][:n_local].astype(jnp.float32)
                         * spikes)
            dr = jnp.zeros((), jnp.float32)
            for tab, spk in zip(tabs["halo"], halo_spikes):
                i_ring = deliver_gather_all(tab, spk, i_ring, slot, e.d_ring)
                ev += jnp.sum(tab["nnz"][:-1].astype(jnp.float32) * spk)
            if plastic:
                from .stdp import stdp_step
                new_tiers, traces = stdp_step(
                    tiers, masks, inv, traces_in, [spikes] + halo_spikes,
                    spikes, e.stdp, pre_caps, spec.active_cap_local)
                new_plastic = {"w": [t["w"] for t in new_tiers],
                               "x_pre": traces["x_pre"][:1],
                               "x_post": traces["x_post"]}

        new_state = {
            "neuron": neuron, "i_ring": i_ring, "t": state["t"] + 1,
            "rng": key, "active": state["active"],
            "metrics": {"spikes": m["spikes"] + jnp.sum(spikes),
                        "events": m["events"] + ev,
                        "dropped": m["dropped"] + dr},
        }
        if new_plastic is not None:
            new_state["plastic"] = new_plastic
        return new_state, spikes

    abs_state, abs_tables = abstract_dist_inputs(cfg, storage)
    state_sp = jax.tree.map(
        lambda leaf: cfg.pspec(len(leaf.shape) - 2), abs_state)
    table_sp = jax.tree.map(
        lambda leaf: cfg.pspec(len(leaf.shape) - 2), abs_tables)

    from ..parallel.compat import shard_map

    if recorder is not None:
        from ..obs.record import init_recorder_state, record_step

    n_members = cfg.n_members

    def shard_body(state_blk, inputs_blk):
        state = jax.tree.map(lambda a: a[0, 0], state_blk)
        tables = jax.tree.map(lambda a: a[0, 0], inputs_blk.tables)
        masks = inv = None
        if plastic:
            from .stdp import plastic_masks
            inv = {"slots": inputs_blk.inv_slots[0, 0], "bases": inv_bases,
                   "sizes": inv_sizes, "total": inv_total}
            masks = plastic_masks([tables["local"]] + list(tables["halo"]))
        if recorder is not None:
            gids = inputs_blk.gids[0, 0]

        def run_member(member_state):
            """Scan one realization's carry; tables/gids close over
            unbatched, so under vmap every member shares them."""
            if recorder is not None:
                def body(carry, _):
                    st, rec = carry
                    new_state, spikes = shard_step(st, tables, masks, inv)
                    rec = record_step(rec, spikes, gids, st["t"], recorder)
                    return (new_state, rec), jnp.sum(spikes)

                (st, rec), per_step = jax.lax.scan(
                    body, (member_state, init_recorder_state(recorder)),
                    None, length=n_steps)
                return st, per_step, rec

            def body(carry, _):
                st, spikes = shard_step(carry, tables, masks, inv)
                return st, jnp.sum(spikes)

            st, per_step = jax.lax.scan(body, member_state, None,
                                        length=n_steps)
            return st, per_step, None

        if n_members is None:
            state, per_step, rec = run_member(state)
        else:
            # one trace, M member carries: the halo ppermutes inside are
            # batched per member (vmap-of-collective is bit-identical to
            # per-member solo exchanges; tested both laws)
            state, per_step, rec = jax.vmap(run_member)(state)
        lift = lambda a: a[None, None]                          # noqa: E731
        out = (jax.tree.map(lift, state),
               per_step[None, None] if record_rate else None)
        if recorder is not None:
            out += (jax.tree.map(lift, rec),)
        return out

    member_dims = 0 if n_members is None else 1
    inputs_sp = SimInputs(
        tables=table_sp,
        inv_slots=cfg.pspec(2) if plastic else None,   # inverse-index slots
        gids=cfg.pspec(1) if recorder is not None else None)  # gid maps
    in_specs = [state_sp, inputs_sp]
    out_specs = [state_sp,
                 cfg.pspec(1 + member_dims) if record_rate else None]
    if recorder is not None:
        out_specs.append(jax.tree.map(
            lambda leaf: cfg.pspec(leaf.ndim + member_dims),
            init_recorder_state(recorder)))
    mapped = shard_map(shard_body, mesh=mesh,
                       in_specs=tuple(in_specs),
                       out_specs=tuple(out_specs))
    # Pin the state output's shardings to the input's NamedShardings:
    # XLA's propagation may legally mark some outputs replicated (it
    # does under the ensemble vmap), and a donated output fed back with
    # a different-but-equivalent sharding than the first call's input
    # would recompile the segment on its second invocation.
    out_shardings = [jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                                  state_sp), None]
    if recorder is not None:
        out_shardings.append(None)
    return jax.jit(mapped, donate_argnums=(0,),
                   out_shardings=tuple(out_shardings))


def simulate(cfg: DistConfig, mesh: Mesh, n_steps: int, timed: bool = False):
    """Convenience driver (small configs): build, run, report.

    ``timed=True`` runs a warm-up segment first (compile excluded) and
    reports ``elapsed_s`` for a second same-length segment.
    """
    import time

    if cfg.engine.stdp is not None:
        raise ValueError(
            "simulate() is the static convenience driver; plastic runs "
            "carry their weight tables through checkpoints -- drive them "
            "via runtime.sim_driver.SimDriver (CLI: repro.launch.sim "
            "--plastic)")
    state = init_dist_state(cfg)
    tables, stats = build_dist_tables(cfg)
    sharding_state, sharding_tables = dist_shardings(cfg, mesh,
                                                     tables.storage)
    state = jax.device_put(state, sharding_state)
    tables = jax.device_put(tables, sharding_tables)
    sim = make_sim_fn(cfg, mesh, n_steps, storage=tables.storage)
    inputs = SimInputs(tables=tables)
    elapsed = None
    # ``sim`` donates its state argument (donate_argnums=(0,)): always
    # rebind to the returned state and keep no other reference, or a
    # later read would touch a donated buffer.
    state, per_step = sim(state, inputs)
    if timed:
        jax.block_until_ready(per_step)
        before = float(jnp.sum(state["metrics"]["events"]))
        t0 = time.perf_counter()
        state, per_step = sim(state, inputs)
        jax.block_until_ready(per_step)
        elapsed = time.perf_counter() - t0
    n_active = float(jnp.sum(state["active"]))
    spikes = float(jnp.sum(state["metrics"]["spikes"]))
    total_steps = n_steps * (2 if timed else 1)
    sim_sec = total_steps * cfg.engine.lif.dt_ms * 1e-3
    out = {
        "state": state,
        "per_step_spikes": per_step,
        "stats": stats,
        "rate_hz": spikes / max(n_active, 1.0) / max(sim_sec, 1e-9),
        "events": float(jnp.sum(state["metrics"]["events"])),
        "dropped": float(jnp.sum(state["metrics"]["dropped"])),
    }
    if timed:
        out["elapsed_s"] = elapsed
        out["events_timed"] = out["events"] - before
    return out
