"""Column grid geometry and spatial domain decomposition.

The cortical slab is a 2D grid of columns (H x W, ``n_per_column`` neurons
each).  For distributed simulation the grid is decomposed into a
``tiles_y x tiles_x`` array of rectangular tiles, one per mesh shard (the
DPSNN process <-> column-set mapping, adapted to a TPU mesh).

Each tile owns ``tile_h x tile_w`` columns and sees a *region* = tile
dilated by the stencil radius R on every side (the halo).  Grids that do
not divide evenly by the tile array are padded with *inactive* columns
(mask-carried; they hold no live neurons).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .connectivity import NEURONS_PER_COLUMN


@dataclasses.dataclass(frozen=True)
class ColumnGrid:
    """The global simulated slab."""

    height: int
    width: int
    n_per_column: int = NEURONS_PER_COLUMN

    @property
    def n_columns(self) -> int:
        return self.height * self.width

    @property
    def n_neurons(self) -> int:
        return self.n_columns * self.n_per_column


@dataclasses.dataclass(frozen=True)
class TileDecomposition:
    """Decomposition of a (possibly padded) grid into tiles + halo regions."""

    grid: ColumnGrid          # the *logical* (unpadded) grid
    tiles_y: int
    tiles_x: int
    radius: int               # stencil radius (halo width), in columns

    # ---- padded geometry -------------------------------------------------
    @property
    def padded_h(self) -> int:
        return self.tiles_y * self.tile_h

    @property
    def padded_w(self) -> int:
        return self.tiles_x * self.tile_w

    @property
    def tile_h(self) -> int:
        return int(math.ceil(self.grid.height / self.tiles_y))

    @property
    def tile_w(self) -> int:
        return int(math.ceil(self.grid.width / self.tiles_x))

    @property
    def tile_cols(self) -> int:
        return self.tile_h * self.tile_w

    @property
    def n_local(self) -> int:
        """Neuron slots owned by one tile (padded columns included)."""
        return self.tile_cols * self.grid.n_per_column

    # ---- halo / region geometry -------------------------------------------
    @property
    def region_h(self) -> int:
        return self.tile_h + 2 * self.radius

    @property
    def region_w(self) -> int:
        return self.tile_w + 2 * self.radius

    @property
    def region_cols(self) -> int:
        return self.region_h * self.region_w

    @property
    def n_region(self) -> int:
        return self.region_cols * self.grid.n_per_column

    @property
    def halo_hops_y(self) -> int:
        """ppermute hops needed along y to assemble the halo."""
        return int(math.ceil(self.radius / self.tile_h))

    @property
    def halo_hops_x(self) -> int:
        return int(math.ceil(self.radius / self.tile_w))

    # ---- indexing helpers --------------------------------------------------
    def tile_origin(self, ty: int, tx: int) -> tuple:
        """Global (y, x) of the tile's top-left column."""
        return ty * self.tile_h, tx * self.tile_w

    def active_mask(self, ty: int, tx: int) -> np.ndarray:
        """(tile_h, tile_w) bool mask of columns that exist in the logical grid."""
        oy, ox = self.tile_origin(ty, tx)
        ys = oy + np.arange(self.tile_h)[:, None]
        xs = ox + np.arange(self.tile_w)[None, :]
        return (ys < self.grid.height) & (xs < self.grid.width)

    def region_active_mask(self, ty: int, tx: int) -> np.ndarray:
        """(region_h, region_w) bool mask of region columns inside the grid."""
        oy, ox = self.tile_origin(ty, tx)
        ys = oy - self.radius + np.arange(self.region_h)[:, None]
        xs = ox - self.radius + np.arange(self.region_w)[None, :]
        return ((ys >= 0) & (ys < self.grid.height)
                & (xs >= 0) & (xs < self.grid.width))

    def region_col_index(self, ry: int, rx: int) -> int:
        """Flatten a region (row, col) to a region column index."""
        return ry * self.region_w + rx

    def local_to_region(self, ly: int, lx: int) -> int:
        """Region column index of a local tile column."""
        return self.region_col_index(ly + self.radius, lx + self.radius)

    def comm_volume_per_step_bytes(self, bytes_per_neuron: int = 1) -> int:
        """Bytes of spike payload a tile must import per step (halo area).

        This is the quantity the paper's connectivity comparison stresses:
        the halo area grows from (tile+2*3)^2 - tile^2 to (tile+2*10)^2 -
        tile^2 when switching Gaussian -> exponential.
        """
        halo_cols = self.region_cols - self.tile_cols
        return halo_cols * self.grid.n_per_column * bytes_per_neuron


def choose_tiling(n_shards_y: int, n_shards_x: int, grid: ColumnGrid,
                  radius: int) -> TileDecomposition:
    return TileDecomposition(grid=grid, tiles_y=n_shards_y, tiles_x=n_shards_x,
                             radius=radius)
