"""Multi-device behaviour, each case in a subprocess (XLA device count
is locked at first jax init, so the main pytest process must stay
single-device)."""

import os
import subprocess
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# every case spawns an 8-device subprocess simulation; minutes on CPU
pytestmark = pytest.mark.slow


def run_py(code: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh, shard_map
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
"""


def test_halo_exchange_exact():
    run_py(PRELUDE + """
from repro.core.halo import exchange_halo_2d, pack_bits, unpack_bits
TY, TX, th, tw, F, R = 4, 2, 3, 3, 5, 4
gh, gw = TY*th, TX*tw
rng = np.random.default_rng(0)
glob = rng.integers(0, 2, size=(gh, gw, F)).astype(np.float32)
tiles = glob.reshape(TY, th, TX, tw, F).transpose(0, 2, 1, 3, 4)
def body(x):
    x = x[0, 0]
    reg = exchange_halo_2d(x, radius=R, axis_y=("pod", "data"),
                           axis_x="model", mode="strip")
    regp = unpack_bits(exchange_halo_2d(pack_bits(x), radius=R,
        axis_y=("pod", "data"), axis_x="model"), F)
    return reg[None, None], regp[None, None]
sm = jax.jit(shard_map(body, mesh=mesh,
    in_specs=(P(("pod", "data"), "model"),),
    out_specs=(P(("pod", "data"), "model"),)*2))
reg, regp = sm(jnp.asarray(tiles))
pad = np.pad(glob, ((R, R), (R, R), (0, 0)))
for ty in range(TY):
    for tx in range(TX):
        want = pad[ty*th:ty*th+th+2*R, tx*tw:tx*tw+tw+2*R]
        assert np.array_equal(want, np.asarray(reg)[ty, tx]), (ty, tx)
assert np.array_equal(np.asarray(regp), np.asarray(reg))
print("halo OK")
""")


def test_distributed_snn_simulation():
    run_py(PRELUDE + """
from repro.core.connectivity import exponential_law
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.engine import EngineConfig
from repro.core.dist_engine import DistConfig, simulate
law = exponential_law()
dec = TileDecomposition(grid=ColumnGrid(8, 8, 40), tiles_y=4, tiles_x=2,
                        radius=law.radius)
cfg = DistConfig(engine=EngineConfig(decomp=dec, law=law),
                 axis_y=("pod", "data"), axis_x="model")
out = simulate(cfg, mesh, n_steps=40)
assert out["dropped"] == 0
assert np.isfinite(out["rate_hz"]) and out["rate_hz"] >= 0
assert out["events"] >= 0
print("dist sim OK", out["rate_hz"])
""")


def test_dist_matches_single_shard_statistics():
    """Same global model, 1-shard vs 8-shard: firing-rate statistics
    agree (different RNG streams -> statistical, not bitwise)."""
    run_py(PRELUDE + """
from repro.core.connectivity import gaussian_law
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, firing_rate_hz,
                               simulate as engine_simulate)
from repro.core.dist_engine import DistConfig, simulate
law = gaussian_law()
grid = ColumnGrid(8, 8, 40)
# single shard
d1 = TileDecomposition(grid=grid, tiles_y=1, tiles_x=1, radius=law.radius)
c1 = EngineConfig(decomp=d1, law=law, seed=5)
t1 = build_shard_tables(c1)
s1, _ = jax.jit(lambda s: engine_simulate(s, t1, c1, 400))(init_sim_state(c1))
r1 = firing_rate_hz(s1, c1, 400)
# 8 shards
d8 = TileDecomposition(grid=grid, tiles_y=4, tiles_x=2, radius=law.radius)
c8 = DistConfig(engine=EngineConfig(decomp=d8, law=law, seed=5),
                axis_y=("pod", "data"), axis_x="model")
out = simulate(c8, mesh, n_steps=400)
r8 = out["rate_hz"]
print("rates:", r1, r8)
assert r8 == __import__("pytest").approx(r1, rel=0.35)
""")


def test_sim_driver_retile_resume(tmp_path):
    """Checkpoint a 1x2-tiled segmented run, resume it 2x1 with elastic
    re-tiling: the relayout is exact per global column id (spot-checked
    against the checkpoint) and the resumed run proceeds sanely."""
    run_py(f"""
import jax, numpy as np
from repro.checkpoint.store import latest_step, restore_checkpoint
from repro.core.connectivity import gaussian_law
from repro.core.dist_engine import DistConfig, abstract_dist_inputs
from repro.core.engine import EngineConfig, firing_rate_hz
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.retile import neuron_gather_map
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver

def dist(ty, tx):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(6, 6, 20), tiles_y=ty,
                            tiles_x=tx, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=4))

ck = {str(tmp_path)!r}
m12 = make_mesh((1, 2), ("data", "model"))
d1 = SimDriver(DriverConfig(ckpt_dir=ck, ckpt_every=1,
                            handle_sigterm=False),
               dist(1, 2), m12, segment_steps=30)
out1 = d1.run(60)
assert out1["final_step"] == 60

m21 = make_mesh((2, 1), ("data", "model"))
d2 = SimDriver(DriverConfig(ckpt_dir=ck, ckpt_every=1,
                            handle_sigterm=False),
               dist(2, 1), m21, segment_steps=30, allow_retile=True)
start, state = d2._restore_or_init()
assert start == 60
# exact relayout: compare against the raw checkpoint per global col id
old = restore_checkpoint(ck, 60, abstract_dist_inputs(dist(1, 2))[0])
src = neuron_gather_map(dist(1, 2).engine.decomp, dist(2, 1).engine.decomp)
for k in ("v", "c", "refrac"):
    got = np.asarray(state["neuron"][k])
    want = np.asarray(old["neuron"][k]).reshape(-1)[src]
    np.testing.assert_array_equal(got[src >= 0], want[src >= 0], err_msg=k)
ring_old = np.moveaxis(np.asarray(old["i_ring"]), 2, 0)
ring_new = np.moveaxis(np.asarray(state["i_ring"]), 2, 0)
for s in range(ring_old.shape[0]):
    np.testing.assert_array_equal(ring_new[s][src >= 0],
                                  ring_old[s].reshape(-1)[src][src >= 0])
assert int(np.max(np.asarray(state["t"]))) == 60
out2 = d2.run(120)
assert out2["final_step"] == 120
# driver-level rate: re-adds the manifest-carried metric base the
# retile moved out of the per-tile state (engine.firing_rate_hz on a
# retiled state would silently undercount the pre-retile half)
rate = d2.firing_rate_hz(out2["state"])
state_rate = firing_rate_hz(out2["state"], dist(2, 1).engine)
assert np.isfinite(rate) and 0.0 <= rate < 200.0
assert state_rate <= rate  # state alone lost the pre-retile history
print("retile resume OK", rate)
""", devices=2)


def test_sim_driver_plastic_retile_resume(tmp_path):
    """A plastic run born on 1x2 resumes on 2x1: the learned weight
    tables are relaid by global (pre, post) synapse id -- bit-identical
    per synapse (checksum) -- and the run keeps learning on the new
    tiling."""
    run_py(f"""
import numpy as np
from repro.core.connectivity import gaussian_law
from repro.core.dist_engine import DistConfig
from repro.core.engine import EngineConfig
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.stdp import STDPParams
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver

def dist(ty, tx):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(6, 6, 20), tiles_y=ty,
                            tiles_x=tx, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=4,
                                          stdp=STDPParams()))

ck = {str(tmp_path)!r}
m12 = make_mesh((1, 2), ("data", "model"))
d1 = SimDriver(DriverConfig(ckpt_dir=ck, ckpt_every=1,
                            handle_sigterm=False),
               dist(1, 2), m12, segment_steps=30)
out1 = d1.run(60)
assert out1["final_step"] == 60
s1 = d1.plastic_summary(out1["state"])
assert s1["w_l1_delta"] > 0 and s1["n_plastic"] > 0  # learning happened

m21 = make_mesh((2, 1), ("data", "model"))
d2 = SimDriver(DriverConfig(ckpt_dir=ck, ckpt_every=1,
                            handle_sigterm=False),
               dist(2, 1), m21, segment_steps=30, allow_retile=True)
assert d2._born_tiles == (1, 2)        # birth tiling from checkpoint meta
start, state = d2._restore_or_init()
assert start == 60
s2 = d2.plastic_summary(state)
# the relay preserved every learned weight bit-exactly per synapse id
assert s2["weight_checksum"] == s1["weight_checksum"], (s1, s2)
out2 = d2.run(120)
assert out2["final_step"] == 120
s3 = d2.plastic_summary(out2["state"])
assert s3["w_l1_delta"] >= s2["w_l1_delta"]
rate = d2.firing_rate_hz(out2["state"])
assert np.isfinite(rate) and 0.0 <= rate < 200.0
print("plastic retile OK", rate)
""", devices=2)


def test_moe_ep_equals_dense():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
from repro.models import ModelConfig
from repro.models.moe import init_moe, _apply_moe_dense, _apply_moe_ep
from repro.parallel.sharding import MeshRules, rules_for_mesh
rules = rules_for_mesh(mesh)
nomesh = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                   experts=None, vocab=None, kv_seq=None, d_inner=None)
cfg = ModelConfig(name="moe", family="moe", n_layers=1, d_model=32,
                  n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=128,
                  n_experts=8, moe_top_k=2, capacity_factor=8.0,
                  dtype="float32")
p, _ = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
y_ref, _ = _apply_moe_dense(p, cfg, nomesh, x)
y_ep, _ = jax.jit(lambda p, x: _apply_moe_ep(p, cfg, rules, x))(p, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-5, atol=2e-5)
g = jax.jit(jax.grad(lambda p, x: jnp.sum(jnp.sin(
    _apply_moe_ep(p, cfg, rules, x)[0]))))(p, x)
gr = jax.grad(lambda p, x: jnp.sum(jnp.sin(
    _apply_moe_dense(p, cfg, nomesh, x)[0])))(p, x)
for k in g:
    np.testing.assert_allclose(np.asarray(g[k]), np.asarray(gr[k]),
                               rtol=5e-4, atol=5e-4, err_msg=k)
print("EP OK")
""")


def test_sharded_train_step_matches_single_device():
    """The same train step, 1 device vs 4x2 mesh: identical loss."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
from repro.models import ModelConfig
from repro.models.transformer import init_model
from repro.models.model import loss_fn
from repro.parallel.sharding import MeshRules, rules_for_mesh
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                  dtype="float32", attn_chunk_q=32, attn_chunk_k=32,
                  loss_chunk=32)
params, specs = init_model(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 256)
batch = {"tokens": tokens, "labels": tokens}
nomesh = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                   experts=None, vocab=None, kv_seq=None, d_inner=None)
l_single, _ = loss_fn(params, cfg, nomesh, batch)
rules = rules_for_mesh(mesh)
psh = rules.shardings(specs, mesh)
params_sh = jax.device_put(params, psh)
l_mesh, _ = jax.jit(lambda p, b: loss_fn(p, cfg, rules, b))(params_sh, batch)
np.testing.assert_allclose(float(l_single), float(l_mesh), rtol=1e-5)
print("sharded loss OK", float(l_single))
""")


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Checkpoint on a (4,2) mesh, restore onto (2,4): values identical."""
    run_py(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.store import save_checkpoint, restore_checkpoint
from repro.parallel.compat import make_mesh
m1 = make_mesh((4, 2), ("data", "model"))
m2 = make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
x1 = jax.device_put(x, NamedSharding(m1, P("data", "model")))
save_checkpoint({str(tmp_path)!r}, 3, {{"w": x1}})
out = restore_checkpoint({str(tmp_path)!r}, 3, {{"w": x}},
    shardings={{"w": NamedSharding(m2, P("data", "model"))}})
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
assert len(out["w"].sharding.device_set) == 8
print("elastic OK")
""")


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map (auto=) hits an XLA CHECK failure "
           "on the 0.4.x line")
def test_compressed_pod_gradient_sync():
    """int8+error-feedback cross-pod DP: first step matches the exact
    step to int8 precision and training still converges."""
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
from repro.models import ModelConfig
from repro.models.transformer import init_model
from repro.models.model import make_train_step, make_compressed_pod_train_step
from repro.optim import adamw
from repro.optim.compression import init_residuals
from repro.optim.schedules import constant
from repro.parallel.sharding import rules_for_mesh
rules = rules_for_mesh(mesh)
cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=256,
                  dtype="float32", attn_chunk_q=32, attn_chunk_k=32,
                  loss_chunk=32)
params, _ = init_model(jax.random.PRNGKey(0), cfg)
opt = adamw(constant(1e-3))
opt_state = opt.init(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, 256)
batch = {"tokens": tokens, "labels": tokens}
p1, o1, out1 = jax.jit(make_train_step(cfg, rules, opt))(params, opt_state, batch)
resid = init_residuals(params)
step_c = jax.jit(make_compressed_pod_train_step(cfg, rules, opt))
p2, o2, resid, out2 = step_c(params, opt_state, resid, batch)
assert abs(float(out1["loss"]) - float(out2["loss"])) < 1e-5
d = max(float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-3, d
for _ in range(5):
    p2, o2, resid, out2 = step_c(p2, o2, resid, batch)
assert float(out2["loss"]) < float(out1["loss"])
print("compressed pod sync OK")
""")
