"""Segmented long-run SNN driver: resume bit-identity, preemption,
retry-and-replay.  Single-device (1x1 tiling); the multi-device retile
resume lives in tests/test_multidevice.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.connectivity import gaussian_law
from repro.core.dist_engine import DistConfig
from repro.core.engine import EngineConfig, firing_rate_hz
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver

N = 40          # spiking sets in around step ~34 at this scale/seed


def _dist_cfg(seed=3):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=seed))


def _driver(ckpt_dir, seg, **kw):
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir),
                       ckpt_every=kw.pop("ckpt_every", 1),
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, _dist_cfg(), mesh, segment_steps=seg, **kw)


def _metric_totals(state):
    return {k: float(np.asarray(jnp.sum(v)))
            for k, v in state["metrics"].items()}


def test_resume_bit_identity(tmp_path):
    """N steps straight == N/2 + save + kill + restore + N/2, exactly.

    Per-step equality is asserted against the *spooled* spike events
    (``spike_counts`` reads the spool back; the resumed run's spool
    covers both processes thanks to the exactly-once offsets in the
    checkpoint manifest)."""
    straight = _driver(tmp_path / "a", seg=N, record_events=True)
    out_a = straight.run(N)
    assert out_a["final_step"] == N

    first = _driver(tmp_path / "b", seg=N // 2, record_events=True)
    first.run(N // 2)
    # fresh driver = simulated process restart; restores from checkpoint
    second = _driver(tmp_path / "b", seg=N // 2, record_events=True)
    out_b = second.run(N)
    assert out_b["final_step"] == N

    spikes_a = straight.spike_counts(N)
    spikes_b = second.spike_counts(N)
    assert spikes_a.shape == (N,) and spikes_a.sum() > 0
    np.testing.assert_array_equal(spikes_a, spikes_b)
    assert _metric_totals(out_a["state"]) == _metric_totals(out_b["state"])
    # the full state is bit-identical, not just the summaries
    for la, lb in zip(jax.tree.leaves(out_a["state"]),
                      jax.tree.leaves(out_b["state"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_preemption_checkpoints_and_resumes(tmp_path):
    d1 = _driver(tmp_path, seg=10, preempt_after_segments=1)
    out1 = d1.run(N)
    assert out1["preempted"] and out1["final_step"] == 10
    from repro.checkpoint.store import latest_step
    assert latest_step(str(tmp_path)) == 10

    d2 = _driver(tmp_path, seg=10)
    out2 = d2.run(N)
    assert not out2["preempted"] and out2["final_step"] == N
    assert int(np.max(np.asarray(out2["state"]["t"]))) == N
    rate = firing_rate_hz(out2["state"], d2.dist_cfg.engine)
    assert np.isfinite(rate) and rate >= 0


def test_segment_failure_restores_and_replays(tmp_path):
    ref = _driver(tmp_path / "ref", seg=10, record_events=True)
    ref_out = ref.run(30)

    fired = []

    def hook(step):
        if step == 20 and not fired:
            fired.append(step)
            raise RuntimeError("injected node failure")

    d = _driver(tmp_path / "x", seg=10, fault_hook=hook,
                record_events=True)
    out = d.run(30)
    assert fired == [20]
    assert out["final_step"] == 30
    # replayed segment appears once in the spool and the run is an
    # exact replay
    np.testing.assert_array_equal(ref.spike_counts(30), d.spike_counts(30))
    assert _metric_totals(ref_out["state"]) == _metric_totals(out["state"])


def test_replay_does_not_duplicate_metrics_log(tmp_path):
    """A failure after an un-checkpointed segment rewinds past logged
    entries; the abandoned timeline must be pruned so the exported
    metrics_log (--metrics-out) carries each segment exactly once."""
    fired = []

    def hook(step):
        if step == 30 and not fired:
            fired.append(step)
            raise RuntimeError("injected failure after unsaved segment")

    d = _driver(tmp_path, seg=10, ckpt_every=2, fault_hook=hook,
                record_events=True)
    out = d.run(40)
    assert fired == [30] and out["final_step"] == 40
    # checkpoint was at 20, so the logged-but-abandoned step-20 segment
    # is replayed: it must appear once, in order
    assert [m["step"] for m in d.metrics_log] == [0, 10, 20, 30]
    # spool agrees: total spooled events == the state's cumulative spike
    # count (a duplicated replay segment would inflate the spool)
    assert d.spike_counts(40).sum() == d.metric_totals(
        out["state"])["spikes"]


def test_replay_from_scratch_does_not_duplicate_logs(tmp_path):
    """A failure before any checkpoint exists rewinds to step 0; the
    whole abandoned timeline must be pruned from the logs."""
    fired = []

    def hook(step):
        if step == 20 and not fired:
            fired.append(step)
            raise RuntimeError("injected failure before first checkpoint")

    d = _driver(tmp_path, seg=10, ckpt_every=100, fault_hook=hook,
                record_events=True)
    out = d.run(40)
    assert fired == [20] and out["final_step"] == 40
    assert [m["step"] for m in d.metrics_log] == [0, 10, 20, 30]
    counts = d.spike_counts(40)
    assert counts.shape == (40,)
    assert counts.sum() == d.metric_totals(out["state"])["spikes"]


def test_resume_refuses_silent_retile(tmp_path):
    _driver(tmp_path, seg=10).run(10)
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=2, radius=law.radius)
    dist = DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3))
    mesh = make_mesh((1, 1), ("data", "model"))
    d = SimDriver(DriverConfig(ckpt_dir=str(tmp_path),
                               handle_sigterm=False),
                  dist, mesh, segment_steps=10)
    with pytest.raises(ValueError, match="retile"):
        d._restore_or_init()


def test_resume_refuses_grid_mismatch(tmp_path):
    _driver(tmp_path, seg=10).run(10)
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(5, 5, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    dist = DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3))
    mesh = make_mesh((1, 1), ("data", "model"))
    d = SimDriver(DriverConfig(ckpt_dir=str(tmp_path),
                               handle_sigterm=False),
                  dist, mesh, segment_steps=10, allow_retile=True)
    with pytest.raises(ValueError, match="grid"):
        d._restore_or_init()


def test_resume_refuses_seed_or_law_drift(tmp_path):
    """The relayout is only valid for the same model: a resume with a
    different synapse seed (or law) must be refused, not silently
    continued against freshly sampled different tables."""
    _driver(tmp_path, seg=10).run(10)
    mesh = make_mesh((1, 1), ("data", "model"))
    d = SimDriver(DriverConfig(ckpt_dir=str(tmp_path),
                               handle_sigterm=False),
                  _dist_cfg(seed=4), mesh, segment_steps=10)
    with pytest.raises(ValueError, match="seed"):
        d._restore_or_init()


def test_resume_refuses_table_realization_drift(tmp_path, monkeypatch):
    """Same seed under a different table-sampling-procedure version
    rebuilds a different network realization: a resume across that
    boundary must be refused, not silently continued."""
    import repro.core.synapses as syn
    _driver(tmp_path, seg=10).run(10)
    monkeypatch.setattr(syn, "TABLE_REALIZATION_VERSION",
                        syn.TABLE_REALIZATION_VERSION + 1)
    d = _driver(tmp_path, seg=10)
    with pytest.raises(ValueError, match="table_realization"):
        d._restore_or_init()


def test_checkpoint_meta_rides_inside_checkpoint(tmp_path):
    """Tiling/model meta is stored in the step's own manifest (atomic
    with the checkpoint), not a sidecar that can skew on crash."""
    import os
    from repro.checkpoint.store import checkpoint_meta
    _driver(tmp_path, seg=10).run(10)
    assert not os.path.exists(tmp_path / "sim_meta.json")
    meta = checkpoint_meta(str(tmp_path), 10)
    assert (meta["tiles_y"], meta["tiles_x"]) == (1, 1)
    assert meta["grid"] == [4, 4, 10]
    assert meta["law"] == "gaussian" and meta["seed"] == 3


def test_rejects_nonpositive_segment(tmp_path):
    with pytest.raises(ValueError, match="segment_steps"):
        _driver(tmp_path, seg=0)
