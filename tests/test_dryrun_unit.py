"""Dry-run machinery units that don't need 512 devices."""

import jax
import pytest

from repro.configs import ARCH_NAMES, all_cells, get_config, shape_cells
from repro.models import model as M
from repro.models.config import SHAPES
from repro.perf.attention_credit import chunk_traffic_bytes
from repro.perf.roofline import model_flops


def test_cell_enumeration_matches_assignment():
    cells = list(all_cells())
    assert len(cells) == 32                       # 40 - 8 long_500k skips
    longs = [(a, s.name) for a, s in cells if s.name == "long_500k"]
    assert sorted(a for a, _ in longs) == \
        ["falcon-mamba-7b", "recurrentgemma-9b"]
    for a in ARCH_NAMES:
        names = [s.name for s in shape_cells(a)]
        assert names[:3] == ["train_4k", "prefill_32k", "decode_32k"]


@pytest.mark.parametrize("arch", ["qwen3-8b", "whisper-small",
                                  "internvl2-26b", "kimi-k2-1t-a32b"])
def test_input_specs_cover_modalities(arch):
    cfg = get_config(arch)
    tr = M.input_specs(cfg, SHAPES["train_4k"])
    assert tr["tokens"].shape[0] == 256
    assert "labels" in tr
    if cfg.encoder_seq:
        assert tr["frames"].shape[1] == cfg.encoder_seq
    if cfg.n_patches:
        assert tr["patch_embeds"].shape[1] == cfg.n_patches
        # patches count toward the cell's sequence budget
        assert tr["tokens"].shape[1] == 4096 - cfg.n_patches
    dec = M.input_specs(cfg, SHAPES["decode_32k"])
    assert dec["token"].shape == (128, 1)


def test_abstract_params_have_no_buffers():
    cfg = get_config("kimi-k2-1t-a32b")           # 1T params, no alloc
    params, specs = M.abstract_params(cfg)
    total = sum(l.size for l in jax.tree.leaves(params))
    assert total > 1.0e12
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(params))
    # spec tree mirrors param tree
    assert len(jax.tree.leaves(
        specs, is_leaf=lambda t: isinstance(t, tuple))) == \
        len(jax.tree.leaves(params))


def test_decode_state_specs_structure():
    cfg = get_config("recurrentgemma-9b")
    st = M.abstract_decode_state(cfg, SHAPES["decode_32k"])
    sp = M.decode_state_specs(cfg, SHAPES["decode_32k"])
    assert len(jax.tree.leaves(st)) == len(jax.tree.leaves(
        sp, is_leaf=lambda t: isinstance(t, tuple)))
    # windowed attention layers cache only the 2048-slot ring
    # (stacked: (n_periods, B, W, kv, head_dim))
    caches = [l for l in jax.tree.leaves(st) if l.ndim == 5]
    assert caches and all(c.shape[2] == cfg.window for c in caches)


def test_model_flops_conventions():
    cfg = get_config("kimi-k2-1t-a32b")
    t = model_flops(cfg, SHAPES["train_4k"])
    assert t == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6)
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert d == pytest.approx(2 * cfg.active_param_count() * 128, rel=1e-6)


def test_attention_credit_scaling():
    cfg = get_config("qwen2-1.5b")
    c1 = chunk_traffic_bytes(cfg, SHAPES["prefill_32k"])
    c2 = chunk_traffic_bytes(cfg, SHAPES["train_4k"])
    assert c1 > 0 and c2 > 0
    assert chunk_traffic_bytes(cfg, SHAPES["decode_32k"]) == 0.0
    # windowed archs have block-sparse liveness -> much smaller credit
    rg = get_config("recurrentgemma-9b")
    assert chunk_traffic_bytes(rg, SHAPES["prefill_32k"]) < c1
