"""Typed compressed synapse tables (ISSUE 6): the ``TableStorage``
descriptor, value-exact cap compression, delivery equivalence on
compressed tables across both laws and both engines, retile-relay
exactness across storage formats, and the checkpoint storage-drift
refusal."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.dist_engine import DistConfig, SimInputs
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.retile import gather_synapse_stream, retile_tables
from repro.core.synapses import (SynapseTables, SynapseTableSpec,
                                 TableStorage, build_tables,
                                 compress_tables, deliver_events,
                                 deliver_gather_all)
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver


def _law(name):
    return gaussian_law() if name == "gaussian" else exponential_law()


def _dist_spec(law, grid=8, n_per_col=12, tiles=(4, 2)):
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=tiles[0], tiles_x=tiles[1],
                          radius=law.radius)
    return SynapseTableSpec(decomp=d, law=law, rate_cap_hz=25.0)


def _single_cfg(law, grid=5, n_per_col=9, **kw):
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    return EngineConfig(decomp=d, law=law, seed=2, **kw)


# ---------------------------------------------------------------------------
# The storage descriptor
# ---------------------------------------------------------------------------

def test_storage_meta_roundtrip():
    spec = _dist_spec(gaussian_law())
    st = spec.storage()
    assert st.tgt_dtype == "int16"          # n_local < 2**15
    meta = st.meta()
    json.dumps(meta)                        # manifest-ready
    assert TableStorage.from_meta(meta) == st


def test_storage_accum_dtype_is_pinned():
    with pytest.raises(ValueError, match="accum"):
        # repro-lint: ignore[dtype-bounds] deliberately invalid storage:
        # the constructor itself must reject a bf16 accumulator
        TableStorage(tgt_dtype="int16", weight_dtype="bfloat16",
                     accum_dtype="bfloat16", cap_local=4, halo_caps=())


def test_wide_tiles_get_int32_targets():
    law = gaussian_law()
    d = TileDecomposition(grid=ColumnGrid(64, 64, 9), tiles_y=1, tiles_x=1,
                          radius=law.radius)
    spec = SynapseTableSpec(decomp=d, law=law, single_shard=True)
    assert spec.n_local >= 2 ** 15
    assert spec.storage().tgt_dtype == "int32"


def test_compressed_tables_match_their_abstract():
    """The realized storage descriptor round-trips through the spec:
    ``abstract_tables(tables.storage)`` reproduces every leaf's shape
    and dtype, so shardings/in_specs built from the abstract always
    line up with the actual tables."""
    spec = _dist_spec(exponential_law())
    tabs = compress_tables(build_tables(spec, 1, 1, j_exc=0.4,
                                        j_inh=-2.0, seed=0))
    abst = spec.abstract_tables(tabs.storage)
    got = jax.tree.leaves(tabs)
    want = jax.tree.leaves(abst)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.shape == w.shape and g.dtype == w.dtype
    # compression only ever removes all-padding columns
    dense = build_tables(spec, 1, 1, j_exc=0.4, j_inh=-2.0, seed=0)
    assert tabs.storage.cap_local <= dense.storage.cap_local
    np.testing.assert_array_equal(np.asarray(tabs["local"]["nnz"]),
                                  np.asarray(dense["local"]["nnz"]))


def test_simin_pytree_roundtrip():
    """None fields vanish from the SimInputs pytree, so the same class
    serves static, plastic and recording call signatures."""
    spec = _dist_spec(gaussian_law())
    tabs = compress_tables(build_tables(spec, 0, 0, j_exc=0.4,
                                        j_inh=-2.0, seed=0))
    si = SimInputs(tables=tabs)
    leaves, treedef = jax.tree.flatten(si)
    si2 = jax.tree.unflatten(treedef, leaves)
    assert si2.inv_slots is None and si2.gids is None
    assert si2.tables.storage == tabs.storage
    # distinct storages => distinct treedefs (the contract shardings
    # and shard_map in_specs rely on)
    dense = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=0)
    assert (jax.tree.structure(SimInputs(tables=dense))
            != treedef)


# ---------------------------------------------------------------------------
# Delivery equivalence on compressed tables
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("law_name", ["gaussian", "exponential"])
def test_compressed_delivery_bitwise_per_tier(law_name, rng):
    """Cap truncation removes only all-zero-weight padding columns:
    both XLA delivery paths produce a bit-identical ring from the
    compressed and the dense tables, every tier, random spikes."""
    spec = _dist_spec(_law(law_name))
    dense = build_tables(spec, 1, 1, j_exc=0.4, j_inh=-2.0, seed=3)
    comp = compress_tables(dense)
    spikes = jnp.asarray((rng.random(spec.n_local) < 0.1)
                         .astype(np.float32))
    band_spikes = [jnp.asarray((rng.random(b["rows"]) < 0.1)
                               .astype(np.float32))
                   for b in spec.halo_bands()]
    ring0 = jnp.asarray(rng.normal(size=(spec.d_ring, spec.n_local)),
                        jnp.float32)
    for tabs in (dense, comp):
        tiers = [(tabs["local"], spikes, spec.active_cap_local)]
        tiers += [(tab, spk, spec.active_cap_band(b)) for b, tab, spk in
                  zip(spec.halo_bands(), tabs["halo"], band_spikes)]
        ring_e = ring0
        for tab, spk, cap in tiers:
            ring_e, _, _ = deliver_events(tab, spk, ring_e, 2,
                                          spec.d_ring, cap)
        ring_g = ring0
        for tab, spk, _ in tiers:
            ring_g = deliver_gather_all(tab, spk, ring_g, 2, spec.d_ring)
        if tabs is dense:
            ring_e_dense, ring_g_dense = ring_e, ring_g
    np.testing.assert_array_equal(np.asarray(ring_e),
                                  np.asarray(ring_e_dense))
    np.testing.assert_array_equal(np.asarray(ring_g),
                                  np.asarray(ring_g_dense))


@pytest.mark.parametrize("law_name", ["gaussian", "exponential"])
def test_engine_spike_trains_identical_compressed_vs_dense(law_name):
    """Full engine runs (ragged n_local, kernel and XLA paths) emit
    identical spike trains from compressed and dense tables."""
    cfg = _single_cfg(_law(law_name), use_kernels=False)
    dense = build_shard_tables(cfg, compress=False)
    comp = build_shard_tables(cfg)
    assert comp.storage.cap_local <= dense.storage.cap_local
    _, sp_dense = jax.jit(
        lambda s: simulate(s, dense, cfg, 50))(init_sim_state(cfg))
    _, sp_comp = jax.jit(
        lambda s: simulate(s, comp, cfg, 50))(init_sim_state(cfg))
    np.testing.assert_array_equal(np.asarray(sp_dense),
                                  np.asarray(sp_comp))
    cfg_k = dataclasses.replace(cfg, use_kernels="auto")
    _, sp_kern = jax.jit(
        lambda s: simulate(s, comp, cfg_k, 50))(init_sim_state(cfg_k))
    np.testing.assert_array_equal(np.asarray(sp_dense),
                                  np.asarray(sp_kern))


def test_bf16_weights_roundtrip_exactly_through_float32():
    """bfloat16 storage is the float32 realization rounded once at
    build time, and every bf16 value is exactly representable in
    float32 -- so the up-cast delivery arithmetic and the relay's
    float32 canonical stream are value-exact for bf16 tables."""
    from repro.core.synapses import np_dtype
    law = gaussian_law()
    cfg = _single_cfg(law, use_kernels=False)
    cfg32 = dataclasses.replace(cfg, weight_dtype="float32")
    t16 = build_shard_tables(cfg)
    t32 = build_shard_tables(cfg32)
    bf16 = np_dtype("bfloat16")
    w16 = np.asarray(t16["local"]["w"])
    assert w16.dtype == bf16
    # same sampled realization, rounded once
    np.testing.assert_array_equal(
        w16, np.asarray(t32["local"]["w"])[:, :w16.shape[1]].astype(bf16))
    # lossless f32 round-trip (what gather_synapse_stream relies on)
    np.testing.assert_array_equal(w16.astype(np.float32).astype(bf16), w16)


# ---------------------------------------------------------------------------
# Retile relay across storage formats
# ---------------------------------------------------------------------------

def test_retile_relay_exact_across_storage_formats():
    """The global-synapse-id relay is storage-format-invariant: relaying
    a compressed bf16/int16 realization and its dense counterpart
    yields bit-identical canonical streams, and compress-after-relay
    reproduces the storage descriptor deterministically."""
    law = gaussian_law()

    from repro.core.stdp import STDPParams

    def cfgs(tiles):
        # plastic spec: halo floor 0.0, so every realized synapse has a
        # slot on both tilings (the precondition the relay enforces)
        dec = TileDecomposition(grid=ColumnGrid(4, 4, 10),
                                tiles_y=tiles[0], tiles_x=tiles[1],
                                radius=law.radius)
        return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3,
                                              stdp=STDPParams()))

    from repro.core.dist_engine import build_dist_tables
    a, b = cfgs((1, 2)), cfgs((2, 1))
    da, sa = a.engine.decomp, a.engine.spec()
    db, sb = b.engine.decomp, b.engine.spec()
    comp, _ = build_dist_tables(a)
    dense, _ = build_dist_tables(a, compress=False)

    def canon(stream):
        w = np.ascontiguousarray(stream["w"]).astype(np.float32)
        order = np.lexsort((w.view(np.uint32), stream["dslot"],
                            stream["post"], stream["pre"]))
        return np.column_stack(
            [stream["pre"][order], stream["post"][order],
             stream["dslot"][order].astype(np.int64),
             w.view(np.uint32)[order].astype(np.int64)])

    r_comp = retile_tables(comp, da, sa, db, sb)
    r_dense = retile_tables(dense, da, sa, db, sb)
    s_comp = canon(gather_synapse_stream(r_comp, db, sb))
    s_dense = canon(gather_synapse_stream(r_dense, db, sb))
    assert len(s_comp) > 0
    np.testing.assert_array_equal(s_comp, s_dense)
    # deterministic storage reconstruction: compressing either relay
    # lands on the same realized descriptor
    assert (compress_tables(r_comp).storage
            == compress_tables(r_dense).storage)

    # bf16/int16 (static) tables: the same-tiling canonicalization is
    # value-exact through the float32 stream
    stat = DistConfig(engine=EngineConfig(
        decomp=da.__class__(grid=da.grid, tiles_y=1, tiles_x=2,
                            radius=law.radius), law=law, seed=3))
    t_b, _ = build_dist_tables(stat)
    d_stat, s_stat = stat.engine.decomp, stat.engine.spec()
    assert t_b.storage.weight_dtype == "bfloat16"
    r_b = retile_tables(t_b, d_stat, s_stat, d_stat, s_stat)
    np.testing.assert_array_equal(
        canon(gather_synapse_stream(t_b, d_stat, s_stat)),
        canon(gather_synapse_stream(r_b, d_stat, s_stat)))


# ---------------------------------------------------------------------------
# Checkpoint storage-drift refusal
# ---------------------------------------------------------------------------

def _driver(ckpt_dir, weight_dtype="bfloat16"):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    dist = DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3,
                                          weight_dtype=weight_dtype))
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir), ckpt_every=1,
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, dist, mesh, segment_steps=10)


def test_checkpoint_refuses_storage_drift(tmp_path):
    """A same-tiling resume whose table storage no longer matches the
    manifest (here: weight dtype changed between processes) is refused
    -- the checkpointed state was stepped against different tables."""
    _driver(tmp_path).run(10)
    d = _driver(tmp_path, weight_dtype="float32")
    with pytest.raises(ValueError, match="storage"):
        d._restore_or_init()


def test_checkpoint_meta_carries_storage(tmp_path):
    from repro.checkpoint.store import checkpoint_meta, latest_step
    d = _driver(tmp_path)
    d.run(10)
    meta = checkpoint_meta(str(tmp_path), latest_step(str(tmp_path)))
    assert TableStorage.from_meta(meta["storage"]) == d.storage
