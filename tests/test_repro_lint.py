"""repro-lint analyzer: every pass catches its seeded violation, stays
silent on the clean twin, and the live tree lints clean.

The violation fixtures live as source strings (written to temp files
per test), NOT as real modules -- CI lints ``tests/`` too, and these
snippets must never count as repo code.
"""

import subprocess
import sys
import threading

import pytest

from repro.analysis import (ALL_CHECKERS, DeprecatedApiChecker,
                            DonationChecker,
                            DtypeContractsChecker, MetaDriftChecker,
                            Module, PallasGeometryChecker, Project,
                            PytreeAuxChecker, TracerPurityChecker)
from repro.checkpoint.store import (AsyncWriterThread,
                                    set_thread_asserts,
                                    thread_asserts_enabled)

SRC_ROOT = __file__.rsplit("/tests/", 1)[0] + "/src"


def run_checker(checker, sources, paths=None):
    """Lint in-memory sources; returns the surviving findings."""
    mods = []
    for i, src in enumerate(sources):
        path = (paths[i] if paths else f"fixture_{i}.py")
        mods.append(Module(path, source=src))
    return Project(mods).run([checker()])


def assert_flags(checker, bad, clean, paths=None):
    """The pass must flag the seeded violation and stay silent on the
    clean twin."""
    hits = run_checker(checker, [bad], paths)
    assert hits, f"{checker.name} missed its seeded violation"
    assert all(f.check == checker.name for f in hits)
    quiet = run_checker(checker, [clean], paths)
    assert not quiet, f"{checker.name} false-positive on clean twin: " \
        f"{[str(f) for f in quiet]}"
    return hits


# ---------------------------------------------------------------------------
# tracer-purity
# ---------------------------------------------------------------------------

TRACED_RNG_BAD = '''
import numpy as np
import jax

def body(carry, x):
    noise = np.random.default_rng(0).normal()   # host RNG at trace time
    return carry + noise, x

def run(xs):
    return jax.lax.scan(body, 0.0, xs)
'''

TRACED_RNG_CLEAN = '''
import jax
import jax.numpy as jnp

def body(carry, x):
    k, c = carry
    k, sub = jax.random.split(k)
    noise = jax.random.normal(sub, ())
    return (k, c + noise), x

def run(key, xs):
    return jax.lax.scan(body, (key, 0.0), xs)
'''


def test_tracer_purity_flags_host_rng_in_scan_body():
    hits = assert_flags(TracerPurityChecker, TRACED_RNG_BAD,
                        TRACED_RNG_CLEAN)
    assert any("numpy.random" in f.message for f in hits)


TRACED_BRANCH_BAD = '''
import jax
import jax.numpy as jnp

@jax.jit
def step(v):
    total = jnp.sum(v)
    if total > 0:                      # tracer has no truth value
        total = total * 2.0
    return total
'''

TRACED_BRANCH_CLEAN = '''
import jax
import jax.numpy as jnp

@jax.jit
def step(v):
    total = jnp.sum(v)
    if v.shape[0] > 0:                 # static shape check is fine
        total = jnp.where(total > 0, total * 2.0, total)
    return total
'''


def test_tracer_purity_flags_python_branch_on_traced_value():
    hits = assert_flags(TracerPurityChecker, TRACED_BRANCH_BAD,
                        TRACED_BRANCH_CLEAN)
    assert any("`if`" in f.message for f in hits)


TRACED_IO_BAD = '''
import jax

def inner(x):
    print("step", x)                   # host I/O inside jit
    return x * 2

@jax.jit
def step(x):
    return inner(x)
'''


def test_tracer_purity_follows_the_call_graph():
    # `inner` is only traced *transitively* (jit body calls it)
    hits = run_checker(TracerPurityChecker, [TRACED_IO_BAD])
    assert any("print" in f.message and f.line == 5 for f in hits), \
        [str(f) for f in hits]


def test_tracer_purity_flags_unseeded_rng_anywhere():
    bad = "import numpy as np\nx = np.random.rand(4)\n"
    clean = "import numpy as np\nx = np.random.default_rng(7).random(4)\n"
    hits = assert_flags(TracerPurityChecker, bad, clean)
    assert "hidden" in hits[0].message


def test_tracer_purity_allows_host_timing_outside_trace():
    clean = '''
import time

def wall(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
'''
    assert not run_checker(TracerPurityChecker, [clean])


TELEMETRY_SPAN_BAD = '''
import time

import jax
from repro.obs import telemetry

def body(carry, x):
    with telemetry.span("inner"):      # span inside the scan body
        t0 = time.monotonic()          # clock reads at trace time
        carry = carry + x
    return carry, t0

def run(xs):
    return jax.lax.scan(body, 0.0, xs)
'''

TELEMETRY_SPAN_CLEAN = '''
import jax
from repro.obs import telemetry

def body(carry, x):
    return carry + x, x

def run(xs):
    with telemetry.span("segment"):    # wraps the jitted call site
        out = jax.jit(lambda: jax.lax.scan(body, 0.0, xs))()
    return out
'''


def test_tracer_purity_flags_telemetry_span_in_scan_body():
    # the pure-observer contract, enforced statically: a span (or raw
    # host clock) inside a traced closure measures trace time, not the
    # compiled step -- both must be flagged; the same span wrapped
    # around the jit call site is the documented idiom and stays quiet
    hits = assert_flags(TracerPurityChecker, TELEMETRY_SPAN_BAD,
                        TELEMETRY_SPAN_CLEAN)
    assert any("telemetry repro.obs.telemetry.span" in f.message
               for f in hits), [str(f) for f in hits]
    assert any("time.monotonic" in f.message for f in hits), \
        [str(f) for f in hits]


# ---------------------------------------------------------------------------
# dtype-bounds
# ---------------------------------------------------------------------------

F64_BAD = '''
import numpy as np

def fan(n):
    return np.zeros(n, dtype=np.float64)
'''

F64_CLEAN = '''
import numpy as np

def fan(n):
    return np.zeros(n, dtype=np.float32)
'''


def test_dtype_flags_float64_in_core_only():
    path = "src/repro/core/fixture.py"
    hits = assert_flags(DtypeContractsChecker, F64_BAD, F64_CLEAN,
                        paths=[path])
    assert "f32-first" in hits[0].message
    # the same source outside core//kernels/ is not flagged
    assert not run_checker(DtypeContractsChecker, [F64_BAD],
                           paths=["src/repro/obs/fixture.py"])


ACCUM_BAD = '''
import jax.numpy as jnp

def total(w):
    return jnp.sum(w.astype(jnp.bfloat16))
'''

ACCUM_CLEAN = '''
import jax.numpy as jnp

def total(w):
    return jnp.sum(w.astype(jnp.float32))
'''


def test_dtype_flags_accumulation_in_storage_dtype():
    hits = assert_flags(DtypeContractsChecker, ACCUM_BAD, ACCUM_CLEAN,
                        paths=["src/repro/obs/fixture.py"])
    assert "storage dtype" in hits[0].message


INT16_BAD = '''
from repro.core.synapses import TableStorage

st = TableStorage(tgt_dtype="int16", weight_dtype="bfloat16",
                  accum_dtype="float32", cap_local=4, halo_caps=())
'''


def test_dtype_flags_handmade_int16_storage():
    hits = run_checker(DtypeContractsChecker, [INT16_BAD],
                       paths=["src/repro/obs/fixture.py"])
    assert any("hand-built" in f.message for f in hits)
    # inside core/synapses.py itself (where the bound lives) it's fine
    assert not run_checker(DtypeContractsChecker, [INT16_BAD],
                           paths=["src/repro/core/synapses.py"])


def test_dtype_int16_bound_holds_for_committed_configs():
    """The live cross-check: every committed grid x law x tiling that
    selects int16 target ids keeps n_local under 2**15 (runs the real
    constructors, so the check can't drift from the code)."""
    from repro.configs.snn import CASES, reduced_case
    from repro.analysis.dtype_contracts import _TILINGS
    cases = dict(CASES)
    cases["reduced"] = reduced_case()
    checked = 0
    for case in cases.values():
        for ty, tx in _TILINGS:
            if case.grid[0] % ty or case.grid[1] % tx:
                continue
            spec = case.engine_config(ty, tx).spec()
            st = spec.storage()
            if st.tgt_dtype == "int16":
                assert spec.n_local < 2 ** 15, (case.name, ty, tx)
                checked += 1
    assert checked > 0


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------

DONATION_BAD = '''
import jax
import jax.numpy as jnp

def run(state, xs):
    sim = jax.jit(lambda s, x: s + x, donate_argnums=(0,))
    out = sim(state, xs)
    return out + jnp.sum(state)        # state's buffer was donated
'''

DONATION_CLEAN = '''
import jax
import jax.numpy as jnp

def run(state, xs):
    sim = jax.jit(lambda s, x: s + x, donate_argnums=(0,))
    state = sim(state, xs)             # canonical rebinding
    return state + jnp.sum(state)
'''


def test_donation_flags_read_after_donating_call():
    hits = assert_flags(DonationChecker, DONATION_BAD, DONATION_CLEAN)
    assert "`state`" in hits[0].message


DONATION_FACTORY_BAD = '''
import jax

def make_sim(n):
    def step(s, x):
        return s + x
    return jax.jit(step, donate_argnums=(0,))

def drive(state, xs):
    sim = make_sim(3)
    new = sim(state, xs)
    return state                       # read through the factory's donation
'''


def test_donation_tracks_jit_factories():
    hits = run_checker(DonationChecker, [DONATION_FACTORY_BAD])
    assert any(f.line == 12 for f in hits), [str(f) for f in hits]


DONATION_BRANCH_CLEAN = '''
import jax

def drive(state, xs, timed):
    sim = jax.jit(lambda s, x: s + x, donate_argnums=(0,))
    state = sim(state, xs)
    if timed:
        state = sim(state, xs)         # rebinding inside the branch
    return state
'''


def test_donation_branches_merge_without_false_positives():
    assert not run_checker(DonationChecker, [DONATION_BRANCH_CLEAN])


# ---------------------------------------------------------------------------
# meta-drift
# ---------------------------------------------------------------------------

META_BAD = '''
from repro.checkpoint.store import refuse_meta_drift

class SimDriver:
    def _meta(self):
        return {"grid": self.grid, "law": self.law, "seed": self.seed,
                "table_realization": 3, "radius": self.radius,
                "cap_headroom": self.cap_headroom}

    def _restore(self, meta):
        refuse_meta_drift(
            meta, self._meta(),
            ("grid", "law", "radius", "seed", "table_realization"),
            "dir")
'''

META_CLEAN = META_BAD.replace(
    '("grid", "law", "radius", "seed", "table_realization")',
    '("grid", "law", "radius", "seed", "table_realization", '
    '"cap_headroom")')


def test_meta_drift_flags_unvalidated_manifest_key():
    hits = assert_flags(MetaDriftChecker, META_BAD, META_CLEAN,
                        paths=["src/repro/runtime/sim_driver.py"])
    assert any("cap_headroom" in f.message for f in hits)


def test_meta_drift_requires_identity_keys_refused():
    src = '''
class SimDriver:
    def _meta(self):
        return {"grid": 1}
'''
    hits = run_checker(MetaDriftChecker, [src],
                       paths=["src/repro/runtime/sim_driver.py"])
    assert any("identity key 'seed'" in f.message for f in hits)


def test_meta_drift_storage_fields_roundtrip():
    src = '''
import dataclasses

@dataclasses.dataclass(frozen=True)
class TableStorage:
    tgt_dtype: str
    weight_dtype: str

    def meta(self):
        return {"tgt_dtype": self.tgt_dtype}   # weight_dtype missing
'''
    hits = run_checker(MetaDriftChecker, [src],
                       paths=["src/repro/core/synapses.py"])
    assert any("weight_dtype" in f.message for f in hits)


# ---------------------------------------------------------------------------
# pytree-aux
# ---------------------------------------------------------------------------

PYTREE_BAD = '''
import jax

@jax.tree_util.register_pytree_node_class
class Tables:
    def __init__(self, local, meta):
        self.local, self.meta = local, meta

    def tree_flatten(self):
        return (self.local,), {"meta": self.meta}   # dict aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux["meta"])
'''

PYTREE_CLEAN = '''
import jax

@jax.tree_util.register_pytree_node_class
class Tables:
    def __init__(self, local, storage):
        self.local, self.storage = local, storage

    def tree_flatten(self):
        return (self.local,), self.storage          # frozen dataclass

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)
'''


def test_pytree_aux_flags_mutable_aux():
    hits = assert_flags(PytreeAuxChecker, PYTREE_BAD, PYTREE_CLEAN)
    assert "dict literal" in hits[0].message


# ---------------------------------------------------------------------------
# pallas-geometry
# ---------------------------------------------------------------------------

GEOMETRY_BAD = '''
from jax.experimental import pallas as pl

LANES = 128
ENTRY_SUBLANES = 32
ENTRY_BLOCK = ENTRY_SUBLANES * LANES
TILE_N = 4000                          # not lane-aligned

spec = pl.BlockSpec((ENTRY_SUBLANES, 100), lambda i: (i, 0))
'''

GEOMETRY_CLEAN = '''
from jax.experimental import pallas as pl

LANES = 128
ENTRY_SUBLANES = 32
ENTRY_BLOCK = ENTRY_SUBLANES * LANES
TILE_N = 4096

spec = pl.BlockSpec((ENTRY_SUBLANES, LANES), lambda i: (i, 0))
'''


def test_pallas_geometry_flags_misalignment():
    path = "src/repro/kernels/fixture.py"
    hits = assert_flags(PallasGeometryChecker, GEOMETRY_BAD,
                        GEOMETRY_CLEAN, paths=[path])
    msgs = " | ".join(f.message for f in hits)
    assert "TILE_N" in msgs and "minor dim 100" in msgs


def test_pallas_geometry_flags_vmem_blowout():
    blown = GEOMETRY_CLEAN.replace("ENTRY_SUBLANES = 32",
                                   "ENTRY_SUBLANES = 512")
    hits = run_checker(PallasGeometryChecker, [blown],
                       paths=["src/repro/kernels/fixture.py"])
    assert any("VMEM" in f.message for f in hits)


RING_CLEAN = '''
from jax.experimental import pallas as pl

LANES = 128
ENTRY_SUBLANES = 128
ENTRY_BLOCK = ENTRY_SUBLANES * LANES
CHUNK = 4096
RING_N_MAX = 8192

spec = pl.BlockSpec((ENTRY_SUBLANES, LANES), lambda i: (i, 0))
'''


def test_pallas_geometry_resident_ring_budget():
    # the fused plastic step's constants (CHUNK x RING_N_MAX resident
    # ring): clean at the shipped sizes, flagged when the ring grows
    # past what the one-hot row factor leaves of the VMEM core
    path = "src/repro/kernels/fixture.py"
    assert not run_checker(PallasGeometryChecker, [RING_CLEAN],
                           paths=[path])
    blown = RING_CLEAN.replace("RING_N_MAX = 8192", "RING_N_MAX = 16384")
    hits = run_checker(PallasGeometryChecker, [blown], paths=[path])
    assert any("RING_N_MAX" in f.message for f in hits)


# ---------------------------------------------------------------------------
# deprecated-api
# ---------------------------------------------------------------------------

DEPRECATED_IMPORT_BAD = '''
from repro.core.engine import run, simulate
from repro.core import engine

def drive(s, t, c):
    run(s, t, c, 10)
    return engine.run_plastic(s, t, {}, c, 10)
'''

DEPRECATED_CLEAN = '''
from repro.core.engine import simulate

def analyze_run(d):
    return d

class Driver:
    def run(self, n):              # unrelated method named run
        return n

def drive(s, t, c, d):
    simulate(s, t, c, 10, plasticity={})
    analyze_run(d)
    return Driver().run(3)
'''


def test_deprecated_api_flags_imports_and_calls():
    hits = assert_flags(DeprecatedApiChecker, DEPRECATED_IMPORT_BAD,
                        DEPRECATED_CLEAN)
    msgs = "\n".join(f.message for f in hits)
    assert "import of retired" in msgs
    assert "run_plastic" in msgs and "simulate" in msgs


def test_deprecated_api_flags_alias_resurrection_in_engine():
    resurrected = ("def run(state, tables, cfg, n_steps):\n"
                   "    return state\n")
    hits = run_checker(DeprecatedApiChecker, [resurrected],
                       paths=["src/repro/core/engine.py"])
    assert hits and "redefinition" in hits[0].message
    # the same def anywhere else is NOT the retired alias
    assert not run_checker(DeprecatedApiChecker, [resurrected],
                           paths=["src/repro/runtime/other.py"])


# ---------------------------------------------------------------------------
# pragmas
# ---------------------------------------------------------------------------

def test_pragma_suppresses_inline_and_above_line():
    inline = ("import numpy as np\n"
              "x = np.random.rand(4)  "
              "# repro-lint: ignore[tracer-purity] fixture\n")
    above = ("import numpy as np\n"
             "# repro-lint: ignore[tracer-purity] fixture\n"
             "x = np.random.rand(4)\n")
    wrong_check = ("import numpy as np\n"
                   "x = np.random.rand(4)  "
                   "# repro-lint: ignore[donation] wrong pass\n")
    assert not run_checker(TracerPurityChecker, [inline])
    assert not run_checker(TracerPurityChecker, [above])
    assert run_checker(TracerPurityChecker, [wrong_check])


def test_file_pragma_suppresses_whole_file():
    src = ("# repro-lint: ignore-file[tracer-purity] generator fixture\n"
           "import numpy as np\n"
           "x = np.random.rand(4)\n"
           "y = np.random.randn(2)\n")
    assert not run_checker(TracerPurityChecker, [src])


# ---------------------------------------------------------------------------
# the live tree is clean, via the real CLI
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["text", "json"])
def test_analyzer_clean_on_live_src(fmt):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--format", fmt,
         SRC_ROOT],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_lists_all_six_passes():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.lint", "--list"],
        capture_output=True, text=True)
    assert proc.returncode == 0
    for cls in ALL_CHECKERS:
        assert cls.name in proc.stdout
    assert len(ALL_CHECKERS) >= 6


# ---------------------------------------------------------------------------
# AsyncWriterThread owning-thread assertion (the --sanitize runtime half)
# ---------------------------------------------------------------------------

class _Writer(AsyncWriterThread):
    """Minimal subclass with spooler-style non-queue state."""

    def __init__(self):
        self.offset = 0
        super().__init__()

    def _write(self, item):
        pass

    def append(self, n):
        self._assert_owner("append")
        self.offset += n
        self._submit(n)


@pytest.fixture
def thread_asserts():
    set_thread_asserts(True)
    try:
        yield
    finally:
        set_thread_asserts(False)


def test_owner_thread_append_passes_under_asserts(thread_asserts):
    w = _Writer()
    try:
        w.append(3)
        w.wait()
        assert w.offset == 3
    finally:
        w.close()


def test_foreign_thread_append_raises_under_asserts(thread_asserts):
    w = _Writer()
    err = []
    try:
        t = threading.Thread(
            target=lambda: err.append(
                pytest.raises(AssertionError, w.append, 1)))
        t.start()
        t.join()
        assert err and "owned by" in str(err[0].value)
        assert w.offset == 0           # the race never mutated state
    finally:
        w.close()


def test_asserts_off_by_default():
    assert not thread_asserts_enabled()
    w = _Writer()
    hit = []
    try:
        t = threading.Thread(target=lambda: hit.append(w.append(1)))
        t.start()
        t.join()
        assert w.offset == 1           # permissive without --sanitize
    finally:
        w.close()
