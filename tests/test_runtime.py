"""Fault-tolerant driver: restart-on-failure, stragglers, preemption."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import DriverConfig, StragglerWatchdog, TrainDriver


def _driver(tmp_path, fault_hook=None, ckpt_every=5, max_retries=3):
    def step_fn(state, batch):
        new = {"x": state["x"] + batch}
        return new, {"loss": float(np.asarray(new["x"]))}

    def batch_fn(step):
        return jnp.asarray(1.0)

    return TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                     max_retries=max_retries, backoff_s=0.01,
                     handle_sigterm=False),
        step_fn=step_fn, batch_fn=batch_fn,
        init_state_fn=lambda: {"x": jnp.asarray(0.0)},
        fault_hook=fault_hook)


def test_driver_runs_and_checkpoints(tmp_path):
    out = _driver(tmp_path).run(12)
    assert out["final_step"] == 12
    assert float(np.asarray(out["state"]["x"])) == 12.0
    from repro.checkpoint.store import latest_step
    assert latest_step(str(tmp_path)) == 12


def test_driver_recovers_from_injected_fault(tmp_path):
    """Fail once at step 7: the driver restores from the last checkpoint
    (step 5) and replays -- final state identical to a clean run."""
    fired = []

    def hook(step):
        if step == 7 and not fired:
            fired.append(step)
            raise RuntimeError("injected node failure")

    out = _driver(tmp_path, fault_hook=hook).run(12)
    assert fired == [7]
    assert out["final_step"] == 12
    assert float(np.asarray(out["state"]["x"])) == 12.0  # exact replay
    # the abandoned timeline is pruned: each step logged exactly once
    steps = [m["step"] for m in out["metrics"]]
    assert steps == list(range(12))


def test_driver_gives_up_after_max_retries(tmp_path):
    def hook(step):
        if step >= 3:
            raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        _driver(tmp_path, fault_hook=hook, max_retries=2).run(10)


def test_straggler_watchdog_flags_slow_step():
    wd = StragglerWatchdog(factor=3.0, window=10)
    for s in range(8):
        wd.observe(s, 0.01)
    assert wd.observe(8, 0.2) is True
    assert wd.flagged and wd.flagged[0][0] == 8
    assert wd.observe(9, 0.012) is False


def test_preemption_checkpoints_and_exits(tmp_path):
    d = _driver(tmp_path, ckpt_every=100)

    orig_batch = d.batch_fn

    def batch_fn(step):
        if step == 4:
            d.preempted = True            # simulated SIGTERM
        return orig_batch(step)

    d.batch_fn = batch_fn
    out = d.run(50)
    assert out["preempted"] and out["final_step"] == 5
    from repro.checkpoint.store import latest_step
    assert latest_step(str(tmp_path)) == 5  # clean checkpoint on exit


def test_restore_does_not_materialize_init_state(tmp_path):
    """With ``abstract_state`` given, a restore never calls
    ``init_state_fn`` -- at scale, materializing a throwaway init state
    doubles peak memory right at restart (regression)."""
    import jax

    _driver(tmp_path).run(6)

    def boom():
        raise AssertionError("init_state_fn must not run on restore")

    d2 = TrainDriver(
        DriverConfig(ckpt_dir=str(tmp_path), backoff_s=0.01,
                     handle_sigterm=False),
        step_fn=lambda s, b: ({"x": s["x"] + b},
                              {"loss": float(np.asarray(s["x"]))}),
        batch_fn=lambda step: jnp.asarray(1.0),
        init_state_fn=boom,
        abstract_state={"x": jax.ShapeDtypeStruct((), jnp.float32)})
    start, state = d2._restore_or_init()
    assert start == 6 and float(np.asarray(state["x"])) == 6.0
    out = d2.run(9)
    assert out["final_step"] == 9
    assert float(np.asarray(out["state"]["x"])) == 9.0


def test_elastic_restore_via_driver(tmp_path):
    """Run 6 steps, kill, resume with a fresh driver: continues at 6
    (the driver checkpoints on exit)."""
    d1 = _driver(tmp_path, ckpt_every=5)
    d1.run(6)
    d2 = _driver(tmp_path, ckpt_every=5)
    start, state = d2._restore_or_init()
    assert start == 6 and float(np.asarray(state["x"])) == 6.0
    out = d2.run(10)
    assert out["final_step"] == 10
    assert float(np.asarray(out["state"]["x"])) == 10.0
