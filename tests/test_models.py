"""Per-architecture smoke tests (assignment requirement): a REDUCED
config of each family runs one forward/train step on CPU, asserting
output shapes and no NaNs -- plus decode-consistency spot checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.data.pipeline import LMBatchPipeline
from repro.models.config import ShapeConfig
from repro.models.model import (loss_fn, make_prefill, make_serve_step)
from repro.models.transformer import (forward, init_decode_state,
                                      init_model, logits as lm_logits)
from repro.parallel.sharding import MeshRules

RULES = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                  experts=None, vocab=None, kv_seq=None, d_inner=None)
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")

# tier-1 keeps one representative per family axis (dense / MoE / small);
# the rest of the sweep is multi-minute on CPU and runs under -m slow
FAST_ARCHS = {"gemma-2b", "granite-moe-1b-a400m"}


def _arch_params(names):
    return [a if a in FAST_ARCHS
            else pytest.param(a, marks=pytest.mark.slow) for a in names]


@pytest.mark.parametrize("arch", _arch_params(ARCH_NAMES))
def test_arch_smoke_train_step(arch):
    """One loss+grad evaluation per reduced arch: shapes + finite."""
    cfg = get_reduced(arch)
    params, specs = init_model(jax.random.PRNGKey(0), cfg)
    pipe = LMBatchPipeline(cfg=cfg, shape=SMOKE_SHAPE, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: loss_fn(p, cfg, RULES, b),
                           has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    assert int(metrics["tokens"]) > 0


@pytest.mark.parametrize("arch", _arch_params(ARCH_NAMES))
def test_arch_smoke_forward_shapes(arch):
    cfg = get_reduced(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    pipe = LMBatchPipeline(cfg=cfg, shape=SMOKE_SHAPE, seed=1)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()
             if k != "labels"}
    x, _, _ = jax.jit(lambda p, b: forward(p, cfg, RULES, b))(params, batch)
    n_text = batch["tokens"].shape[1]
    assert x.shape == (2, n_text, cfg.d_model), arch
    assert np.isfinite(np.asarray(x, np.float32)).all(), arch


@pytest.mark.parametrize("arch", _arch_params(
    ["qwen3-8b", "falcon-mamba-7b", "recurrentgemma-9b", "whisper-small",
     "granite-moe-1b-a400m"]))
def test_arch_decode_matches_forward(arch):
    """Prefill + single-token decode == full forward (per family)."""
    cfg = get_reduced(arch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, n = 2, 20
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, n)), jnp.int32)
    batch = {"tokens": tokens}
    from repro.models.frontends import STUB_WIDTH
    if cfg.encoder_seq:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, STUB_WIDTH)),
            jnp.dtype(cfg.dtype))
    x, _, _ = forward(params, cfg, RULES, batch)
    lg = lm_logits(params, x)

    st = init_decode_state(cfg, B, n)
    pre_batch = dict(batch, tokens=tokens[:, :n - 1])
    lg_p, st = jax.jit(make_prefill(cfg, RULES))(params, pre_batch, st)
    np.testing.assert_allclose(
        np.asarray(lg_p[:, 0], np.float32),
        np.asarray(lg[:, n - 2], np.float32), rtol=3e-2, atol=3e-2)
    lg_d, st = jax.jit(make_serve_step(cfg, RULES))(
        params, st, tokens[:, n - 1:], jnp.int32(n - 1))
    np.testing.assert_allclose(
        np.asarray(lg_d[:, 0], np.float32),
        np.asarray(lg[:, n - 1], np.float32), rtol=3e-2, atol=3e-2)


def test_full_configs_match_pool_specs():
    """The FULL configs carry the exact pool numbers (never reduced)."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch
    assert get_config("granite-moe-1b-a400m").n_experts == 32
    assert get_config("granite-moe-1b-a400m").moe_top_k == 8
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("falcon-mamba-7b").ssm_state == 16
    assert get_config("recurrentgemma-9b").window == 2048


def test_param_counts_sane():
    assert get_config("kimi-k2-1t-a32b").param_count() > 1.0e12
    assert 25e9 < get_config("kimi-k2-1t-a32b").active_param_count() < 35e9
    assert 6.5e9 < get_config("falcon-mamba-7b").param_count() < 7.8e9
    assert 8.5e9 < get_config("recurrentgemma-9b").param_count() < 10.5e9
