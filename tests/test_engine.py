"""Single-shard engine: dynamics, modes, plasticity, rate separation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               firing_rate_hz, init_plasticity,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.neuron import LIFParams, lif_sfa_step
from repro.core.stdp import STDPParams


def _cfg(law=None, n_per_col=50, grid=4, **kw):
    law = law or gaussian_law()
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    return EngineConfig(decomp=d, law=law, **kw)


def test_neuron_refractory_and_reset():
    p = LIFParams()
    st = {"v": jnp.asarray([25.0, 5.0]), "c": jnp.zeros(2),
          "refrac": jnp.asarray([0, 0], jnp.int32)}
    new, spk = lif_sfa_step(st, jnp.zeros(2), p)
    assert spk[0] == 1.0 and spk[1] == 0.0
    assert new["v"][0] == p.v_reset_mv
    assert new["refrac"][0] == p.refrac_steps
    assert new["c"][0] == pytest.approx(p.alpha_c)
    # refractory neuron cannot spike even under huge drive
    new2, spk2 = lif_sfa_step(new, jnp.asarray([100.0, 0.0]), p)
    assert spk2[0] == 0.0 and new2["refrac"][0] == p.refrac_steps - 1


def test_run_no_nan_and_reasonable_rate():
    cfg = _cfg()
    tabs = build_shard_tables(cfg)
    st = init_sim_state(cfg)
    st2, per_step = jax.jit(lambda s: simulate(s, tabs, cfg, 200))(st)
    assert np.isfinite(np.asarray(st2["neuron"]["v"])).all()
    rate = firing_rate_hz(st2, cfg, 200)
    assert 0.1 < rate < 100.0
    assert float(st2["metrics"]["dropped"]) == 0.0


def test_event_mode_equals_gather_all_dynamics():
    """Same seed, same tables: the two delivery modes must produce the
    exact same spike trains (event-driven is an optimization, not an
    approximation)."""
    cfg_e = _cfg(mode="event")
    cfg_g = _cfg(mode="gather_all")
    tabs = build_shard_tables(cfg_e)
    s_e, spikes_e = jax.jit(lambda s: simulate(s, tabs, cfg_e, 100))(
        init_sim_state(cfg_e))
    s_g, spikes_g = jax.jit(lambda s: simulate(s, tabs, cfg_g, 100))(
        init_sim_state(cfg_g))
    np.testing.assert_array_equal(np.asarray(spikes_e),
                                  np.asarray(spikes_g))
    assert float(s_e["metrics"]["events"]) == \
        float(s_g["metrics"]["events"])


@pytest.mark.slow
def test_rate_separation_exponential_vs_gaussian():
    """Paper section 2: identical parameters, only the connectivity law
    changes -> the exponential net fires at a higher rate (32-38 Hz vs
    7.5 Hz at full scale; at reduced scale we assert the ordering)."""
    rates = {}
    for name, law in [("gauss", gaussian_law()), ("expo", exponential_law())]:
        # grid must be big enough that the 21-column exponential stencil
        # is not fully edge-truncated (8x8 gives a ~1.7x separation;
        # the ratio grows toward the paper's ~4.5x with grid size)
        cfg = _cfg(law=law, n_per_col=60, grid=8)
        tabs = build_shard_tables(cfg)
        st, _ = jax.jit(lambda s, c=cfg, t=tabs: simulate(s, t, c, 300))(
            init_sim_state(cfg))
        rates[name] = firing_rate_hz(st, cfg, 300)
    assert rates["expo"] > 1.4 * rates["gauss"], rates


def test_stdp_potentiation_depression_ordering():
    """Pair-based STDP sign: pre->post potentiates, post->pre depresses."""
    cfg = _cfg(n_per_col=30, stdp=STDPParams(a_plus=0.01, a_minus=0.01))
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    w0 = np.asarray(tabs["local"]["w"]).copy()
    st = init_sim_state(cfg)
    (st2, tabs2, traces), _ = jax.jit(
        lambda s, t: simulate(s, t, cfg, 120, plasticity=aux))(st, tabs)
    w1 = np.asarray(tabs2["local"]["w"])
    assert np.abs(w1 - w0).sum() > 0
    plastic = w0 > 0
    assert (w1[plastic] >= -1e-6).all()
    assert (w1[plastic] <= cfg.stdp.w_max + 1e-6).all()
    np.testing.assert_array_equal(w1[~plastic], w0[~plastic])


def test_external_drive_scales_with_rate():
    from repro.core.engine import external_drive
    key = jax.random.PRNGKey(0)
    cfg_lo = _cfg(ext_rate_hz=1.0)
    cfg_hi = _cfg(ext_rate_hz=30.0)
    lo = float(jnp.sum(external_drive(key, 5000, cfg_lo)))
    hi = float(jnp.sum(external_drive(key, 5000, cfg_hi)))
    assert hi > 10 * lo
