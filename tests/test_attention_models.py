"""Model-level attention: impl equivalence, flash VJP, ring caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.models.attention import chunked_attention
from repro.models.config import ModelConfig
from repro.models.moe import _apply_moe_dense, init_moe
from repro.parallel.sharding import MeshRules

RULES = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                  experts=None, vocab=None, kv_seq=None, d_inner=None)


def _ref(q, k, v, **kw):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    o = kref.attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, sq, d),
        k.transpose(0, 2, 1, 3).reshape(b * kv, k.shape[1], d),
        v.transpose(0, 2, 1, 3).reshape(b * kv, v.shape[1], d), **kw)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("sq,sk,h,kv,causal,win,cq,ck", [
    (37, 37, 4, 2, True, None, 16, 16),
    pytest.param(64, 64, 4, 1, True, 24, 16, 16,
                 marks=pytest.mark.slow),
    pytest.param(20, 50, 2, 2, False, None, 16, 16,
                 marks=pytest.mark.slow),
    pytest.param(50, 50, 2, 2, True, None, 50, 50,    # single chunk
                 marks=pytest.mark.slow),
])
def test_chunked_attention_fwd_bwd(sq, sk, h, kv, causal, win, cq, ck, rng):
    d = 16
    q = jnp.asarray(rng.normal(size=(2, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, sk, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, sk, kv, d)), jnp.float32)
    f = lambda q, k, v: chunked_attention(
        q, k, v, causal=causal, window=win, scale=d ** -0.5,
        chunk_q=cq, chunk_k=ck)
    np.testing.assert_allclose(f(q, k, v),
                               _ref(q, k, v, causal=causal, window=win),
                               rtol=2e-4, atol=2e-4)
    g = jax.grad(lambda *a: jnp.sum(jnp.sin(f(*a))), argnums=(0, 1, 2))(
        q, k, v)
    gr = jax.grad(lambda *a: jnp.sum(jnp.sin(
        _ref(*a, causal=causal, window=win))), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


@pytest.mark.slow
def test_ring_cache_decode_matches_windowed_attention(rng):
    """long-context decode: the W-slot ring cache must reproduce full
    sliding-window attention exactly."""
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                      window=8, dtype="float32", pattern=("attn",),
                      attn_chunk_q=16, attn_chunk_k=16)
    from repro.models.attention import apply_attention, init_attention, \
        init_cache
    p, _ = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    S = 24
    x = jnp.asarray(rng.normal(size=(1, S, 32)), jnp.float32)
    pos = jnp.arange(S)
    full, _ = apply_attention(p, cfg, RULES, x, pos, causal=True,
                              window=cfg.window)
    cache = init_cache(cfg, 1, S, jnp.float32, window=cfg.window)
    assert cache.ring and cache.k.shape[1] == cfg.window
    # prefill 16 tokens, then decode the rest one by one
    _, cache = apply_attention(p, cfg, RULES, x[:, :16], pos[:16],
                               causal=True, window=cfg.window,
                               cache=cache, cache_pos=jnp.int32(0))
    for t in range(16, S):
        out, cache = apply_attention(
            p, cfg, RULES, x[:, t:t + 1], pos[t:t + 1], causal=True,
            window=cfg.window, cache=cache, cache_pos=jnp.int32(t))
        np.testing.assert_allclose(out[:, 0], full[:, t],
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_moe_dense_capacity_accounting(rng):
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=1, n_kv_heads=1, d_ff=8, vocab_size=32,
                      n_experts=4, moe_top_k=2, capacity_factor=0.25,
                      dtype="float32")
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 256, 16)), jnp.float32)
    y, aux = _apply_moe_dense(p, cfg, RULES, x)
    assert y.shape == x.shape
    # tight capacity must actually drop assignments
    assert float(aux["frac_dropped"]) > 0.0
    assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
    # generous capacity drops nothing
    cfg2 = ModelConfig(**{**cfg.__dict__, "capacity_factor": 8.0})
    _, aux2 = _apply_moe_dense(p, cfg2, RULES, x)
    assert float(aux2["frac_dropped"]) == 0.0
