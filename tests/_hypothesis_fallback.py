"""Minimal stand-in for ``hypothesis`` when it is not installed.

The real dependency is declared in ``pyproject.toml`` (CI installs it);
this fallback keeps the suite runnable in hermetic environments where
``pip install`` is unavailable.  It covers exactly the API surface the
tests use -- ``@given`` + ``@settings`` with ``st.integers``,
``st.floats`` and ``st.sampled_from`` -- by drawing a deterministic,
seeded sample of examples instead of doing property search/shrinking.

Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # hermetic env
        from _hypothesis_fallback import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import inspect
import os
import zlib

import numpy as np

# Fewer examples than real hypothesis defaults: the fallback does no
# shrinking, so extra draws buy little; keep tier-1 fast.
MAX_EXAMPLES_CAP = int(os.environ.get("HYPOTHESIS_FALLBACK_EXAMPLES", "6"))


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the ``hypothesis.strategies`` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def settings(max_examples=10, **_ignored):
    """Records ``max_examples``; deadline/etc. are meaningless here."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies_args):
    def deco(fn):
        inner = fn

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read from the wrapper: ``@settings`` may sit above or below
            # ``@given`` (functools.wraps copies the attr up; a later
            # ``settings`` application mutates the wrapper directly)
            n = min(getattr(wrapper, "_fallback_max_examples", 10),
                    MAX_EXAMPLES_CAP)
            # Stable per-test seed so failures reproduce across runs.
            seed = zlib.crc32(inner.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strategies_args)
                inner(*args, *drawn, **kwargs)

        # ``settings`` may be applied above or below ``given``.
        wrapper._fallback_max_examples = getattr(
            inner, "_fallback_max_examples", 10)
        # Hide the strategy-filled (rightmost) params from pytest's
        # fixture resolution, like real hypothesis does.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strategies_args)])
        return wrapper

    return deco
