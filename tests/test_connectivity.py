"""Connectivity laws: the paper's own numbers + property tests."""

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.connectivity import (ConnectivityLaw, exponential_law,
                                     gaussian_law, expected_synapse_counts,
                                     NEURONS_PER_COLUMN)


def test_paper_stencils():
    assert gaussian_law().radius == 3            # 7x7
    assert gaussian_law().stencil_width == 7
    assert exponential_law().radius == 10        # 21x21
    assert exponential_law().stencil_width == 21


def test_paper_cutoff_distances():
    # DESIGN.md section 2 derivation
    assert gaussian_law().r_cut_um == pytest.approx(279.7, abs=0.5)
    assert exponential_law().r_cut_um == pytest.approx(986.4, abs=0.5)


@pytest.mark.parametrize("grid,law,recur_g,total_g", [
    ((24, 24), "gaussian", 0.9, 1.2),
    ((24, 24), "exponential", 1.5, 1.8),
    ((48, 48), "gaussian", 3.5, 5.0),
    ((48, 48), "exponential", 5.9, 7.4),
    ((96, 96), "gaussian", 14.2, 20.4),
    ((96, 96), "exponential", 23.4, 29.6),
])
def test_table1_synapse_counts(grid, law, recur_g, total_g):
    """Reproduce paper Table 1 within 10% (the paper rounds to 0.1G)."""
    l = gaussian_law() if law == "gaussian" else exponential_law()
    c = expected_synapse_counts(l, *grid)
    assert c["recurrent_synapses"] / 1e9 == pytest.approx(recur_g, rel=0.10)
    assert c["total_synapses"] / 1e9 == pytest.approx(total_g, rel=0.10)


def test_paper_per_neuron_counts():
    g = expected_synapse_counts(gaussian_law(), 96, 96)
    e = expected_synapse_counts(exponential_law(), 96, 96)
    # ~990 local + ~250 remote (gaussian), >1000 remote (exponential)
    assert g["remote_per_neuron"] == pytest.approx(250, rel=0.15)
    assert e["remote_per_neuron"] > 1000
    assert g["recurrent_per_neuron"] == pytest.approx(1240, rel=0.1)
    assert e["recurrent_per_neuron"] == pytest.approx(2050, rel=0.1)


def test_neurons_match_paper():
    assert expected_synapse_counts(gaussian_law(), 24, 24)["neurons"] == \
        576 * NEURONS_PER_COLUMN  # 0.71M


@given(st.floats(0.001, 0.2), st.floats(50.0, 500.0),
       st.sampled_from(["gaussian", "exponential"]))
@settings(max_examples=50, deadline=None)
def test_prob_monotone_decreasing(a, scale, kind):
    law = ConnectivityLaw(kind=kind, amplitude=a, scale_um=scale)
    r = np.linspace(0.0, 3000.0, 200)
    p = law.prob(r)
    assert (np.diff(p) <= 1e-12).all()
    assert (p <= a + 1e-12).all() and (p >= 0).all()


@given(st.floats(0.002, 0.2), st.floats(50.0, 500.0),
       st.sampled_from(["gaussian", "exponential"]))
@settings(max_examples=50, deadline=None)
def test_cutoff_consistency(a, scale, kind):
    """p(r) > cutoff exactly inside r_cut; stencil covers r_cut."""
    law = ConnectivityLaw(kind=kind, amplitude=a, scale_um=scale)
    rc = law.r_cut_um
    if rc > 0:
        assert law.prob(rc * 0.999) > 0
        assert law.prob(rc * 1.001) == 0
    assert law.radius >= math.floor(rc / law.alpha_um)


@given(st.sampled_from(["gaussian", "exponential"]))
@settings(max_examples=10, deadline=None)
def test_stencil_symmetry(kind):
    law = gaussian_law() if kind == "gaussian" else exponential_law()
    off = law.stencil_offsets()
    s = {(int(y), int(x)) for y, x in off}
    assert (0, 0) not in s
    for y, x in list(s):
        assert (-y, -x) in s and (x, y) in s      # 8-fold symmetry
