"""Perf-variant correctness: the optimizations must be function-exact."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import apply_attention, init_attention
from repro.models.config import ModelConfig
from repro.parallel.sharding import MeshRules

RULES = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                  experts=None, vocab=None, kv_seq=None, d_inner=None)


def test_head_padding_is_function_exact(rng):
    """Pad 3 q-heads (2 kv) to 4/4: with zeroed extra out-proj rows and
    zero-extended kv projections, outputs are bit-compatible."""
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=24,
                      n_heads=3, n_kv_heads=3, d_ff=32, vocab_size=64,
                      head_dim=8, dtype="float32",
                      attn_chunk_q=16, attn_chunk_k=16)
    cfg_pad = dataclasses.replace(cfg, n_heads=4, n_kv_heads=4)
    p, _ = init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    # build padded params: extra slices zero
    pp = {
        "wq": jnp.zeros((24, 4, 8)).at[:, :3].set(p["wq"]),
        "wk": jnp.zeros((24, 4, 8)).at[:, :3].set(p["wk"]),
        "wv": jnp.zeros((24, 4, 8)).at[:, :3].set(p["wv"]),
        "wo": jnp.zeros((4, 8, 24)).at[:3].set(p["wo"]),
    }
    x = jnp.asarray(rng.normal(size=(2, 20, 24)), jnp.float32)
    pos = jnp.arange(20)
    out, _ = apply_attention(p, cfg, RULES, x, pos, causal=True)
    out_pad, _ = apply_attention(pp, cfg_pad, RULES, x, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_pad),
                               rtol=1e-5, atol=1e-6)


def test_variant_registry_applies():
    from repro.launch.dryrun import VARIANTS, _apply_cfg_variant
    from repro.configs import get_config
    cfg = get_config("qwen2-1.5b")                 # 12 heads, kv 2
    v = _apply_cfg_variant(cfg, VARIANTS["padded_heads"])
    assert v.n_heads == 16 and v.n_kv_heads == 2   # 16 % 2 == 0, kv kept
    assert v.resolved_head_dim == cfg.resolved_head_dim
    w = _apply_cfg_variant(get_config("whisper-small"),
                           VARIANTS["padded_heads"])
    assert w.n_heads == 16 and w.n_kv_heads == 16  # MHA: pad kv too
    k = _apply_cfg_variant(get_config("kimi-k2-1t-a32b"),
                           VARIANTS["micro2"])
    assert k.n_heads == 64                          # untouched
