"""Runtime telemetry: span tracer semantics, JSONL/Chrome round-trips,
and the pure-observer contract on the segmented driver (spike trains
and plastic weight checksums bit-identical with tracing on or off,
including across preempt -> resume)."""

import io
import json
import logging
import threading

import jax
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.dist_engine import DistConfig
from repro.core.engine import EngineConfig
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.obs.telemetry import (FORMAT, Telemetry, enable_json_logging,
                                 read_jsonl, summarize)
from repro.parallel.compat import make_mesh
from repro.perf.trace import to_chrome_trace, write_chrome_trace
from repro.runtime import DriverConfig, SimDriver

N = 40

LAWS = {"gaussian": gaussian_law, "exponential": exponential_law}


def _dist_cfg(law="gaussian", stdp=None, seed=3):
    lw = LAWS[law]()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=lw.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=lw, seed=seed,
                                          stdp=stdp))


def _driver(ckpt_dir, seg, law="gaussian", stdp=None, **kw):
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir),
                       ckpt_every=kw.pop("ckpt_every", 1),
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, _dist_cfg(law, stdp=stdp), mesh,
                     segment_steps=seg, **kw)


def _state_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# tracer unit semantics
# ---------------------------------------------------------------------------

def test_span_nesting_and_thread_attribution():
    tel = Telemetry()
    with tel.span("outer", step=0):
        with tel.span("inner"):
            pass

    def worker():
        with tel.span("worker_span"):
            pass

    t = threading.Thread(target=worker, name="writer-0")
    t.start()
    t.join()

    outer, = tel.spans("outer")
    inner, = tel.spans("inner")
    wspan, = tel.spans("worker_span")
    # nesting: inner closed first, carries outer as parent, depth 1
    assert inner["parent"] == "outer" and inner["depth"] == 1
    assert outer["parent"] is None and outer["depth"] == 0
    assert outer["attrs"] == {"step": 0}
    assert outer["dur"] >= inner["dur"] >= 0
    # the worker thread has its own stack: no cross-thread parent, and
    # the record names the emitting thread
    assert wspan["parent"] is None and wspan["depth"] == 0
    assert wspan["thread"] == "writer-0"
    assert wspan["tid"] != outer["tid"]


def test_disabled_tracer_is_a_no_op_but_still_logs(caplog):
    tel = Telemetry(enabled=False)
    with tel.span("segment", step=0):
        pass
    tel.metrics("segment", wall_s=1.0)
    with caplog.at_level(logging.WARNING, logger="repro.telemetry"):
        tel.event("straggler", msg="step 3 overran", level="warning",
                  step=3)
    assert tel.records() == []            # nothing collected...
    assert "step 3 overran" in caplog.text   # ...but operators still see it
    assert caplog.records[0].repro_event == {"kind": "straggler",
                                             "step": 3}


def test_jsonl_roundtrip_and_chrome_schema(tmp_path):
    tel = Telemetry(jsonl_path=str(tmp_path / "t.jsonl"))
    with tel.span("segment", step=0):
        with tel.span("segment.compute", step=0):
            pass
    tel.event("straggler", msg="overran", level="warning", step=0,
              dt_s=2.0)
    tel.metrics("segment", step=0, wall_s=0.5, d_spikes=3.0)
    tel.flush_jsonl()

    back = read_jsonl(str(tmp_path / "t.jsonl"))
    assert [h["format"] for h in back["header"]] == [FORMAT]
    assert {s["name"] for s in back["span"]} == {"segment",
                                                 "segment.compute"}
    ev, = back["event"]
    assert ev["kind"] == "straggler" and ev["dt_s"] == 2.0
    m, = back["metrics"]
    assert m["kind"] == "segment" and m["d_spikes"] == 3.0

    trace = to_chrome_trace(tel.records(), pid=7)
    path = write_chrome_trace(tel, str(tmp_path / "trace.json"))
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk["otherData"]["format"] == FORMAT
    durs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in durs} == {"segment", "segment.compute"}
    for e in durs:
        assert e["pid"] == 7 and e["ts"] >= 0 and e["dur"] >= 0
    inner = next(e for e in durs if e["name"] == "segment.compute")
    assert inner["args"]["parent"] == "segment"
    instants = [e for e in trace["traceEvents"] if e["ph"] == "i"]
    assert {e["name"] for e in instants} == {"straggler", "segment"}
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert [e["args"]["name"] for e in meta] == ["MainThread"]


def test_flush_jsonl_is_exactly_once(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tel = Telemetry(jsonl_path=path)
    with tel.span("a"):
        pass
    assert tel.flush_jsonl() == 1
    assert tel.flush_jsonl() == 0         # nothing new: no rewrite
    with tel.span("b"):
        pass
    assert tel.flush_jsonl() == 1         # only the new record appends
    back = read_jsonl(path)
    assert len(back["header"]) == 1
    assert [s["name"] for s in back["span"]] == ["a", "b"]


def test_summarize_aggregates_spans_segments_and_events(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tel = Telemetry(jsonl_path=path)
    for step in (0, 10):
        with tel.span("segment", step=step):
            pass
        tel.metrics("segment", step=step, wall_s=0.5, steps_per_s=20.0,
                    d_spikes=3.0, d_events=7.0, d_dropped=0.0,
                    d_recorder_dropped=0.0)
    tel.event("straggler", msg="overran", level="warning", step=10)
    tel.flush_jsonl()

    s = summarize(read_jsonl(path))
    assert s["processes"] == 1
    seg_span = s["spans"]["segment"]
    assert seg_span["count"] == 2
    assert seg_span["total_s"] >= seg_span["max_s"] >= \
        seg_span["mean_s"] >= 0
    assert s["events"] == {"straggler": 1}
    seg = s["segments"]
    assert seg["n"] == 2 and seg["wall_s"] == 1.0
    assert seg["steps_per_s_mean"] == seg["steps_per_s_min"] == 20.0
    assert seg["d_spikes"] == 6.0 and seg["d_events"] == 14.0


def test_read_jsonl_refuses_foreign_streams(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"type": "header", "format": "other-v9"})
                 + "\n")
    with pytest.raises(ValueError, match="unknown telemetry format"):
        read_jsonl(str(p))
    p.write_text(json.dumps({"type": "span", "name": "x"}) + "\n")
    with pytest.raises(ValueError, match="no telemetry header"):
        read_jsonl(str(p))


def test_json_log_formatter_emits_structured_lines():
    stream = io.StringIO()
    handler = enable_json_logging(stream=stream)
    lg = logging.getLogger("repro")
    try:
        Telemetry(enabled=False).event(
            "preempt", msg="SIGTERM received", level="warning",
            logger=logging.getLogger("repro.runtime"), step=20)
    finally:
        lg.removeHandler(handler)
        lg.propagate = True
    rec = json.loads(stream.getvalue().strip())
    assert rec["level"] == "warning" and rec["msg"] == "SIGTERM received"
    assert rec["event"] == {"kind": "preempt", "step": 20}


# ---------------------------------------------------------------------------
# driver integration: pure observer + per-segment stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("law", ["gaussian", "exponential"])
def test_pure_observer_bit_identity_static(tmp_path, law):
    """Tracing on vs off: bit-identical spike trains and final state."""
    ref = _driver(tmp_path / "off", seg=10, law=law, record_events=True)
    out_ref = ref.run(N)
    tel = Telemetry()
    traced = _driver(tmp_path / "on", seg=10, law=law,
                     record_events=True, telemetry=tel)
    out_tel = traced.run(N)
    np.testing.assert_array_equal(ref.spike_counts(N),
                                  traced.spike_counts(N))
    _state_equal(out_ref["state"], out_tel["state"])
    # and the tracer actually observed the run it did not perturb
    assert len(tel.spans("segment.compute")) == N // 10
    assert len([r for r in tel.records()
                if r["type"] == "metrics"]) == N // 10


def test_pure_observer_bit_identity_plastic_preempt_resume(tmp_path):
    """Traced preempt -> resume plastic run == untraced straight run,
    down to the tiling-invariant learned-weight checksum."""
    from repro.core.stdp import STDPParams
    ref = _driver(tmp_path / "ref", seg=10, stdp=STDPParams(),
                  record_events=True)
    out_ref = ref.run(N)

    first = _driver(tmp_path / "t", seg=10, stdp=STDPParams(),
                    record_events=True, preempt_after_segments=1,
                    telemetry=Telemetry())
    out1 = first.run(N)
    assert out1["preempted"] and out1["final_step"] == 10
    second = _driver(tmp_path / "t", seg=10, stdp=STDPParams(),
                     record_events=True, telemetry=Telemetry())
    out2 = second.run(N)
    assert out2["final_step"] == N

    np.testing.assert_array_equal(ref.spike_counts(N),
                                  second.spike_counts(N))
    _state_equal(out_ref["state"], out2["state"])
    assert ref.plastic_summary(out_ref["state"])["weight_checksum"] \
        == second.plastic_summary(out2["state"])["weight_checksum"]


def test_segment_stream_carries_deltas_and_spans(tmp_path):
    tel = Telemetry()
    drv = _driver(tmp_path, seg=10, record_events=True, telemetry=tel)
    out = drv.run(N)

    segs = [r for r in tel.records() if r["type"] == "metrics"
            and r["kind"] == "segment"]
    assert [m["step"] for m in segs] == [0, 10, 20, 30]
    for m in segs:
        assert m["wall_s"] > 0 and m["steps_per_s"] > 0
        for k in ("d_spikes", "d_events", "d_dropped",
                  "d_recorder_dropped"):
            assert k in m
    # deltas telescope back to the cumulative totals
    totals = drv.metric_totals(out["state"])
    assert sum(m["d_spikes"] for m in segs) == totals["spikes"]
    assert sum(m["d_events"] for m in segs) == totals["events"]
    # the same deltas ride the driver's metrics_log (--metrics-out)
    assert all("d_spikes" in row for row in out["metrics"])
    # every driver phase reported spans; writer-thread work is
    # attributed to the writer threads, not the main loop
    names = {s["name"] for s in tel.spans()}
    assert {"segment", "segment.compute", "segment.spool_drain",
            "ckpt.snapshot", "ckpt.spool_sync", "ckpt.d2h",
            "ckpt.write", "spool.write", "restore.init"} <= names
    main_tid = tel.spans("segment")[0]["tid"]
    assert all(s["tid"] != main_tid for s in tel.spans("ckpt.write"))
    compute = tel.spans("segment.compute")[0]
    assert compute["parent"] == "segment" and compute["depth"] == 1


def test_analyze_cli_folds_in_telemetry_summary(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    drv = _driver(tmp_path, seg=10, record_events=True,
                  telemetry=Telemetry(jsonl_path=path))
    drv.run(N)
    drv.tel.flush_jsonl()

    from repro.launch.analyze import main as analyze_main
    out = analyze_main(["--run", f"r={tmp_path}",
                        "--telemetry", f"r={path}",
                        "--out", str(tmp_path / "report.json")])
    t = out["telemetry"]["r"]
    assert t["processes"] == 1
    assert t["segments"]["n"] == N // 10
    assert t["spans"]["segment.compute"]["count"] == N // 10
    with open(tmp_path / "report.json") as f:
        assert json.load(f)["telemetry"]["r"]["segments"]["n"] == N // 10


def test_exactly_once_stream_across_preempt_resume(tmp_path):
    """Each process appends its own header + records once; the stitched
    file holds every segment exactly once."""
    path = str(tmp_path / "telemetry.jsonl")
    tel1 = Telemetry(jsonl_path=path)
    d1 = _driver(tmp_path, seg=10, preempt_after_segments=1,
                 telemetry=tel1)
    d1.run(N)
    tel1.flush_jsonl()
    tel1.flush_jsonl()                    # idempotent final flush

    tel2 = Telemetry(jsonl_path=path)
    d2 = _driver(tmp_path, seg=10, telemetry=tel2)
    out = d2.run(N)
    assert out["final_step"] == N
    tel2.flush_jsonl()

    back = read_jsonl(path)
    assert len(back["header"]) == 2       # one per process
    segs = [m["step"] for m in back["metrics"]
            if m["kind"] == "segment"]
    assert sorted(segs) == [0, 10, 20, 30]
    resumes = [e for e in back["event"] if e["kind"] == "resume"]
    assert len(resumes) == 1 and resumes[0]["step"] == 10
