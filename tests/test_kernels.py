"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.neuron import LIFParams
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.synaptic_accum import synaptic_accum_pallas


# ---------------------------------------------------------------------------
# lif_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 1024, 4097, 70000])
def test_lif_kernel_sweep(n, rng):
    p = LIFParams()
    st = {"v": jnp.asarray(rng.uniform(-5, 25, n), jnp.float32),
          "c": jnp.asarray(rng.uniform(0, 3, n), jnp.float32),
          "refrac": jnp.asarray(rng.integers(0, 3, n), jnp.int32)}
    i = jnp.asarray(rng.uniform(-2, 6, n), jnp.float32)
    a = jnp.asarray(rng.uniform(0, 1, n) > 0.1)
    s1, k1 = ops.lif_step(st, i, p, a)
    s2, k2 = ops.lif_step_ref(st, i, p, a)
    for kk in s1:
        np.testing.assert_allclose(s1[kk], s2[kk], rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(k1, k2)


@given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_lif_kernel_property(n, seed):
    rng = np.random.default_rng(seed)
    p = LIFParams()
    st = {"v": jnp.asarray(rng.uniform(-10, 30, n), jnp.float32),
          "c": jnp.asarray(rng.uniform(0, 5, n), jnp.float32),
          "refrac": jnp.asarray(rng.integers(0, 4, n), jnp.int32)}
    i = jnp.asarray(rng.uniform(-5, 10, n), jnp.float32)
    new, spk = ops.lif_step(st, i, p)
    # invariants: spiking neurons reset + enter refractory
    spk = np.asarray(spk).astype(bool)
    assert (np.asarray(new["v"])[spk] == p.v_reset_mv).all()
    assert (np.asarray(new["refrac"])[spk] == p.refrac_steps).all()
    # non-spiking: refractory counter decremented toward 0
    old_r = np.asarray(st["refrac"])
    assert (np.asarray(new["refrac"])[~spk]
            == np.maximum(old_r[~spk] - 1, 0)).all()


# ---------------------------------------------------------------------------
# synaptic_accum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,cap,d_ring,n_local,n_events", [
    (5, 3, 2, 17, 2),
    (33, 17, 8, 300, 12),
    (64, 8, 4, 100, 64),
])
def test_synaptic_accum_sweep(rows, cap, d_ring, n_local, n_events, rng):
    tgt = jnp.asarray(rng.integers(0, n_local, (rows + 1, cap)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(rows + 1, cap)), jnp.float32)
    w = w.at[-1].set(0)
    ds = jnp.asarray(rng.integers(0, d_ring, (rows + 1, cap)), jnp.int8)
    ring = jnp.asarray(rng.normal(size=(d_ring, n_local)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows + 1, n_events), jnp.int32)
    got = synaptic_accum_pallas(idx, 3, tgt, w, ds, ring)
    want = ref.synaptic_accum_ref(idx, 3, tgt, w, ds, ring)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_synaptic_accum_sink_row_is_noop(rng):
    rows, cap, d_ring, n_local = 8, 4, 4, 20
    tgt = jnp.zeros((rows + 1, cap), jnp.int32)
    w = jnp.zeros((rows + 1, cap), jnp.float32)
    ds = jnp.zeros((rows + 1, cap), jnp.int8)
    ring = jnp.asarray(rng.normal(size=(d_ring, n_local)), jnp.float32)
    idx = jnp.full((6,), rows, jnp.int32)        # all padding events
    out = synaptic_accum_pallas(idx, 0, tgt, w, ds, ring)
    np.testing.assert_array_equal(out, ring)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    (4, 2, 64, 64, 32, True, None, 0),
    (8, 2, 100, 100, 64, True, 48, 0),
    (2, 2, 37, 129, 16, False, None, 0),
    (2, 1, 1, 77, 32, True, None, 76),
    (3, 3, 48, 16, 8, True, None, 0),
]


@pytest.mark.parametrize("bh,bhkv,sq,sk,d,causal,win,qoff", CASES)
def test_flash_attention_sweep(bh, bhkv, sq, sk, d, causal, win, qoff, rng):
    q = jnp.asarray(rng.normal(size=(bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bhkv, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bhkv, sk, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=win,
                          q_offset=qoff, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=win,
                             q_offset=qoff)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype, rng):
    q = jnp.asarray(rng.normal(size=(2, 64, 16)), dtype)
    k = jnp.asarray(rng.normal(size=(2, 64, 16)), dtype)
    v = jnp.asarray(rng.normal(size=(2, 64, 16)), dtype)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, causal=True)
    assert got.dtype == dtype
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=tol, atol=tol)


def test_flash_attention_rows_fully_masked(rng):
    """Window smaller than block: early rows w/ no visible keys -> 0."""
    q = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
    # q_offset far beyond keys: every row masked by causality+window
    out = flash_attention(q, k, v, causal=False, window=2, q_offset=100,
                          block_q=8, block_k=8)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
