"""HLO cost analyzer + spike bit-packing properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.halo import (halo_import_bytes, pack_bits, packed_width,
                             unpack_bits)
from repro.perf.hlo_analysis import analyze_hlo, parse_computations


def test_analyzer_multiplies_loop_bodies():
    """THE reason this analyzer exists: cost_analysis counts a scan body
    once; ours multiplies by the annotated trip count."""
    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(scanned).lower(x, w).compile()
    costs = analyze_hlo(compiled.as_text())
    one_matmul = 2 * 128 ** 3
    assert costs.dot_flops == pytest.approx(10 * one_matmul, rel=0.01)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):       # older jax returns [dict]
        ca = ca[0]
    xla_flops = ca["flops"]
    assert xla_flops == pytest.approx(one_matmul, rel=0.01)  # body once


def test_analyzer_nested_loops():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    costs = analyze_hlo(jax.jit(nested).lower(x, w).compile().as_text())
    assert costs.dot_flops == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)


def test_parse_computations_finds_entry():
    txt = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile().as_text()
    comps = parse_computations(txt)
    assert sum(c["entry"] for c in comps.values()) == 1


@given(st.integers(1, 200), st.integers(0, 2 ** 31 - 1),
       st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_pack_bits_roundtrip(n, seed, lead):
    rng = np.random.default_rng(seed)
    x = (rng.random((lead, n)) < 0.3).astype(np.float32)
    packed = pack_bits(jnp.asarray(x))
    assert packed.shape == (lead, packed_width(n))
    back = unpack_bits(packed, n)
    np.testing.assert_array_equal(np.asarray(back), x)


def test_pack_bits_is_32x_smaller():
    x = jnp.zeros((10, 10, 992), jnp.float32)
    assert pack_bits(x).size * 8 * 4 == x.size * 4  # 1 bit vs 32 bits


def test_halo_import_bytes_strip_less_than_block():
    # radius < tile: block mode ships whole tiles, strip ships the rim
    s = halo_import_bytes(8, 8, 3, 100, mode="strip")
    b = halo_import_bytes(8, 8, 3, 100, mode="block")
    assert s < b
    # exact strip volume = dilated area - tile area
    assert s == ((8 + 6) ** 2 - 64) * 100
