"""The fused Pallas event-delivery pipeline is a drop-in for the XLA
path: interpret-mode kernel vs ``deliver_events`` vs ``kernels.ref``
across both connectivity laws and multiple halo fan-out bands."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.synapses import SynapseTableSpec, build_tables, deliver_events
from repro.kernels import ref
from repro.kernels.synaptic_accum import (ENTRY_BLOCK, LANES,
                                          compact_events, event_delivery,
                                          event_delivery_banded,
                                          synaptic_accum_pallas)


def _dist_spec(law, grid=8, n_per_col=12, tiles=(4, 2)):
    # n_per_col=12 keeps the kernel/XLA/ref triple comparison fast; the
    # gaussian law needs 20 for the fan-out map to split into >= 2 bands.
    # rate_cap_hz=25 shrinks the compaction head-room (and with it the
    # interpret-mode trace cost) while staying far above the ~8% spike
    # rates these tests drive.
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=tiles[0], tiles_x=tiles[1],
                          radius=law.radius)
    return SynapseTableSpec(decomp=d, law=law, rate_cap_hz=25.0)


def _band_spikes(spec, rng, rate=0.05):
    return [jnp.asarray((rng.random(b["rows"]) < rate).astype(np.float32))
            for b in spec.halo_bands()]


@pytest.mark.parametrize("law_name", ["gaussian", "exponential"])
def test_banded_delivery_matches_xla_and_ref(law_name, rng):
    """Local tier + every halo band, one fused kernel launch vs the
    per-tier XLA loop vs the pure-jnp oracle."""
    law = gaussian_law() if law_name == "gaussian" else exponential_law()
    spec = _dist_spec(law, n_per_col=20 if law_name == "gaussian" else 12)
    bands = spec.halo_bands()
    assert len(bands) >= 2, "need at least two halo fan-out bands"

    tabs = build_tables(spec, 1, 1, j_exc=0.4, j_inh=-2.0, seed=3)
    spikes_local = jnp.asarray(
        (rng.random(spec.n_local) < 0.08).astype(np.float32))
    spikes_bands = _band_spikes(spec, rng)
    ring0 = jnp.asarray(rng.normal(size=(spec.d_ring, spec.n_local)),
                        jnp.float32)
    t_slot = 5

    tiers = [(tabs["local"], spikes_local, spec.active_cap_local)]
    tiers += [(tab, spk, spec.active_cap_band(b))
              for b, tab, spk in zip(bands, tabs["halo"], spikes_bands)]

    # fused Pallas (interpret on CPU)
    ring_k, ev_k, dr_k = jax.jit(
        lambda r: event_delivery_banded(tiers, r, t_slot, spec.d_ring,
                                        interpret=True))(ring0)

    # XLA per-tier loop
    ring_x = ring0
    ev_x = jnp.zeros((), jnp.int32)
    for tab, spk, cap in tiers:
        ring_x, ev, dr = deliver_events(tab, spk, ring_x, t_slot,
                                        spec.d_ring, cap)
        ev_x = ev_x + ev.astype(jnp.int32)

    # pure-jnp oracle, tier by tier
    ring_r = ring0
    for tab, spk, cap in tiers:
        n_rows = tab["tgt"].shape[0] - 1
        idx, _ = compact_events(spk, n_rows, cap)
        ring_r = ref.synaptic_accum_ref(idx, t_slot, tab["tgt"], tab["w"],
                                        tab["dslot"], ring_r)

    np.testing.assert_allclose(np.asarray(ring_k), np.asarray(ring_x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ring_k), np.asarray(ring_r),
                               rtol=1e-5, atol=1e-5)
    assert int(ev_k) == int(ev_x)
    assert int(dr_k) == 0


@pytest.mark.parametrize("law_name", ["gaussian", "exponential"])
def test_zero_spike_delivery_is_identity(law_name, rng):
    """All-padding event lists (no spikes anywhere) leave the ring
    bit-identical: every entry block is skipped."""
    law = gaussian_law() if law_name == "gaussian" else exponential_law()
    spec = _dist_spec(law)
    tabs = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=1)
    ring0 = jnp.asarray(rng.normal(size=(spec.d_ring, spec.n_local)),
                        jnp.float32)
    tiers = [(tabs["local"], jnp.zeros(spec.n_local), spec.active_cap_local)]
    tiers += [(tab, jnp.zeros(b["rows"]), spec.active_cap_band(b))
              for b, tab in zip(spec.halo_bands(), tabs["halo"])]
    ring_k, ev, dr = jax.jit(
        lambda r: event_delivery_banded(tiers, r, 2, spec.d_ring,
                                        interpret=True))(ring0)
    np.testing.assert_array_equal(np.asarray(ring_k), np.asarray(ring0))
    assert int(ev) == 0 and int(dr) == 0


def test_single_tier_fused_equals_deliver_events(rng):
    """ops.synaptic_accum_events (the fused single-tier wrapper) is a
    drop-in for core.synapses.deliver_events."""
    law = gaussian_law()
    d = TileDecomposition(grid=ColumnGrid(4, 4, 30), tiles_y=1, tiles_x=1,
                          radius=law.radius)
    spec = SynapseTableSpec(decomp=d, law=law, single_shard=True)
    tabs = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=2)
    spikes = jnp.asarray((rng.random(spec.n_local) < 0.1).astype(np.float32))
    ring0 = jnp.zeros((spec.d_ring, spec.n_local), jnp.float32)
    r1, e1, d1 = jax.jit(
        lambda r: event_delivery(tabs["local"], spikes, r, 1,
                                 spec.d_ring, spec.active_cap_local,
                                 interpret=True))(ring0)
    r2, e2, d2 = deliver_events(tabs["local"], spikes, ring0, 1,
                                spec.d_ring, spec.active_cap_local)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2),
                               rtol=1e-5, atol=1e-5)
    assert int(e1) == int(e2) and int(d1) == int(d2)


def test_engine_auto_kernels_matches_xla_engine():
    """use_kernels="auto" on CPU (interpret-mode Pallas) reproduces the
    pure-XLA engine's spike trains exactly."""
    law = gaussian_law()
    d = TileDecomposition(grid=ColumnGrid(3, 3, 30), tiles_y=1, tiles_x=1,
                          radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels="auto")
    cfg_x = dataclasses.replace(cfg, use_kernels=False)
    tabs = build_shard_tables(cfg)
    _, sp_k = jax.jit(lambda s: simulate(s, tabs, cfg, 60))(init_sim_state(cfg))
    _, sp_x = jax.jit(lambda s: simulate(s, tabs, cfg_x, 60))(
        init_sim_state(cfg_x))
    np.testing.assert_array_equal(np.asarray(sp_k), np.asarray(sp_x))


def test_delivery_plan_shapes():
    """The spec's kernel-facing plan matches the materialized tables."""
    law = exponential_law()
    spec = _dist_spec(law)
    plan = spec.delivery_plan()
    tabs = build_tables(spec, 1, 1, j_exc=0.4, j_inh=-2.0, seed=0)
    tiers = [tabs["local"]] + list(tabs["halo"])
    assert len(plan) == len(tiers)
    assert plan[0].rows == spec.n_local
    assert spec.band_caps() == [p.cap for p in plan[1:]]
    for p, tab in zip(plan, tiers):
        assert tab["tgt"].shape == (p.rows + 1, p.cap)
        assert p.active_cap <= p.rows + 1
        assert p.entries == p.active_cap * p.cap
        assert p.entries_padded >= p.entries
        assert p.entries_padded % LANES == 0
    # a compressed build's realized caps ride in its storage descriptor,
    # and the plan sized from it matches the truncated tables
    from repro.core.synapses import compress_tables
    ctabs = compress_tables(tabs)
    cplan = spec.delivery_plan(ctabs.storage)
    for p, tab in zip(cplan, [ctabs["local"]] + list(ctabs["halo"])):
        assert tab["tgt"].shape == (p.rows + 1, p.cap)


def test_entry_geometry_contract():
    """The spec's lane-packed launch geometry is consistent with its
    per-tier plan and with the kernel layout constants."""
    spec = _dist_spec(exponential_law())
    plan = spec.delivery_plan()
    geo = spec.entry_geometry()
    assert geo.lanes == LANES and geo.entry_block == ENTRY_BLOCK
    assert geo.entries == sum(p.entries_padded for p in plan)
    assert geo.entries_padded % ENTRY_BLOCK == 0
    assert geo.entries_padded >= max(geo.entries, ENTRY_BLOCK)
    assert geo.n_blocks == geo.entries_padded // ENTRY_BLOCK
    assert geo.packed_shape == (geo.entries_padded // LANES, LANES)


def test_plan_mismatch_is_rejected(rng):
    """A tier that does not match its delivery plan fails loudly (the
    plan is the spec contract the engines compile against)."""
    spec = _single_spec(gaussian_law(), n_per_col=12)
    tabs = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=0)
    ring0 = jnp.zeros((spec.d_ring, spec.n_local), jnp.float32)
    tiers = [(tabs["local"], jnp.zeros(spec.n_local),
              spec.active_cap_local)]
    plan = spec.delivery_plan()
    bad = [dataclasses.replace(plan[0], cap=plan[0].cap + 1)]
    with pytest.raises(ValueError, match="does not match"):
        event_delivery_banded(tiers, ring0, 0, spec.d_ring, plan=bad,
                              interpret=True)
    with pytest.raises(ValueError, match="plan has"):
        event_delivery_banded(tiers, ring0, 0, spec.d_ring,
                              plan=plan + plan, interpret=True)
    # and the matching plan goes through the lane-packed kernel cleanly
    ring_k, _, _ = jax.jit(
        lambda r: event_delivery_banded(tiers, r, 0, spec.d_ring,
                                        plan=plan, interpret=True))(ring0)
    np.testing.assert_array_equal(np.asarray(ring_k), np.asarray(ring0))


# ---------------------------------------------------------------------------
# Lane-packed layout edge cases: ragged n_local / partial entry blocks
# ---------------------------------------------------------------------------

def _single_spec(law, grid=5, n_per_col=9, rate_cap=25.0):
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    return SynapseTableSpec(decomp=d, law=law, rate_cap_hz=rate_cap,
                            single_shard=True)


@pytest.mark.parametrize("grid,n_per_col", [
    (5, 9),     # n_local = 225: not a multiple of LANES (128)
    (10, 45),   # n_local = 4500: > TILE_N and not a multiple of it
])
def test_ragged_n_local_matches_xla_and_ref(grid, n_per_col, rng):
    """n_local that fills neither the lane dim nor the ring tiling:
    kernel vs deliver_events vs the jnp oracle, random initial ring."""
    law = gaussian_law()
    spec = _single_spec(law, grid=grid, n_per_col=n_per_col)
    tabs = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=5)
    spikes = jnp.asarray(
        (rng.random(spec.n_local) < 0.08).astype(np.float32))
    ring0 = jnp.asarray(rng.normal(size=(spec.d_ring, spec.n_local)),
                        jnp.float32)
    cap = spec.active_cap_local
    r_k, e_k, d_k = jax.jit(
        lambda r: event_delivery(tabs["local"], spikes, r, 3, spec.d_ring,
                                 cap, interpret=True))(ring0)
    r_x, e_x, d_x = deliver_events(tabs["local"], spikes, ring0, 3,
                                   spec.d_ring, cap)
    idx, _ = compact_events(spikes, spec.n_local, cap)
    r_r = ref.synaptic_accum_ref(idx, 3, tabs["local"]["tgt"],
                                 tabs["local"]["w"],
                                 tabs["local"]["dslot"], ring0)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_x),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                               rtol=1e-5, atol=1e-5)
    assert int(e_k) == int(e_x) and int(d_k) == int(d_x) == 0


def test_active_cap_overflow_drops_like_xla(rng):
    """More spiking rows than the event list holds: the kernel delivers
    the same truncated prefix as the XLA path and reports the same
    drop count."""
    law = gaussian_law()
    spec = _single_spec(law)
    tabs = build_tables(spec, 0, 0, j_exc=0.4, j_inh=-2.0, seed=7)
    spikes = jnp.asarray(
        (rng.random(spec.n_local) < 0.5).astype(np.float32))
    n_spk = int(np.asarray(spikes).sum())
    cap = max(n_spk // 3, 1)           # force overflow
    assert n_spk > cap
    ring0 = jnp.asarray(rng.normal(size=(spec.d_ring, spec.n_local)),
                        jnp.float32)
    r_k, e_k, d_k = jax.jit(
        lambda r: event_delivery(tabs["local"], spikes, r, 1, spec.d_ring,
                                 cap, interpret=True))(ring0)
    r_x, e_x, d_x = deliver_events(tabs["local"], spikes, ring0, 1,
                                   spec.d_ring, cap)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_x),
                               rtol=1e-5, atol=1e-5)
    assert int(d_k) == int(d_x) == n_spk - cap
    assert int(e_k) == int(e_x)


def test_partial_last_block_and_lane(rng):
    """Entry counts that fill neither the last lane (E % 128 != 0) nor
    the last lane-packed block (E % ENTRY_BLOCK != 0) deliver exactly;
    the trailing padding is skipped, not scattered."""
    rows, cap, d_ring, n_local = 11, 7, 4, 150
    assert (rows + 1) * cap % LANES != 0
    tgt = jnp.asarray(rng.integers(0, n_local, (rows + 1, cap)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(rows + 1, cap)), jnp.float32)
    w = w.at[-1].set(0)
    ds = jnp.asarray(rng.integers(0, d_ring, (rows + 1, cap)), jnp.int8)
    ring = jnp.asarray(rng.normal(size=(d_ring, n_local)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, rows + 1, 5), jnp.int32)
    got = synaptic_accum_pallas(idx, 2, tgt, w, ds, ring)
    want = ref.synaptic_accum_ref(idx, 2, tgt, w, ds, ring)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
