"""Tile decomposition + synapse-table invariants."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.connectivity import gaussian_law
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.synapses import (SynapseTableSpec, _pack_rows, build_tables,
                                 deliver_events, deliver_gather_all)


@given(st.integers(2, 40), st.integers(2, 40), st.integers(1, 5),
       st.integers(1, 5), st.integers(1, 10))
@settings(max_examples=60, deadline=None)
def test_tiles_cover_grid(h, w, ty, tx, radius):
    d = TileDecomposition(grid=ColumnGrid(h, w, 10), tiles_y=ty,
                          tiles_x=tx, radius=radius)
    assert d.padded_h >= h and d.padded_w >= w
    covered = np.zeros((d.padded_h, d.padded_w), dtype=int)
    active_total = 0
    for yy in range(ty):
        for xx in range(tx):
            oy, ox = d.tile_origin(yy, xx)
            covered[oy:oy + d.tile_h, ox:ox + d.tile_w] += 1
            active_total += d.active_mask(yy, xx).sum()
    assert (covered == 1).all()                  # exact partition
    assert active_total == h * w                 # every live column once
    assert d.halo_hops_y == -(-radius // d.tile_h)


def test_halo_import_volume_grows_with_radius():
    g = ColumnGrid(48, 48, 100)
    d3 = TileDecomposition(grid=g, tiles_y=8, tiles_x=8, radius=3)
    d10 = TileDecomposition(grid=g, tiles_y=8, tiles_x=8, radius=10)
    assert d10.comm_volume_per_step_bytes() > \
        2 * d3.comm_volume_per_step_bytes()


def test_pack_rows_roundtrip(rng):
    n_rows, cap = 17, 9
    m = 60
    rows = rng.integers(0, n_rows, m)
    tgts = rng.integers(0, 100, m)
    ws = rng.normal(size=m)
    ds = rng.integers(1, 7, m).astype(np.int8)
    tab, clipped = _pack_rows(n_rows, cap, rows, tgts, ws, ds, np.float32)
    assert tab["tgt"].shape == (n_rows + 1, cap)
    counts = np.bincount(rows, minlength=n_rows)
    assert clipped == np.maximum(counts - cap, 0).sum()
    assert (tab["nnz"][:-1] == np.minimum(counts, cap)).all()
    assert tab["nnz"][-1] == 0                  # sink row empty
    # every stored weight belongs to its row's input set (f32 tolerance)
    for r in range(n_rows):
        stored = np.asarray(tab["w"][r][:tab["nnz"][r]])
        allowed = ws[rows == r].astype(np.float32)
        for s in stored:
            assert np.abs(allowed - s).min() < 1e-6


def _tiny_tables(rng, n_local=40, cap=8, d_ring=4):
    rows = n_local
    tgt = rng.integers(0, n_local, (rows + 1, cap)).astype(np.int32)
    w = rng.normal(size=(rows + 1, cap)).astype(np.float32)
    nnz = rng.integers(0, cap + 1, rows + 1).astype(np.int32)
    k = np.arange(cap)[None, :]
    w = np.where(k < nnz[:, None], w, 0.0)      # pad zero like _pack_rows
    tgt = np.where(k < nnz[:, None], tgt, 0)
    ds = rng.integers(1, d_ring, (rows + 1, cap)).astype(np.int8)
    w[-1] = 0
    nnz[-1] = 0
    return {k2: jnp.asarray(v) for k2, v in
            dict(tgt=tgt, w=w, dslot=ds, nnz=nnz).items()}


def test_event_equals_gather_all(rng):
    """The paper's two delivery regimes must agree synapse-for-synapse."""
    tabs = _tiny_tables(rng)
    n_local, d_ring = 40, 4
    spikes = (rng.random(n_local) < 0.3).astype(np.float32)
    ring = rng.normal(size=(d_ring, n_local)).astype(np.float32)
    out_gather = deliver_gather_all(tabs, jnp.asarray(spikes),
                                    jnp.asarray(ring), jnp.int32(1), d_ring)
    out_event, n_ev, n_drop = deliver_events(
        tabs, jnp.asarray(spikes), jnp.asarray(ring), jnp.int32(1),
        d_ring, active_cap=n_local)
    np.testing.assert_allclose(out_gather, out_event, rtol=1e-5, atol=1e-6)
    assert int(n_drop) == 0
    nnz = np.asarray(tabs["nnz"][:n_local])
    assert int(n_ev) == int((nnz * spikes).sum())


def test_event_current_conservation(rng):
    """Sum of ring increments == sum of delivered weights (paper's
    synaptic-event bookkeeping)."""
    tabs = _tiny_tables(rng)
    n_local, d_ring = 40, 4
    spikes = (rng.random(n_local) < 0.5).astype(np.float32)
    ring0 = np.zeros((d_ring, n_local), np.float32)
    out, _, _ = deliver_events(tabs, jnp.asarray(spikes),
                               jnp.asarray(ring0), jnp.int32(0), d_ring,
                               active_cap=n_local)
    w = np.asarray(tabs["w"])[:n_local]
    expected = (w * spikes[:, None]).sum()
    assert float(jnp.sum(out)) == pytest.approx(float(expected), rel=1e-5)


def test_build_tables_counts_match_expectation(rng):
    law = gaussian_law()
    grid = ColumnGrid(6, 6, 40)
    d = TileDecomposition(grid=grid, tiles_y=2, tiles_x=2, radius=law.radius)
    spec = SynapseTableSpec(decomp=d, law=law)
    total = 0
    for ty in range(2):
        for tx in range(2):
            t = build_tables(spec, ty, tx, j_exc=0.4, j_inh=-1.6, seed=3)
            total += t["stats"]["n_synapses"]
            assert t["stats"]["clipped"] == 0
    # every shard stores local synapses + remote-with-target-in-shard:
    # totals should approximate sum over shards of expected_synapses
    exp = 4 * spec.expected_synapses()
    assert total == pytest.approx(exp, rel=0.15)


def test_band_capacity_bounds_padding():
    """Banded halo capacities keep padding bounded (Fig 3 flatness)."""
    from repro.core.connectivity import exponential_law
    law = exponential_law()
    grid = ColumnGrid(48, 48, 124)
    d = TileDecomposition(grid=grid, tiles_y=8, tiles_x=8, radius=law.radius)
    spec = SynapseTableSpec(decomp=d, law=law)
    bands = spec.halo_bands()
    assert 1 < len(bands) <= 8
    # capacities decrease geometrically from band to band
    caps = [b["cap"] for b in bands]
    assert all(c1 >= c2 for c1, c2 in zip(caps, caps[1:]))
    # bytes/synapse must stay in a sane band (paper: flat ~30 B/syn)
    bps = spec.table_bytes() / spec.expected_synapses()
    assert bps < 40.0
