"""The one-launch plastic step (kernels/plastic_step.py).

Contract under test: with kernels enabled and the shard inside the
resident-ring envelope, ``plastic_delivery_stdp`` applies delivery AND
the LTD weight update in a single Pallas launch -- and that launch is
*bit-identical* to both fallbacks (the kernel-delivery + XLA
``stdp_step`` two-pass, and the pure-XLA reference), on regular and
ragged tile sizes, with and without spikes.  Routing is a pure perf
decision, never a numerics one.
"""

import dataclasses

import jax
import numpy as np
import pytest

import repro.kernels.ops as kops
import repro.kernels.plastic_step as ps
from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_plasticity, init_sim_state,
                               simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.stdp import STDPParams


def _cfg(law="gaussian", grid=4, n_per_col=10, seed=3, **kw):
    law_ = gaussian_law() if law == "gaussian" else exponential_law()
    dec = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                            tiles_y=1, tiles_x=1, radius=law_.radius)
    return EngineConfig(decomp=dec, law=law_, seed=seed,
                        stdp=STDPParams(), **kw)


def _run(cfg, steps, tabs=None):
    tabs = build_shard_tables(cfg) if tabs is None else tabs
    aux = init_plasticity(tabs, cfg)
    (st, tabs1, traces), per = jax.jit(
        lambda s, t: simulate(s, t, cfg, steps, plasticity=aux))(
            init_sim_state(cfg), tabs)
    return st, tabs1, traces, np.asarray(per)


def _assert_same(a, b):
    sa, ta, ra, pa = a
    sb, tb, rb, pb = b
    np.testing.assert_array_equal(pa, pb)
    np.testing.assert_array_equal(np.asarray(ta["local"]["w"]),
                                  np.asarray(tb["local"]["w"]))
    for k in ("x_post",):
        np.testing.assert_array_equal(np.asarray(ra[k]), np.asarray(rb[k]))
    np.testing.assert_array_equal(np.asarray(ra["x_pre"][0]),
                                  np.asarray(rb["x_pre"][0]))
    for k in ("v", "c", "refrac"):
        np.testing.assert_array_equal(np.asarray(sa["neuron"][k]),
                                      np.asarray(sb["neuron"][k]))
    np.testing.assert_array_equal(np.asarray(sa["i_ring"]),
                                  np.asarray(sb["i_ring"]))
    for k in ("events", "dropped", "spikes"):
        np.testing.assert_array_equal(np.asarray(sa["metrics"][k]),
                                      np.asarray(sb["metrics"][k]))


@pytest.mark.parametrize("law", ["gaussian", "exponential"])
def test_fused_bit_identical_to_twopass_and_xla(law, monkeypatch):
    """Fused one-launch vs two-pass-with-kernel-delivery vs pure XLA:
    all three produce bitwise the same weights, traces, neuron state
    and metrics over a window where plasticity actually fires."""
    steps = 48
    cfg = _cfg(law)
    assert cfg.kernels_enabled and ps.fused_supported(cfg.spec().n_local)
    fused = _run(cfg, steps)
    with monkeypatch.context() as m:
        m.setattr(ps, "RING_N_MAX", 0)       # routes the two-pass path
        twopass = _run(cfg, steps)
    xla = _run(dataclasses.replace(cfg, use_kernels=False), steps)
    assert fused[3].sum() > 0                # the run spiked
    _assert_same(fused, twopass)
    _assert_same(fused, xla)


def test_fused_bit_identical_on_ragged_tiles():
    """5x5x9: n_local = 225 is lane- and sublane-ragged (pads to
    N_ALIGN inside the kernel, entry stream pads per tier) -- identity
    must survive the padding."""
    steps = 48
    cfg = _cfg(grid=5, n_per_col=9)
    assert cfg.spec().n_local % 128 != 0
    fused = _run(cfg, steps)
    xla = _run(dataclasses.replace(cfg, use_kernels=False), steps)
    assert fused[3].sum() > 0
    _assert_same(fused, xla)


def test_zero_spike_window_is_identity():
    """Before the first spike (~step 34 at this scale/seed) the plastic
    step must be a bitwise no-op on the weights: no events, traces
    stay zero, and the fused path agrees with XLA on all of it."""
    steps = 10
    cfg = _cfg()
    tabs = build_shard_tables(cfg)
    fused = _run(cfg, steps, tabs=tabs)
    xla = _run(dataclasses.replace(cfg, use_kernels=False), steps,
               tabs=tabs)
    assert fused[3].sum() == 0
    np.testing.assert_array_equal(np.asarray(fused[1]["local"]["w"]),
                                  np.asarray(tabs["local"]["w"]))
    assert float(np.abs(np.asarray(fused[2]["x_post"])).sum()) == 0.0
    assert float(np.asarray(fused[0]["metrics"]["events"])) == 0.0
    _assert_same(fused, xla)


def test_ring_n_max_routes_to_fallback(monkeypatch):
    """``fused_supported`` is the routing predicate: under the envelope
    the fused kernel launches; past it (RING_N_MAX forced to 0) the
    two-pass fallback runs and the fused kernel is never invoked."""
    cfg = _cfg()
    calls = {"fused": 0}
    real = kops.plastic_step_banded

    def spy(*a, **kw):
        calls["fused"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(kops, "plastic_step_banded", spy)
    _run(cfg, 2)
    assert calls["fused"] > 0

    calls["fused"] = 0
    with monkeypatch.context() as m:
        m.setattr(ps, "RING_N_MAX", 0)
        assert not ps.fused_supported(cfg.spec().n_local)
        _run(cfg, 2)
    assert calls["fused"] == 0


def test_fused_supported_envelope():
    """The predicate mirrors the kernel's own resident-ring guard
    (n_local padded to N_ALIGN vs RING_N_MAX)."""
    assert ps.fused_supported(1)
    assert ps.fused_supported(ps.RING_N_MAX)
    assert not ps.fused_supported(ps.RING_N_MAX + 1)
    # the committed A/B config sits inside the envelope
    assert ps.fused_supported(8 * 8 * 60)
