"""Optimizers, schedules, compression, data pipeline, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: see tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.checkpoint.store import (AsyncCheckpointer, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.data.pipeline import LMBatchPipeline
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adafactor, adamw, int8_dequantize, int8_quantize
from repro.optim.compression import BLOCK, init_residuals
from repro.optim.schedules import constant, warmup_cosine, warmup_rsqrt


def _quad_problem():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = x @ w_true
    loss = lambda p: jnp.mean((x @ p["w"] - y) ** 2)
    return loss


@pytest.mark.parametrize("make_opt,iters,frac", [
    (lambda: adamw(constant(0.05), weight_decay=0.0), 300, 0.1),
    (lambda: adafactor(constant(0.3)), 500, 0.1),
])
def test_optimizers_converge(make_opt, iters, frac):
    loss = _quad_problem()
    opt = make_opt()
    params = {"w": jnp.zeros((16, 8), jnp.float32)}
    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: opt.update(p, jax.grad(loss)(p), s))
    for _ in range(iters):
        params, state, gnorm = step(params, state)
    assert float(loss(params)) < frac * l0
    assert np.isfinite(float(gnorm))


def test_adamw_state_specs_structure():
    opt = adamw(constant(1e-3))
    specs = {"a": ("fsdp", "mlp"), "b": (None,)}
    ss = opt.state_specs(specs)
    assert ss["m"] == specs and ss["v"] == specs and ss["step"] == ()


def test_adafactor_state_specs_factored():
    opt = adafactor(constant(1e-3))
    ss = opt.state_specs({"w": ("stack", "experts", "fsdp", "mlp")})
    assert ss["v"]["w"]["row"] == ("stack", "experts", "fsdp")
    assert ss["v"]["w"]["col"] == ("stack", "experts", "mlp")


def test_schedules():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)
    r = warmup_rsqrt(1.0, 16)
    assert float(r(jnp.int32(16))) == pytest.approx(1.0, rel=1e-3)
    assert float(r(jnp.int32(64))) == pytest.approx(0.5, rel=1e-2)


@given(st.integers(1, 3000), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * 10, jnp.float32)
    q, scale = int8_quantize(x)
    back = int8_dequantize(q, scale, x.shape)
    blocks = np.asarray(jnp.pad(x, (0, -n % BLOCK)).reshape(-1, BLOCK))
    bound = np.repeat(np.abs(blocks).max(-1) / 127.0 / 2, BLOCK)[:n]
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound + 1e-6).all()


@pytest.mark.slow
def test_error_feedback_compression_converges():
    """int8+EF SGD reaches the same optimum as exact SGD (the property
    that justifies the cross-pod compressed all-reduce)."""
    loss = _quad_problem()
    params = {"w": jnp.zeros((16, 8), jnp.float32)}
    resid = init_residuals(params)
    lr = 0.1
    for _ in range(800):
        g = jax.grad(loss)(params)
        new_r = {}
        for k in g:
            q, s = int8_quantize(g[k] + resid[k])
            sent = int8_dequantize(q, s, g[k].shape)
            new_r[k] = g[k] + resid[k] - sent
            params[k] = params[k] - lr * sent
        resid = new_r
    assert float(loss(params)) < 1e-3


def test_pipeline_determinism_and_shift():
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                      n_heads=1, n_kv_heads=1, d_ff=8, vocab_size=97)
    shape = ShapeConfig("t", 32, 4, "train")
    a = LMBatchPipeline(cfg=cfg, shape=shape, seed=7).batch(3)
    b = LMBatchPipeline(cfg=cfg, shape=shape, seed=7).batch(3)
    c = LMBatchPipeline(cfg=cfg, shape=shape, seed=7).batch(4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert a["tokens"].max() < 97


def test_checkpoint_roundtrip_and_gc(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32),
            "b": [jnp.arange(3), {"c": jnp.asarray(2.5)}]}
    d = str(tmp_path)
    for step in (1, 2, 3, 4):
        save_checkpoint(d, step, tree, keep=2)
    assert latest_step(d) == 4
    assert sorted(os.listdir(d)) == ["step_00000003", "step_00000004"]
    out = restore_checkpoint(d, 4, jax.eval_shape(lambda: tree))
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_detects_corruption(tmp_path, rng):
    tree = {"a": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    d = str(tmp_path)
    path = save_checkpoint(d, 1, tree)
    fn = os.path.join(path, "leaf_00000.npy")
    blob = bytearray(open(fn, "rb").read())
    blob[-1] ^= 0xFF
    open(fn, "wb").write(bytes(blob))
    with pytest.raises(IOError):
        restore_checkpoint(d, 1, tree)


def test_checkpoint_rejects_dtype_drift(tmp_path):
    """A leaf whose on-disk dtype differs from the expected one must be
    refused -- silently restoring it would recompile or corrupt the
    jitted step (regression: restore used to check shapes only)."""
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((4,), jnp.float32)})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(str(tmp_path), 1,
                           {"a": jax.ShapeDtypeStruct((4,), jnp.float16)})
    # same shapes, same dtypes: fine
    out = restore_checkpoint(str(tmp_path), 1,
                             {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert np.asarray(out["a"]).dtype == np.float32


def test_checkpoint_structure_mismatch(tmp_path):
    tree = {"a": jnp.zeros((3,))}
    save_checkpoint(str(tmp_path), 1, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3,)),
                                              "b": jnp.zeros((2,))})


def test_async_checkpointer(tmp_path, rng):
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    tree = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    for s in (10, 20):
        ck.save(s, tree)
    ck.close()
    assert latest_step(str(tmp_path)) == 20


def test_async_checkpointer_snapshot_immutable(tmp_path, rng):
    """The double-buffered handoff contract: ``save`` snapshots on
    device and returns before the D2H transfer, so the caller is free
    to *donate* the live tree to its next jitted segment immediately.
    The written checkpoint must hold the values at save time, not
    whatever the donated buffer was overwritten with."""
    ck = AsyncCheckpointer(str(tmp_path), keep=3)
    w0 = np.asarray(rng.normal(size=(64,)), np.float32)
    tree = {"w": jnp.asarray(w0)}
    ck.save(1, tree)
    # donate the source buffer to a segment that clobbers it in place
    clobber = jax.jit(lambda t: jax.tree.map(lambda x: x * 0 - 1.0, t),
                      donate_argnums=0)
    tree = clobber(tree)
    jax.block_until_ready(tree["w"])
    ck.close()
    out = restore_checkpoint(str(tmp_path), 1,
                             {"w": jnp.zeros((64,), jnp.float32)})
    np.testing.assert_array_equal(np.asarray(out["w"]), w0)
    np.testing.assert_array_equal(np.asarray(tree["w"]), -1.0)
