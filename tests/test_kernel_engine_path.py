"""The Pallas-kernel-backed engine path is a drop-in: identical spikes."""

import dataclasses

import jax
import numpy as np

from repro.core.connectivity import gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition


def test_kernel_engine_matches_jnp_engine():
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(3, 3, 30), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=dec, law=law)
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    tabs = build_shard_tables(cfg)
    _, sp1 = jax.jit(lambda s: simulate(s, tabs, cfg, 50))(init_sim_state(cfg))
    _, sp2 = jax.jit(lambda s: simulate(s, tabs, cfg_k, 50))(
        init_sim_state(cfg_k))
    np.testing.assert_array_equal(np.asarray(sp1), np.asarray(sp2))
