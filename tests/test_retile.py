"""Elastic re-tiling: exact state permutation by global column id.

Pure host-side checks (no multi-device mesh needed): every neuron's
(v, c, refrac), its active flag, and every in-flight delay-ring current
must land at the correct new (tile, local-index) for its global column
id; ``t`` is preserved and the per-tile metrics restart at zero (the
cumulative totals travel as global scalars in the checkpoint manifest,
driven by SimDriver -- see test_sim_driver.py).
"""

import numpy as np
import pytest

from repro.core.connectivity import gaussian_law
from repro.core.dist_engine import DistConfig, init_dist_state
from repro.core.engine import EngineConfig
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.retile import (global_column_ids, neuron_gather_map,
                               retile_config, retile_state)

# grid 3x3 does not divide either tiling evenly -> both layouts carry
# padded columns, exercising the -1 (no source neuron) paths
H, W, NPC = 3, 3, 4


def _cfg(ty, tx):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(H, W, NPC), tiles_y=ty,
                            tiles_x=tx, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=2))


def _global_neuron_ids(dec):
    """(TY, TX, n_local) global neuron id, -1 on padded slots."""
    gid = global_column_ids(dec)
    gnid = gid[..., None] * NPC + np.arange(NPC)
    return np.where(gid[..., None] >= 0, gnid, -1).reshape(
        dec.tiles_y, dec.tiles_x, dec.n_local)


def _patterned_state(cfg, t=5):
    """State whose every leaf encodes the global neuron id it belongs to."""
    dec = cfg.engine.decomp
    st = {k: np.asarray(v) if not isinstance(v, dict)
          else {kk: np.asarray(vv) for kk, vv in v.items()}
          for k, v in init_dist_state(cfg).items()}
    gnid = _global_neuron_ids(dec)
    valid = gnid >= 0
    st["neuron"]["v"] = np.where(valid, gnid, 0).astype(np.float32)
    st["neuron"]["c"] = np.where(valid, gnid + 0.25, 0).astype(np.float32)
    st["neuron"]["refrac"] = np.where(valid, gnid % 5, 0).astype(np.int32)
    d_ring = st["i_ring"].shape[2]
    slots = np.arange(d_ring)[None, None, :, None]
    ring = 1000.0 * slots + gnid[:, :, None, :]
    st["i_ring"] = np.where(valid[:, :, None, :], ring, 0.0).astype(
        np.float32)
    st["t"] = np.full(st["t"].shape, t, np.int32)
    st["metrics"] = {
        "spikes": np.arange(1, valid.shape[0] * valid.shape[1] + 1,
                            dtype=np.float32).reshape(valid.shape[:2]),
        "events": np.full(valid.shape[:2], 2.5, np.float32),
        "dropped": np.zeros(valid.shape[:2], np.float32),
    }
    return st


def test_gather_map_is_bijection_on_logical_neurons():
    old, new = _cfg(1, 2).engine.decomp, _cfg(2, 1).engine.decomp
    src = neuron_gather_map(old, new)
    taken = np.sort(src[src >= 0])
    # every logical neuron of the old layout appears exactly once
    gnid_old = _global_neuron_ids(old).reshape(-1)
    want = np.sort(np.where(gnid_old >= 0)[0])
    np.testing.assert_array_equal(taken, want)


@pytest.mark.parametrize("old_tiles,new_tiles", [((1, 2), (2, 1)),
                                                 ((2, 1), (1, 2))])
def test_retile_places_state_by_global_column_id(old_tiles, new_tiles):
    old_cfg, new_cfg = _cfg(*old_tiles), _cfg(*new_tiles)
    old_d, new_d = old_cfg.engine.decomp, new_cfg.engine.decomp
    st = _patterned_state(old_cfg, t=5)
    out = retile_state(st, old_d, new_d)

    gnid = _global_neuron_ids(new_d)
    valid = gnid >= 0
    np.testing.assert_array_equal(
        np.asarray(out["neuron"]["v"]),
        np.where(valid, gnid, 0).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(out["neuron"]["c"]),
        np.where(valid, gnid + 0.25, 0).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(out["neuron"]["refrac"]),
        np.where(valid, gnid % 5, 0).astype(np.int32))
    # delay ring: each in-flight current moved with its target neuron,
    # slot axis untouched
    d_ring = np.asarray(out["i_ring"]).shape[2]
    slots = np.arange(d_ring)[None, None, :, None]
    want_ring = np.where(valid[:, :, None, :],
                         1000.0 * slots + gnid[:, :, None, :], 0.0)
    np.testing.assert_array_equal(np.asarray(out["i_ring"]),
                                  want_ring.astype(np.float32))
    # t preserved (so t % d_ring slot alignment survives)
    assert np.asarray(out["t"]).shape == (new_d.tiles_y, new_d.tiles_x)
    np.testing.assert_array_equal(np.asarray(out["t"]), 5)
    # active mask equals the new decomposition's own mask
    want_active = np.stack([
        np.stack([np.repeat(new_d.active_mask(y, x).ravel(), NPC)
                  for x in range(new_d.tiles_x)])
        for y in range(new_d.tiles_y)])
    np.testing.assert_array_equal(np.asarray(out["active"]), want_active)
    # metrics restart at zero on every tile: cumulative totals are
    # global scalars (checkpoint manifest), not relayout-able per-tile
    # state -- parking history on an arbitrary tile made per-tile
    # metric reads tiling-dependent
    for k in ("spikes", "events", "dropped"):
        arr = np.asarray(out["metrics"][k])
        np.testing.assert_array_equal(arr, np.zeros_like(arr))
        assert arr.dtype == st["metrics"][k].dtype
    # dtypes survive the relayout (would otherwise poison the jitted step)
    for name, leaf in (("v", out["neuron"]["v"]),
                       ("refrac", out["neuron"]["refrac"]),
                       ("t", out["t"]), ("i_ring", out["i_ring"])):
        assert np.asarray(leaf).dtype == np.asarray(
            st["neuron"][name] if name in ("v", "refrac")
            else st[name]).dtype, name


def test_retile_identity_roundtrip():
    """1x2 -> 2x1 -> 1x2 restores the exact original neuron state."""
    a, b = _cfg(1, 2), _cfg(2, 1)
    st = _patterned_state(a, t=7)
    back = retile_state(
        retile_state(st, a.engine.decomp, b.engine.decomp),
        b.engine.decomp, a.engine.decomp)
    for k in ("v", "c", "refrac"):
        np.testing.assert_array_equal(np.asarray(back["neuron"][k]),
                                      st["neuron"][k])
    np.testing.assert_array_equal(np.asarray(back["i_ring"]), st["i_ring"])
    np.testing.assert_array_equal(np.asarray(back["active"]), st["active"])


def test_retile_config_keeps_everything_but_tiles():
    cfg = _cfg(1, 2)
    new = retile_config(cfg, 2, 1)
    assert new.tiles == (2, 1)
    assert new.engine.decomp.grid == cfg.engine.decomp.grid
    assert new.engine.seed == cfg.engine.seed
    assert new.engine.law == cfg.engine.law


def test_gather_map_rejects_grid_mismatch():
    law = gaussian_law()
    a = TileDecomposition(grid=ColumnGrid(3, 3, 4), tiles_y=1, tiles_x=2,
                          radius=law.radius)
    b = TileDecomposition(grid=ColumnGrid(4, 3, 4), tiles_y=2, tiles_x=1,
                          radius=law.radius)
    with pytest.raises(ValueError, match="grid"):
        neuron_gather_map(a, b)
