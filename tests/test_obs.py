"""Spike observatory: device-side recording, spool contract, analysis.

The spool contract under test (ISSUE 4): zero-spike segments leave
valid empty logs; resume-after-preemption (and failure replay) yields
exactly-once events, bit-compared against an unpreempted run; and
recording on/off leaves the engine spike trains bit-identical.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.dist_engine import DistConfig
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.kernels.spike_compact import spike_compact_pallas
from repro.kernels.synaptic_accum import compact_events
from repro.obs.analysis import (analyze_run, compare_runs, ks_statistic,
                                updown_segmentation)
from repro.obs.record import recorder_spec, stacked_gid_maps, tile_gid_map
from repro.obs.spool import (SpikeSpooler, load_events, read_header,
                             shard_events)
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver

N = 40


def _dist_cfg(seed=3, **engine_kw):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=seed,
                                          **engine_kw))


def _driver(ckpt_dir, seg, dist=None, **kw):
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir),
                       ckpt_every=kw.pop("ckpt_every", 1),
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, dist or _dist_cfg(), mesh, segment_steps=seg,
                     **kw)


# ---------------------------------------------------------------------------
# Device-side recorder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,cap,p", [(160, 40, 0.1), (160, 40, 0.9),
                                     (7, 7, 0.5), (1024, 16, 0.3),
                                     (2000, 600, 0.0), (513, 520, 1.0),
                                     (3840, 3104, 0.05)])
def test_spike_compact_kernel_matches_xla(n, cap, p):
    """The Pallas compaction kernel is bit-identical to the XLA
    ``compact_events`` fallback: ascending indices, sink padding, and
    the uncapped spike count."""
    rng = np.random.default_rng(n + cap)
    spk = jnp.asarray((rng.random(n) < p).astype(np.float32))
    i_x, c_x = compact_events(spk, n, cap)
    i_k, c_k = spike_compact_pallas(spk, n, cap, interpret=True)
    assert int(c_x) == int(c_k)
    np.testing.assert_array_equal(np.asarray(i_x), np.asarray(i_k))


def test_gid_map_is_global_and_tiling_invariant():
    """Each local slot maps to its global neuron id; padded slots and
    the compaction sink map to -1; the union over tiles covers every
    logical neuron exactly once, whatever the tiling."""
    law = gaussian_law()
    grid = ColumnGrid(3, 3, 4)          # 3x3 does not divide 2 -> padding
    seen = {}
    for ty, tx in [(1, 1), (1, 2), (2, 2)]:
        dec = TileDecomposition(grid=grid, tiles_y=ty, tiles_x=tx,
                                radius=law.radius)
        g = stacked_gid_maps(dec)
        assert g.shape == (ty, tx, dec.n_local + 1)
        assert (g[..., -1] == -1).all()
        live = g[..., :-1][g[..., :-1] >= 0]
        np.testing.assert_array_equal(np.sort(live),
                                      np.arange(grid.n_neurons))
        seen[(ty, tx)] = np.sort(live)
    assert all((v == seen[(1, 1)]).all() for v in seen.values())


def test_recording_is_pure_observer(tmp_path):
    """Recording on/off leaves the engine dynamics (the full final
    state) bit-identical -- the recorder is an observer, not a
    participant -- and the spool's per-step counts (the driver's only
    per-step record, via ``spike_counts``) match the raw logs and the
    non-recording run's cumulative totals exactly."""
    off = _driver(tmp_path / "off", seg=10)
    out_off = off.run(N)
    on = _driver(tmp_path / "on", seg=10, record_events=True)
    out_on = on.run(N)
    for a, b in zip(jax.tree.leaves(out_off["state"]),
                    jax.tree.leaves(out_on["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    counts = on.spike_counts(N)
    assert counts.shape == (N,) and counts.sum() > 0
    assert counts.sum() == float(
        np.asarray(jnp.sum(out_off["state"]["metrics"]["spikes"])))
    # spike_counts without recording is an error now, not a stale dict
    with pytest.raises(ValueError, match="record_events"):
        off.spike_counts()
    # and the spooled log agrees with the per-step counts exactly
    on.spool.close()
    ev = load_events(str(tmp_path / "on"))
    assert len(ev) == int(counts.sum())
    np.testing.assert_array_equal(
        np.bincount(ev["step"], minlength=N).astype(np.float32), counts)


def test_single_shard_run_records_events():
    cfg = _dist_cfg().engine
    tabs = build_shard_tables(cfg)
    rspec = recorder_spec(cfg, N)
    st, per_step, rec = jax.jit(
        lambda s: simulate(s, tabs, cfg, N, recorder=rspec))(init_sim_state(cfg))
    cnt = int(rec["count"])
    assert cnt == int(np.asarray(per_step).sum())
    assert int(rec["dropped"]) == 0
    gids = np.asarray(rec["gid"][:cnt])
    steps = np.asarray(rec["step"][:cnt])
    assert (np.diff(steps) >= 0).all()
    assert gids.min() >= 0 and gids.max() < cfg.decomp.grid.n_neurons
    # every recorded gid names a real (non-padded) neuron slot
    gmap = tile_gid_map(cfg.decomp, 0, 0)
    assert set(gids).issubset(set(gmap[gmap >= 0]))


# ---------------------------------------------------------------------------
# Spool contract
# ---------------------------------------------------------------------------

def test_zero_spike_segments_produce_valid_empty_logs(tmp_path):
    """No drive -> no spikes: the spool still holds a valid header and
    (empty) shard logs, and the analysis pipeline handles them."""
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    dist = DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3,
                                          ext_rate_hz=0.0))
    d = _driver(tmp_path, seg=10, dist=dist, record_events=True)
    out = d.run(20)
    d.spool.close()
    assert float(np.asarray(jnp.sum(out["state"]["metrics"]["spikes"]))) == 0
    shards = shard_events(str(tmp_path))
    assert list(shards) == ["events_000_000.spk"]
    assert len(shards["events_000_000.spk"]) == 0
    assert read_header(str(tmp_path))["law"] == "gaussian"
    rep = analyze_run(str(tmp_path))
    assert rep["n_events"] == 0 and rep["t_steps"] == 20
    assert rep["rates"]["mean_hz"] == 0.0
    assert rep["population"]["updown"]["regime"] == "silent"


def test_spool_exactly_once_after_preemption(tmp_path):
    """A run preempted mid-way and resumed spools logs identical (after
    (step, gid) ordering) to an unpreempted run's."""
    straight = _driver(tmp_path / "a", seg=10, record_events=True)
    straight.run(N)
    straight.spool.close()

    d1 = _driver(tmp_path / "b", seg=10, record_events=True,
                 preempt_after_segments=2)
    out1 = d1.run(N)
    assert out1["preempted"]
    d1.spool.close()
    d2 = _driver(tmp_path / "b", seg=10, record_events=True)
    out2 = d2.run(N)
    assert out2["final_step"] == N
    d2.spool.close()

    ev_a = load_events(str(tmp_path / "a"))
    ev_b = load_events(str(tmp_path / "b"))
    assert len(ev_a) > 0
    np.testing.assert_array_equal(ev_a, ev_b)      # byte-identical stream


def test_spool_exactly_once_after_failure_replay(tmp_path):
    """A segment failure after un-checkpointed (but already spooled)
    segments rewinds the logs to the checkpoint frontier before
    replaying: each event lands exactly once."""
    straight = _driver(tmp_path / "ref", seg=10, record_events=True)
    straight.run(N)
    straight.spool.close()

    fired = []

    def hook(step):
        if step == 30 and not fired:
            fired.append(step)
            raise RuntimeError("injected failure after unsaved segment")

    d = _driver(tmp_path / "x", seg=10, ckpt_every=2, record_events=True,
                fault_hook=hook)
    out = d.run(N)
    assert fired == [30] and out["final_step"] == N
    d.spool.close()
    np.testing.assert_array_equal(load_events(str(tmp_path / "ref")),
                                  load_events(str(tmp_path / "x")))


def test_recorder_overflow_is_counted_not_silent(tmp_path):
    """An undersized event buffer drops the excess spikes and says so:
    the spooled logs keep the per-segment prefix, and the drop counter
    surfaces through the driver."""
    full = _driver(tmp_path / "full", seg=10, record_events=True)
    full.run(N)
    full.spool.close()
    n_total = len(load_events(str(tmp_path / "full")))
    assert n_total > 2

    tiny = _driver(tmp_path / "tiny", seg=10, record_events=True,
                   record_capacity=1)
    tiny.run(N)
    tiny.spool.close()
    ev = load_events(str(tmp_path / "tiny"))
    assert len(ev) <= 4                      # <= capacity x segments
    assert tiny.recorder_dropped == n_total - len(ev)
    # drops ride the checkpoint manifest too (resume keeps the count)
    again = _driver(tmp_path / "tiny", seg=10, record_events=True,
                    record_capacity=1)
    start, _ = again._restore_or_init()
    assert start == N and again.recorder_dropped == tiny.recorder_dropped


def test_spooler_refuses_foreign_header(tmp_path):
    """A spool directory left behind by a different model is refused,
    not silently appended to (analysis normalizes by the header's
    n_neurons -- mixing models would poison every rate)."""
    sp = SpikeSpooler(str(tmp_path), (1, 1),
                      header={"n_neurons": 160, "law": "gaussian"})
    sp.close()
    # same model: fine (resume path)
    SpikeSpooler(str(tmp_path), (1, 1),
                 header={"n_neurons": 160, "law": "gaussian"}).close()
    with pytest.raises(ValueError, match="different model"):
        SpikeSpooler(str(tmp_path), (1, 1),
                     header={"n_neurons": 3840, "law": "gaussian"})


def test_spooler_truncate_rejects_tampered_logs(tmp_path):
    sp = SpikeSpooler(str(tmp_path), (1, 1), header={"n_neurons": 4})
    sp.append(0, 0, np.asarray([1, 2]), np.asarray([3, 0]))
    sp.wait()
    os.truncate(tmp_path / "events_000_000.spk", 0)
    with pytest.raises(IOError, match="truncated or deleted"):
        sp.truncate({"events_000_000.spk": 2})
    sp.close()


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------

def _write_synthetic(directory, events, n_neurons=8, dt_ms=1.0):
    sp = SpikeSpooler(str(directory), (1, 1),
                      header={"grid": [2, 2, n_neurons // 4],
                              "law": "gaussian", "seed": 0,
                              "dt_ms": dt_ms, "n_neurons": n_neurons})
    steps = np.asarray([e[0] for e in events], np.int32)
    gids = np.asarray([e[1] for e in events], np.int32)
    sp.append(0, 0, steps, gids)
    sp.close()


def test_analysis_statistics_on_synthetic_events(tmp_path):
    """Known spike trains -> exact statistics: a perfectly regular
    train has ISI CV 0; rates are counts / duration; a square-wave
    population alternation segments into Up/Down states."""
    # neuron 0: every 10 steps (regular); neuron 1: two spikes;
    # steps 0-49 active, 50-99 silent, 100-149 active (square wave)
    ev = [(t, 0) for t in range(0, 150, 10)]
    ev += [(5, 1), (25, 1)]
    burst = [(t, g) for t in list(range(0, 50, 2)) + list(range(100, 150, 2))
             for g in (2, 3, 4)]
    ev += burst
    _write_synthetic(tmp_path, sorted(ev), n_neurons=8)
    rep = analyze_run(str(tmp_path), t_steps=150, bin_steps=5)
    assert rep["n_events"] == len(ev)
    # neuron 0 fired 15 times in 0.15 s -> 100 Hz
    rates = rep["_neuron_rates"]
    assert rates[0] == pytest.approx(100.0)
    assert rates[1] == pytest.approx(2 / 0.15)
    assert rep["isi"]["n_excluded"] >= 1        # neuron 1: only 2 spikes
    assert rep["isi"]["n_neurons"] == 4
    # the regular neuron pins the low percentile near 0 (its CV is 0);
    # the bursty neurons push the mean well above it
    assert rep["isi"]["p05"] < 0.5 < rep["isi"]["mean_cv"]
    ud = rep["population"]["updown"]
    assert ud["regime"] == "slow_wave_like"
    assert ud["n_down_periods"] >= 1 and ud["n_up_periods"] >= 2
    assert 0.3 < ud["up_fraction"] < 0.9


def test_ks_statistic_separates_distinct_distributions():
    rng = np.random.default_rng(0)
    a = rng.normal(8.0, 1.0, 400)
    same = rng.normal(8.0, 1.0, 400)
    b = rng.normal(35.0, 5.0, 400)
    assert ks_statistic(a, b) > 0.9
    assert ks_statistic(a, same) < 0.2
    assert ks_statistic(a, a) == 0.0


def test_updown_silent_and_awake_edges():
    assert updown_segmentation(np.zeros(50))["regime"] == "silent"
    steady = np.full(100, 10.0) + np.linspace(0, 0.1, 100)
    assert updown_segmentation(steady)["regime"] == "awake_like"


@pytest.mark.slow
def test_analyze_reports_rate_separation_direction(tmp_path):
    """Acceptance: at 8x8x60 / 300 steps the analyze pipeline reports a
    higher mean firing rate and a distinct per-neuron rate distribution
    for the exponential law vs Gaussian -- same direction as
    test_engine.py::test_rate_separation_exponential_vs_gaussian, but
    measured from the spooled logs instead of engine counters."""
    reports = {}
    for name, law in [("gauss", gaussian_law()),
                      ("expo", exponential_law())]:
        dec = TileDecomposition(grid=ColumnGrid(8, 8, 60), tiles_y=1,
                                tiles_x=1, radius=law.radius)
        cfg = EngineConfig(decomp=dec, law=law, use_kernels=False)
        tabs = build_shard_tables(cfg)
        rspec = recorder_spec(cfg, 300)
        st, _, rec = jax.jit(
            lambda s, c=cfg, t=tabs, r=rspec: simulate(s, t, c, 300,
                                                    recorder=r))(
            init_sim_state(cfg))
        cnt = int(rec["count"])
        assert int(rec["dropped"]) == 0
        d = tmp_path / name
        sp = SpikeSpooler(str(d), (1, 1),
                          header={"grid": [8, 8, 60], "law": law.kind,
                                  "seed": 0, "dt_ms": cfg.lif.dt_ms,
                                  "n_neurons": dec.grid.n_neurons})
        sp.append(0, 0, np.asarray(rec["step"][:cnt]),
                  np.asarray(rec["gid"][:cnt]))
        sp.close()
        reports[name] = analyze_run(str(d), t_steps=300)
    cmp = compare_runs(reports)
    pair = cmp["pairs"]["gauss_vs_expo"]
    assert reports["expo"]["mean_rate_hz"] > \
        1.4 * reports["gauss"]["mean_rate_hz"], cmp["mean_rate_hz"]
    assert pair["rate_ks_statistic"] > 0.3       # distinct distributions


# ---------------------------------------------------------------------------
# Retile metric carry (satellite: totals as manifest global scalars)
# ---------------------------------------------------------------------------

def test_retile_resume_carries_metric_totals_in_manifest(tmp_path):
    """After an elastic retile the per-tile state metrics restart at
    zero; the history travels as global scalars in the manifest and the
    driver's reported totals are tiling-independent."""
    from repro.checkpoint.store import checkpoint_meta

    d1 = _driver(tmp_path, seg=10)
    out1 = d1.run(N)
    totals1 = d1.metric_totals(out1["state"])
    assert totals1["spikes"] > 0
    meta = checkpoint_meta(str(tmp_path), N)
    assert meta["metric_base"] == {"spikes": 0.0, "events": 0.0,
                                   "dropped": 0.0}
    assert meta["metric_totals"] == totals1

    # resume the 1x1 checkpoint on a 2x1 tiling (host-side relayout;
    # the 1-device mesh partially replicates -- fine for restore-only)
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=2,
                            tiles_x=1, radius=law.radius)
    dist = DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=3))
    d2 = _driver(tmp_path, seg=10, dist=dist, allow_retile=True)
    start, state = d2._restore_or_init()
    assert start == N
    # state metrics zeroed on every tile; base holds the history
    for k in ("spikes", "events", "dropped"):
        assert float(np.asarray(jnp.sum(state["metrics"][k]))) == 0.0
    assert d2.metric_totals(state) == totals1
    assert d2.firing_rate_hz(state) == pytest.approx(
        totals1["spikes"] / 160 / (N * 1e-3))
    # the next checkpoint's manifest publishes the carried base
    d2._save(N, state)
    d2.ckpt.wait()
    meta2 = checkpoint_meta(str(tmp_path), N)
    assert meta2["metric_base"] == totals1
    assert meta2["metric_totals"] == totals1
