"""Ensemble-batched simulation: member m of an N-member ensemble is
bit-identical to the solo run with ``state_seed=seeds[m]`` -- spike
trains, spool bytes, plastic checksums -- and the whole ensemble goes
through ONE compiled segment function."""

import dataclasses
import hashlib
import os

import jax
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.dist_engine import DistConfig
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_ensemble_state, init_plasticity,
                               init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.stdp import STDPParams
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver
from repro.obs.spool import member_name

SEEDS = (0, 7, 13)
N = 40


def _cfg(law, seed=3, state_seed=None, stdp=None):
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    return EngineConfig(decomp=dec, law=law, seed=seed,
                        state_seed=state_seed, stdp=stdp)


def _leaves(tree):
    return [np.asarray(l) for l in jax.tree.leaves(tree)]


@pytest.mark.parametrize("law_fn", [gaussian_law, exponential_law],
                         ids=["gaussian", "exponential"])
def test_ensemble_of_one_bit_identical_static(law_fn):
    """vmap over a singleton member axis is the identity: same spikes,
    same final state as the plain path."""
    cfg = _cfg(law_fn())
    tabs = build_shard_tables(cfg)
    solo_s, solo_steps = simulate(init_sim_state(cfg), tabs, cfg, N)
    ens_cfg = dataclasses.replace(cfg, state_seed=None)
    ens_s, ens_steps = simulate(
        init_ensemble_state(ens_cfg, [cfg.state_seed_value]),
        tabs, ens_cfg, N, ensemble=1)
    np.testing.assert_array_equal(np.asarray(solo_steps),
                                  np.asarray(ens_steps)[0])
    for a, b in zip(_leaves(solo_s), _leaves(ens_s)):
        np.testing.assert_array_equal(a, b[0])


@pytest.mark.parametrize("law_fn", [gaussian_law, exponential_law],
                         ids=["gaussian", "exponential"])
def test_ensemble_of_one_bit_identical_plastic(law_fn):
    cfg = _cfg(law_fn(), stdp=STDPParams())
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    (solo_s, solo_w, solo_tr), solo_steps = simulate(
        init_sim_state(cfg), tabs, cfg, N, plasticity=aux)
    (ens_s, ens_w, ens_tr), ens_steps = simulate(
        init_ensemble_state(cfg, [cfg.state_seed_value]), tabs, cfg, N,
        plasticity=aux, ensemble=1)
    np.testing.assert_array_equal(np.asarray(solo_steps),
                                  np.asarray(ens_steps)[0])
    for tree_a, tree_b in ((solo_s, ens_s), (solo_w, ens_w),
                           (solo_tr, ens_tr)):
        for a, b in zip(_leaves(tree_a), _leaves(tree_b)):
            np.testing.assert_array_equal(a, b[0])


def test_ensemble_members_differ():
    """Different member seeds actually produce different dynamics
    (guards against a broadcast bug making every member member 0)."""
    cfg = _cfg(gaussian_law())
    tabs = build_shard_tables(cfg)
    _, steps = simulate(init_ensemble_state(cfg, SEEDS), tabs, cfg, N,
                        ensemble=len(SEEDS))
    steps = np.asarray(steps)
    assert steps.shape[0] == len(SEEDS)
    assert not np.array_equal(steps[0], steps[1])


# ---------------------------------------------------------------------------
# driver-level: spool byte-identity, one compile, preempt -> resume
# ---------------------------------------------------------------------------

def _dist(seed=3, state_seed=None, seeds=None, stdp=None):
    law = gaussian_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law, seed=seed,
                                          state_seed=state_seed,
                                          stdp=stdp),
                      ensemble_seeds=seeds)


def _driver(ckpt_dir, dist, seg=10, cache=None, **kw):
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir), ckpt_every=1,
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, dist, mesh, segment_steps=seg, sim_cache=cache,
                     **kw)


def _spk_digests(spool_dir):
    out = {}
    for root, _, files in os.walk(spool_dir):
        for fn in sorted(files):
            if fn.endswith(".spk"):
                rel = os.path.relpath(os.path.join(root, fn), spool_dir)
                with open(os.path.join(root, fn), "rb") as f:
                    out[rel] = hashlib.sha256(f.read()).hexdigest()
    return out


def test_member_spools_byte_identical_to_solo(tmp_path):
    """Each member's spool shards hash-equal the solo run with that
    state seed, and the ensemble used one compiled step."""
    cache = {}
    ens = _driver(tmp_path / "ens", _dist(seeds=SEEDS), cache=cache,
                  record_events=True)
    ens.run(N)
    assert ens.compiled_step_cache_size() in (None, 1)
    assert len(cache) == 1
    ens_digests = _spk_digests(ens.spool.directory)

    for m, seed in enumerate(SEEDS):
        solo = _driver(tmp_path / f"solo{m}", _dist(state_seed=seed),
                       record_events=True)
        solo.run(N)
        solo_digests = _spk_digests(solo.spool.directory)
        want = {os.path.join(member_name(m), rel): h
                for rel, h in solo_digests.items()}
        got = {rel: h for rel, h in ens_digests.items()
               if rel.startswith(member_name(m) + os.sep)}
        assert got == want
        np.testing.assert_array_equal(solo.spike_counts(N),
                                      ens.spike_counts(N, member=m))


def test_ensemble_preempt_resume_exactly_once(tmp_path):
    """Preempt an ensemble mid-run, resume in a new driver: final
    per-member spools byte-identical to the unpreempted reference
    (exactly-once offsets cover member streams)."""
    ref = _driver(tmp_path / "ref", _dist(seeds=SEEDS),
                  record_events=True)
    ref_out = ref.run(N)

    first = _driver(tmp_path / "p", _dist(seeds=SEEDS),
                    record_events=True, preempt_after_segments=2)
    out1 = first.run(N)
    assert out1["preempted"] and out1["final_step"] == 20
    cache = {}
    second = _driver(tmp_path / "p", _dist(seeds=SEEDS), cache=cache,
                     record_events=True)
    out2 = second.run(N)
    assert not out2["preempted"] and out2["final_step"] == N
    assert _spk_digests(second.spool.directory) \
        == _spk_digests(ref.spool.directory)
    assert len(cache) == 1
    # state bit-identity too
    for a, b in zip(_leaves(out2["state"]), _leaves(ref_out["state"])):
        np.testing.assert_array_equal(a, b)


def test_ensemble_plastic_member_checksum_matches_solo(tmp_path):
    """Member m's learned-weight checksum == the solo plastic run with
    state_seed=seeds[m] (the table realization is shared; only the
    dynamics seed varies)."""
    stdp = STDPParams()
    ens = _driver(tmp_path / "ens", _dist(seeds=SEEDS[:2], stdp=stdp))
    out = ens.run(N)
    for m, seed in enumerate(SEEDS[:2]):
        solo = _driver(tmp_path / f"s{m}",
                       _dist(state_seed=seed, stdp=stdp))
        sout = solo.run(N)
        assert solo.plastic_summary(sout["state"])["weight_checksum"] \
            == ens.plastic_summary(out["state"], member=m)["weight_checksum"]


def test_ensemble_refuses_retile(tmp_path):
    """A member-stacked checkpoint must not resume onto a different
    tiling even with allow_retile (simulated by rewriting the
    manifest's tiling, as a real 2-device checkpoint would carry)."""
    import json
    d = _driver(tmp_path, _dist(seeds=SEEDS))
    d.run(10)
    mpath = tmp_path / "step_00000010" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["meta"]["tiles_y"] = 2
    mpath.write_text(json.dumps(manifest))
    again = _driver(tmp_path, _dist(seeds=SEEDS), allow_retile=True)
    with pytest.raises(ValueError, match="member axis"):
        again.run(N)


def test_seed_split_solo_state_seed():
    """state_seed decouples dynamics from the table realization: same
    tables, different trajectories; state_seed=None follows seed."""
    law = gaussian_law()
    a = _cfg(law, seed=3, state_seed=None)
    b = _cfg(law, seed=3, state_seed=99)
    ta, tb = build_shard_tables(a), build_shard_tables(b)
    for la, lb in zip(_leaves(ta), _leaves(tb)):
        np.testing.assert_array_equal(la, lb)
    _, sa = simulate(init_sim_state(a), ta, a, N)
    _, sb = simulate(init_sim_state(b), tb, b, N)
    assert not np.array_equal(np.asarray(sa), np.asarray(sb))
    assert a.state_seed_value == 3 and b.state_seed_value == 99
