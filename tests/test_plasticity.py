"""Distributed plasticity: single-shard vs distributed bit-identity,
checkpointed plastic resume, the global-synapse-id table relay, and the
STDP-identity resume refusals (ISSUE 5).

All single-device (1x1 mesh) -- the 2-device plastic retile-resume case
lives in tests/test_multidevice.py, and CI's plastic resume-smoke leg
drives the same path through the repro.launch.sim CLI.
"""

import jax
import numpy as np
import pytest

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.dist_engine import (DistConfig, SimInputs,
                                    build_dist_inverse_index,
                                    build_dist_tables,
                                    init_dist_plastic_state,
                                    init_dist_state, make_sim_fn)
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_plasticity, init_sim_state,
                               simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.retile import (gather_synapse_stream, local_gid_map,
                               retile_plastic, retile_tables)
from repro.core.stdp import STDPParams
from repro.parallel.compat import make_mesh
from repro.runtime import DriverConfig, SimDriver

N = 40          # spiking sets in around step ~34 at this scale/seed


def _dist(law="gaussian", tiles=(1, 1), seed=3, stdp=STDPParams()):
    law_ = gaussian_law() if law == "gaussian" else exponential_law()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 10), tiles_y=tiles[0],
                            tiles_x=tiles[1], radius=law_.radius)
    return DistConfig(engine=EngineConfig(decomp=dec, law=law_, seed=seed,
                                          stdp=stdp))


def _driver(ckpt_dir, seg, stdp=STDPParams(), **kw):
    cfg = DriverConfig(ckpt_dir=str(ckpt_dir),
                       ckpt_every=kw.pop("ckpt_every", 1),
                       backoff_s=0.01, handle_sigterm=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    return SimDriver(cfg, _dist(stdp=stdp), mesh, segment_steps=seg, **kw)


def _canon(stream):
    """Canonical (pre, post, dslot, w-bits) rows of a synapse stream --
    the tiling-invariant identity the relay must preserve bit-exactly."""
    w = np.ascontiguousarray(stream["w"]).astype(np.float32)
    wbits = w.view(np.uint32)
    order = np.lexsort((wbits, stream["dslot"], stream["post"],
                        stream["pre"]))
    return np.column_stack([stream["pre"][order], stream["post"][order],
                            stream["dslot"][order].astype(np.int64),
                            wbits[order].astype(np.int64)])


# ---------------------------------------------------------------------------
# Single-shard plastic simulate vs the distributed carry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("law", ["gaussian", "exponential"])
def test_dist_plastic_matches_single_shard(law):
    """The distributed plastic scan at 1x1 is bit-identical to the
    single-shard plastic ``simulate`` reference: spikes, final weights and
    both trace arrays."""
    steps = 60
    dist = _dist(law)
    cfg = dist.engine
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    (st, tabs1, traces), per = jax.jit(
        lambda s, t: simulate(s, t, cfg, steps, plasticity=aux))(
            init_sim_state(cfg), tabs)

    mesh = make_mesh((1, 1), ("data", "model"))
    state = init_dist_state(dist)
    dtabs, _ = build_dist_tables(dist)
    state["plastic"] = init_dist_plastic_state(dist, dtabs)
    slots, _ = build_dist_inverse_index(dist, dtabs)
    sim = make_sim_fn(dist, mesh, steps, storage=dtabs.storage)
    dstate, per_d = sim(state, SimInputs(tables=dtabs, inv_slots=slots))

    assert np.asarray(per).sum() > 0            # the run actually spiked
    np.testing.assert_array_equal(np.asarray(per_d)[0, 0],
                                  np.asarray(per))
    np.testing.assert_array_equal(
        np.asarray(dstate["plastic"]["w"][0][0, 0]),
        np.asarray(tabs1["local"]["w"]))
    np.testing.assert_array_equal(
        np.asarray(dstate["plastic"]["x_post"][0, 0]),
        np.asarray(traces["x_post"]))
    np.testing.assert_array_equal(
        np.asarray(dstate["plastic"]["x_pre"][0][0, 0]),
        np.asarray(traces["x_pre"][0]))
    np.testing.assert_array_equal(np.asarray(dstate["neuron"]["v"][0, 0]),
                                  np.asarray(st["neuron"]["v"]))
    # plasticity moved excitatory weights (the run is not a no-op)
    delta = np.abs(np.asarray(tabs1["local"]["w"])
                   - np.asarray(tabs["local"]["w"]))
    assert delta.sum() > 0


def test_plastic_simulate_ignores_halo_tiers_of_multitile_tables():
    """``init_plasticity`` covers every tier, but the single-shard
    plastic ``simulate`` consumer steps only the local one -- handing it a
    multi-tile shard's tables (halo tiers present) must not corrupt the
    scan carry (regression: the N-tier trace state used to collapse to
    1 tier after the first step)."""
    import dataclasses
    cfg = dataclasses.replace(_dist(tiles=(1, 2)).engine,
                              use_kernels=False)
    tabs = build_shard_tables(cfg, 0, 0)
    aux = init_plasticity(tabs, cfg)
    assert len(aux["masks"]) > 1                 # halo tiers present
    (st, t1, traces), per = jax.jit(
        lambda s, t: simulate(s, t, cfg, 5, plasticity=aux))(
            init_sim_state(cfg), tabs)
    assert np.asarray(per).shape == (5,)
    assert len(traces["x_pre"]) == 1             # local tier only


# ---------------------------------------------------------------------------
# Checkpointed plastic segments (SimDriver)
# ---------------------------------------------------------------------------

def test_plastic_resume_bit_identity(tmp_path):
    """A preempted-and-resumed plastic run ends with weight tables and
    traces bit-identical to an unpreempted run: the plastic carry rides
    every checkpoint."""
    straight = _driver(tmp_path / "a", seg=N)
    out_a = straight.run(N)
    assert out_a["final_step"] == N

    first = _driver(tmp_path / "b", seg=N // 2)
    first.run(N // 2)
    second = _driver(tmp_path / "b", seg=N // 2)
    out_b = second.run(N)
    assert out_b["final_step"] == N

    for la, lb in zip(jax.tree.leaves(out_a["state"]),
                      jax.tree.leaves(out_b["state"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    sa = straight.plastic_summary(out_a["state"])
    sb = second.plastic_summary(out_b["state"])
    assert sa["weight_checksum"] == sb["weight_checksum"]
    assert sa["w_l1_delta"] > 0                  # learning happened
    assert sa["n_plastic"] > 0


def test_plastic_recording_is_pure_observer(tmp_path):
    """The spike observatory composes with plasticity without touching
    the dynamics: final weights bit-identical with recording on/off."""
    off = _driver(tmp_path / "off", seg=20)
    out_off = off.run(N)
    on = _driver(tmp_path / "on", seg=20, record_events=True)
    out_on = on.run(N)
    for a, b in zip(jax.tree.leaves(out_off["state"]),
                    jax.tree.leaves(out_on["state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert on.spike_counts(N).sum() > 0


# ---------------------------------------------------------------------------
# STDP-identity refusals (mirrors the grid/law/seed refusals)
# ---------------------------------------------------------------------------

def test_plastic_refuses_static_checkpoint(tmp_path):
    mesh = make_mesh((1, 1), ("data", "model"))
    SimDriver(DriverConfig(ckpt_dir=str(tmp_path), handle_sigterm=False),
              _dist(stdp=None), mesh, segment_steps=10).run(10)
    d = _driver(tmp_path, seg=10)
    with pytest.raises(ValueError, match="stdp"):
        d._restore_or_init()


def test_static_refuses_plastic_checkpoint(tmp_path):
    _driver(tmp_path, seg=10).run(10)
    mesh = make_mesh((1, 1), ("data", "model"))
    d = SimDriver(DriverConfig(ckpt_dir=str(tmp_path),
                               handle_sigterm=False),
                  _dist(stdp=None), mesh, segment_steps=10)
    with pytest.raises(ValueError, match="stdp"):
        d._restore_or_init()


def test_plastic_refuses_stdp_param_drift(tmp_path):
    """Resuming under different STDP parameters is a different model --
    refused, like a seed or law drift."""
    _driver(tmp_path, seg=10).run(10)
    d = _driver(tmp_path, seg=10, stdp=STDPParams(a_plus=0.009))
    with pytest.raises(ValueError, match="stdp"):
        d._restore_or_init()


# ---------------------------------------------------------------------------
# Global-synapse-id table relay (host-side; no mesh needed)
# ---------------------------------------------------------------------------

def test_retile_tables_preserves_global_synapse_multiset():
    """Relaying 1x2 -> 2x1 preserves every (pre, post, dslot, weight)
    record bit-exactly -- nothing re-sampled, nothing dropped."""
    a, b = _dist(tiles=(1, 2)), _dist(tiles=(2, 1))
    ta, _ = build_dist_tables(a)
    relaid = retile_tables(ta, a.engine.decomp, a.engine.spec(),
                           b.engine.decomp, b.engine.spec())
    sa = gather_synapse_stream(ta, a.engine.decomp, a.engine.spec())
    sb = gather_synapse_stream(relaid, b.engine.decomp, b.engine.spec())
    assert len(sa["pre"]) > 0
    np.testing.assert_array_equal(_canon(sa), _canon(sb))
    # occupancy bookkeeping survives: same total synapse count
    assert int(np.asarray(relaid["local"]["nnz"]).sum()
               + sum(int(np.asarray(t["nnz"]).sum())
                     for t in relaid["halo"])) == len(sa["pre"])


def test_retile_tables_roundtrip_is_canonical():
    """Relays compose: A -> B -> A lands bit-identically to the direct
    canonicalization A -> A (so any chain of retiles yields the same
    layout as relaying from birth directly)."""
    a, b = _dist(tiles=(1, 2)), _dist(tiles=(2, 1))
    ta, _ = build_dist_tables(a)
    da, sa = a.engine.decomp, a.engine.spec()
    db, sb = b.engine.decomp, b.engine.spec()
    r1 = retile_tables(ta, da, sa, db, sb)
    r2 = retile_tables(r1, db, sb, da, sa)
    canon = retile_tables(ta, da, sa, da, sa)
    for got, want in zip(jax.tree.leaves(r2), jax.tree.leaves(canon)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_retile_plastic_relays_weights_and_traces():
    """The plastic carry follows the realization: live weights by
    global synapse id, pre-traces by pre neuron id (local tier only --
    halo replicas are exchanged per step, never carried), post-traces
    like the membrane state."""
    a, b = _dist(tiles=(1, 2)), _dist(tiles=(2, 1))
    ta, _ = build_dist_tables(a)
    da, speca = a.engine.decomp, a.engine.spec()
    db, specb = b.engine.decomp, b.engine.spec()
    tiers = [ta["local"]] + list(ta["halo"])
    rng = np.random.default_rng(0)

    # live weights: perturbed copies of the build weights (plastic mask
    # = excitatory entries); traces: the pre/post neuron's gid as value
    w_live = []
    for t in tiers:
        w = np.asarray(t["w"]).copy()
        w += (w > 0) * rng.uniform(0.0, 0.1, size=w.shape).astype(w.dtype)
        w_live.append(w)
    x_pre = [np.zeros((1, 2, tiers[0]["tgt"].shape[2]), np.float32)]
    x_post = np.zeros((1, 2, speca.n_local), np.float32)
    for ty in range(1):
        for tx in range(2):
            lmap = local_gid_map(da, ty, tx)
            x_pre[0][ty, tx, :len(lmap)] = np.maximum(lmap, 0) + 0.5
            x_post[ty, tx] = np.where(lmap >= 0, lmap + 0.25, 0.0)

    out = retile_plastic({"w": w_live, "x_pre": x_pre, "x_post": x_post},
                         ta, da, speca, db, specb)

    # weights: multiset of live (pre, post, dslot, w) preserved exactly
    live_a = gather_synapse_stream(
        {"local": dict(ta["local"], w=w_live[0]),
         "halo": [dict(t, w=w) for t, w in zip(ta["halo"], w_live[1:])]},
        da, speca)
    relaid_tabs = retile_tables(
        {"local": dict(ta["local"], w=w_live[0]),
         "halo": [dict(t, w=w) for t, w in zip(ta["halo"], w_live[1:])]},
        da, speca, db, specb)
    for got, want in zip(out["w"],
                         [relaid_tabs["local"]["w"]]
                         + [t["w"] for t in relaid_tabs["halo"]]):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    live_b = gather_synapse_stream(relaid_tabs, db, specb)
    np.testing.assert_array_equal(_canon(live_a), _canon(live_b))

    # traces: every new-tiling row carries its neuron's gid pattern;
    # the pre-trace list stays local-only across the relay
    assert len(out["x_pre"]) == 1
    for ty in range(2):
        for tx in range(1):
            lmap = local_gid_map(db, ty, tx)
            np.testing.assert_array_equal(
                np.asarray(out["x_pre"][0][ty, tx, :len(lmap)]),
                np.where(lmap >= 0, np.maximum(lmap, 0) + 0.5, 0.0)
                .astype(np.float32))
            np.testing.assert_array_equal(
                np.asarray(out["x_post"][ty, tx]),
                np.where(lmap >= 0, lmap + 0.25, 0.0).astype(np.float32))
