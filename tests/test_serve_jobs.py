"""Simulation job service: typed spec round-trip, queue on a resident
mesh with a shared compiled step, and the incremental streaming
endpoint under concurrent clients."""

import json
import threading
import time
import urllib.error
import urllib.parse
import urllib.request

import pytest

from repro.runtime import JobError, SimJobSpec, build_sim_driver

GRID, NPC = 4, 20


def _spec(ckpt_dir, **kw):
    base = dict(ckpt_dir=str(ckpt_dir), grid=GRID, n_per_column=NPC,
                law="exponential", t_steps=30, segment_steps=10,
                record=True)
    base.update(kw)
    return SimJobSpec(**base)


# ---------------------------------------------------------------------------
# spec round-trip + validation
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip(tmp_path):
    spec = _spec(tmp_path, seeds=(3, 1, 2), plastic=True,
                 stdp={"a_plus": 0.02}, tiles=(1, 1))
    again = SimJobSpec.from_json(spec.to_json())
    assert again == spec
    assert again.seeds == (3, 1, 2) and again.tiles == (1, 1)
    assert again.n_members == 3
    # job_meta is plain JSON data (manifest-safe)
    assert json.loads(json.dumps(spec.job_meta())) == spec.job_meta()


def test_spec_rejects_unknown_fields_and_bad_values(tmp_path):
    with pytest.raises(ValueError, match="bogus"):
        SimJobSpec.from_json(
            json.dumps({"ckpt_dir": str(tmp_path), "bogus": 1}))
    with pytest.raises(ValueError, match="law"):
        _spec(tmp_path, law="cauchy")
    with pytest.raises(ValueError, match="mutually exclusive"):
        _spec(tmp_path, seeds=(1, 2), state_seed=7)
    with pytest.raises(ValueError, match="member"):
        _spec(tmp_path, seeds=())
    with pytest.raises(ValueError, match="t_steps"):
        _spec(tmp_path, t_steps=0)
    with pytest.raises(ValueError, match="plastic"):
        _spec(tmp_path, stdp={"a_plus": 0.02})


def test_build_refuses_bad_resume_targets(tmp_path):
    with pytest.raises(JobError, match="no checkpoint"):
        build_sim_driver(_spec(tmp_path / "empty", resume=True))


# ---------------------------------------------------------------------------
# server + HTTP endpoints
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def server():
    from repro.launch.serve import serve_sim
    httpd, jobs = serve_sim(port=0)
    host, port = httpd.server_address[:2]
    yield f"http://{host}:{port}", jobs
    httpd.shutdown()
    jobs.shutdown()


def _post(base, path, payload):
    req = urllib.request.Request(base + path, data=payload.encode(),
                                 method="POST")
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(base, path):
    try:
        with urllib.request.urlopen(base + path) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_server_runs_ensemble_with_concurrent_streams(server, tmp_path):
    base, jobs = server
    spec = _spec(tmp_path / "ens", seeds=(0, 1, 2))
    st, r = _post(base, "/v1/sim/jobs", spec.to_json())
    assert st == 200 and r["status"] == "queued"
    jid = r["job_id"]

    results = {}

    def client(name, pause):
        cursor, total = None, 0
        while True:
            q = "" if cursor is None else "?cursor=" + urllib.parse.quote(
                json.dumps(cursor))
            st, r = _get(base, f"/v1/sim/jobs/{jid}/stream{q}")
            assert st == 200, r
            cursor = r["cursor"]
            for member, g in r["streams"].items():
                assert member.startswith("member_")
                assert len(g["step"]) == g["n_new"]
                total += g["n_new"]
            if r["done"]:
                break
            time.sleep(pause)
        results[name] = total

    threads = [threading.Thread(target=client, args=("fast", 0.05)),
               threading.Thread(target=client, args=("slow", 0.3))]
    for t in threads:
        t.start()
    job = jobs.wait(jid, timeout=300)
    for t in threads:
        t.join(timeout=60)
    assert job.status == "done", job.error
    assert job.result["final_step"] == 30
    assert job.result["members"] == 3
    assert job.result["compiled_steps"] == 1
    # both clients, at different pace, saw every spooled event
    assert results["fast"] == results["slow"] \
        == job.result["spooled_events"] > 0

    # a second job with different seeds reuses the compiled step
    spec2 = _spec(tmp_path / "ens2", seeds=(7, 8, 9), t_steps=10)
    st, r = _post(base, "/v1/sim/jobs", spec2.to_json())
    job2 = jobs.wait(r["job_id"], timeout=300)
    assert job2.status == "done", job2.error
    assert jobs.compiled_steps() == 1

    st, r = _get(base, "/v1/sim/jobs")
    assert st == 200 and len(r["jobs"]) >= 2


def test_server_rejects_bad_requests(server, tmp_path):
    base, jobs = server
    st, r = _post(base, "/v1/sim/jobs",
                  '{"ckpt_dir": "/tmp/x", "bogus": 1}')
    assert st == 400 and "bogus" in r["error"]
    st, r = _get(base, "/v1/sim/jobs/job-9999")
    assert st == 404
    st, r = _get(base, "/v1/nope")
    assert st == 404
    # a failing job (occupied ckpt_dir without resume) fails, server
    # stays alive
    d = tmp_path / "occupied"
    spec = _spec(d, t_steps=10)
    _, r = _post(base, "/v1/sim/jobs", spec.to_json())
    assert jobs.wait(r["job_id"], timeout=300).status == "done"
    _, r = _post(base, "/v1/sim/jobs", spec.to_json())
    j = jobs.wait(r["job_id"], timeout=300)
    assert j.status == "failed" and "resume" in j.error
    # stream of a no-record job is an explicit 400
    spec3 = _spec(tmp_path / "norec", record=False, t_steps=10)
    _, r = _post(base, "/v1/sim/jobs", spec3.to_json())
    norec_id = r["job_id"]
    jobs.wait(norec_id, timeout=300)
    st, r = _get(base, f"/v1/sim/jobs/{norec_id}/stream")
    assert st == 400 and "record" in r["error"]


def test_unknown_arch_is_explicit(capsys):
    from repro.launch.serve import main
    with pytest.raises(SystemExit) as ei:
        main(["--arch", "not-a-model"])
    msg = str(ei.value)
    assert "unknown arch" in msg and "sim" in msg and "gemma-2b" in msg
