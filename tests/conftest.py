"""Shared fixtures.  NOTE: no XLA_FLAGS here -- unit tests must see the
real single CPU device; multi-device behaviour is tested via
subprocesses (tests/test_multidevice.py) so the device count never
leaks into this process."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
