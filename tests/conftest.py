"""Shared fixtures.  NOTE: no XLA_FLAGS here -- unit tests must see the
real single CPU device; multi-device behaviour is tested via
subprocesses (tests/test_multidevice.py) so the device count never
leaks into this process."""

import numpy as np
import pytest


def pytest_configure(config):
    # registered here as well as pyproject.toml so direct pytest
    # invocations from other rootdirs still know the marker
    config.addinivalue_line(
        "markers", "slow: multi-minute cases excluded from tier-1")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
