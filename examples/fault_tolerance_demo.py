"""Fault-tolerance demo: inject a node failure mid-training and watch
the driver restore from the last checkpoint and replay to the exact
same result.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import shutil

from repro.launch.train import build_trainer

CKPT = "/tmp/repro_ft_demo"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    crashed = []

    def chaos(step):
        if step == 30 and not crashed:
            crashed.append(step)
            raise RuntimeError("simulated TPU worker loss at step 30")

    driver, cfg = build_trainer("qwen2-1.5b", batch=4, seq=64, steps=50,
                                ckpt_dir=CKPT, ckpt_every=10,
                                fault_hook=chaos)
    out = driver.run(50)
    losses = {m["step"]: m["loss"] for m in out["metrics"]}
    print(f"injected crash at step 30 -> restored from step 30's last "
          f"checkpoint (step 30 // 10 * 10 = 30) and replayed")
    print(f"completed {out['final_step']} steps; "
          f"loss {losses[0]:.3f} -> {losses[max(losses)]:.3f}")
    # steps 30-34 were computed twice (before+after crash), but the
    # abandoned timeline is pruned: the log carries each step once
    steps = [m["step"] for m in out["metrics"]]
    assert steps == sorted(set(steps)), "replayed steps must appear once"
    assert len(losses) == 50
    print(f"metrics log carries each of the {len(steps)} steps exactly "
          "once despite the crash-and-replay")
    print("ok")


if __name__ == "__main__":
    main()
