"""Serve a small model with batched requests: prefill once, decode
greedily, report latency per token.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --gen 24
"""

import argparse

from repro.launch.serve import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve_batch(args.arch, args.batch, args.prompt_len, args.gen)
    print(f"{out['config']}: batch {args.batch}, prompt {args.prompt_len}")
    print(f"  prefill: {out['prefill_s']*1e3:8.1f} ms")
    print(f"  decode : {out['decode_s_per_token']*1e3:8.2f} ms/token")
    print(f"  sample generations (token ids): {out['tokens'][:2, :8].tolist()}")


if __name__ == "__main__":
    main()
