"""End-to-end driver for the paper's own experiment (the paper's kind is
*simulation*): distributed multi-shard spiking-network run comparing the
two connectivity laws, with halo-exchange communication, STDP demo, and
the paper's cost/memory metrics.

Runs the distributed engine over however many host devices exist (set
XLA_FLAGS=--xla_force_host_platform_device_count=8 for a 4x2 tile grid).

    PYTHONPATH=src python examples/snn_simulation.py [--steps 300]
"""

import argparse

import jax
import numpy as np

from repro.configs.snn import reduced_case
from repro.core.dist_engine import DistConfig, simulate
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_plasticity, init_sim_state)
from repro.core.engine import simulate as engine_simulate
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.stdp import STDPParams
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--grid", type=int, default=8)
    ap.add_argument("--neurons-per-column", type=int, default=60)
    args = ap.parse_args()

    mesh = make_host_mesh()
    ty, tx = mesh.devices.shape
    print(f"mesh: {ty}x{tx} tiles over {ty * tx} devices")

    results = {}
    for law_name in ("gaussian", "exponential"):
        case = reduced_case(law_name, grid=args.grid,
                            n_per_column=args.neurons_per_column)
        law = case.connectivity()
        dec = TileDecomposition(
            grid=ColumnGrid(*case.grid, case.n_per_column),
            tiles_y=ty, tiles_x=tx, radius=law.radius)
        cfg = DistConfig(engine=EngineConfig(decomp=dec, law=law))
        out = simulate(cfg, mesh, n_steps=args.steps, timed=True)
        cost = out["elapsed_s"] / max(out["events_timed"], 1)
        results[law_name] = dict(rate=out["rate_hz"], cost=cost,
                                 events=out["events"],
                                 syn=out["stats"]["n_synapses"])
        print(f"{law_name:12s} stencil {law.stencil_width:2d}: "
              f"rate {out['rate_hz']:6.2f} Hz, "
              f"{int(out['events']):9d} events, "
              f"cost/event {cost:.2e} s, dropped {int(out['dropped'])}")

    r = results
    print(f"\ncost ratio exp/gauss: {r['exponential']['cost']/r['gaussian']['cost']:.2f} "
          f"(paper measured 1.9-2.3 on CPU/MPI; see benchmarks/fig2)")

    # ---- STDP demo (single shard): weights move under plasticity -------
    law = reduced_case("gaussian", grid=4, n_per_column=40).connectivity()
    dec = TileDecomposition(grid=ColumnGrid(4, 4, 40), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=dec, law=law, stdp=STDPParams())
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    w0 = np.asarray(tabs["local"]["w"]).copy()
    (st, tabs2, _), _ = jax.jit(
        lambda s, t: engine_simulate(s, t, cfg, 150, plasticity=aux))(
        init_sim_state(cfg), tabs)
    w1 = np.asarray(tabs2["local"]["w"])
    moved = np.abs(w1 - w0)[w0 > 0]
    print(f"\nSTDP: {int((moved > 1e-6).sum())} plastic synapses moved, "
          f"mean |dw| {moved.mean():.2e} over 150 ms")


if __name__ == "__main__":
    main()
