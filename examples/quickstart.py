"""Quickstart: the two halves of the framework in one minute.

1. DPSNN core -- simulate a small cortical slab under both of the
   paper's connectivity laws and print the paper's headline metric.
2. LM stack -- one training step + one decode step of an assigned
   architecture (reduced config).

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

# --- 1. spiking network, paper configs at reduced scale -------------------
from repro.core import (EngineConfig, ColumnGrid, TileDecomposition,
                        exponential_law, gaussian_law)
from repro.core.engine import (build_shard_tables, firing_rate_hz,
                               init_sim_state, simulate)

print("== DPSNN core ==")
for law in (gaussian_law(), exponential_law()):
    dec = TileDecomposition(grid=ColumnGrid(6, 6, 50), tiles_y=1,
                            tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=dec, law=law)
    tabs = build_shard_tables(cfg)
    state = init_sim_state(cfg)
    t0 = time.perf_counter()
    state, _ = jax.jit(lambda s: simulate(s, tabs, cfg, 200))(state)
    jax.block_until_ready(state["t"])
    el = time.perf_counter() - t0
    events = float(state["metrics"]["events"])
    print(f"  {law.kind:12s} stencil {law.stencil_width}x"
          f"{law.stencil_width}: rate {firing_rate_hz(state, cfg, 200):5.1f} Hz, "
          f"{int(events)} synaptic events, "
          f"{el / max(events, 1):.2e} s/event")

# --- 2. LM stack ------------------------------------------------------------
from repro.configs import get_reduced
from repro.data.pipeline import LMBatchPipeline
from repro.models.config import ShapeConfig
from repro.models.model import make_serve_step, make_train_step
from repro.models.transformer import init_decode_state, init_model
from repro.optim import adamw
from repro.optim.schedules import constant
from repro.parallel.sharding import MeshRules

print("== LM stack (qwen3-8b reduced) ==")
rules = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                  experts=None, vocab=None, kv_seq=None, d_inner=None)
cfg = get_reduced("qwen3-8b")
params, _ = init_model(jax.random.PRNGKey(0), cfg)
pipe = LMBatchPipeline(cfg=cfg, shape=ShapeConfig("q", 64, 2, "train"))
batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
opt = adamw(constant(1e-3))
step = jax.jit(make_train_step(cfg, rules, opt))
params, opt_state, out = step(params, opt.init(params), batch)
print(f"  train step: loss {float(out['loss']):.3f}, "
      f"grad norm {float(out['grad_norm']):.3f}")

state = init_decode_state(cfg, 2, 32)
serve = jax.jit(make_serve_step(cfg, rules))
logits, state = serve(params, state, batch["tokens"][:, :1], jnp.int32(0))
print(f"  decode step: logits {logits.shape}, "
      f"argmax {jnp.argmax(logits[:, 0], -1).tolist()}")
print("ok")
