"""Train an assigned-architecture (reduced) LM end-to-end with the
fault-tolerant runtime: synthetic pipeline, AdamW, checkpoints, and a
loss curve that actually goes down.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b \\
        --steps 200
"""

import argparse
import json
import os
import shutil

from repro.launch.train import build_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()
    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    driver, cfg = build_trainer(args.arch, args.batch, args.seq,
                                args.steps, args.ckpt_dir)
    print(f"training {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) for "
          f"{args.steps} steps ...")
    out = driver.run(args.steps)
    losses = [m["loss"] for m in out["metrics"]]
    k = max(len(losses) // 10, 1)
    for i in range(0, len(losses), k):
        print(f"  step {out['metrics'][i]['step']:5d}  "
              f"loss {losses[i]:8.4f}  ({out['metrics'][i]['dt']*1e3:.0f} ms)")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    assert losses[-1] < losses[0], "loss did not decrease!"
    with open("/tmp/repro_train_lm_curve.json", "w") as f:
        json.dump(losses, f)
    print("loss curve -> /tmp/repro_train_lm_curve.json")


if __name__ == "__main__":
    main()
