"""Ensemble simulation service, end to end (CI's ensemble-smoke).

One script proves the service contract:

1. a **solo reference** run (state seed = member 0's seed) spools its
   spikes to disk;
2. a job server comes up on loopback; a 3-seed **ensemble job** is
   POSTed as a typed ``SimJobSpec`` and runs through ONE compiled
   segment function (asserted);
3. while it runs, **two concurrent clients** stream the per-member
   spike deltas through the cursor endpoint at different paces -- both
   must end up with every spooled event exactly once;
4. a second job with different seeds reuses the server's compiled step
   (cache size stays 1);
5. ``launch.analyze`` stitches per-member activity reports;
6. member 0's spool shards are **byte-identical** to the solo
   reference -- the ensemble axis is pure batching, not a new model.

Run::

    PYTHONPATH=src python examples/ensemble_service.py \\
        --out results/ensemble_smoke.json
"""

import argparse
import hashlib
import json
import os
import shutil
import tempfile
import threading
import time
import urllib.parse
import urllib.request

GRID, NPC, LAW = 4, 20, "exponential"
SEEDS = (0, 1, 2)
T_STEPS, SEG = 60, 15


def spk_digests(spool_dir):
    out = {}
    for root, _, files in os.walk(spool_dir):
        for fn in sorted(files):
            if fn.endswith(".spk"):
                rel = os.path.relpath(os.path.join(root, fn), spool_dir)
                with open(os.path.join(root, fn), "rb") as f:
                    out[rel] = hashlib.sha256(f.read()).hexdigest()
    return out


def get(base, path):
    with urllib.request.urlopen(base + path) as r:
        return json.loads(r.read())


def post(base, path, payload):
    req = urllib.request.Request(base + path, data=payload.encode(),
                                 method="POST")
    with urllib.request.urlopen(req) as r:
        return json.loads(r.read())


def stream_until_done(base, job_id, pause, results, name):
    cursor, total, polls = None, 0, 0
    per_member = {}
    while True:
        q = "" if cursor is None else \
            "?cursor=" + urllib.parse.quote(json.dumps(cursor))
        r = get(base, f"/v1/sim/jobs/{job_id}/stream{q}")
        cursor = r["cursor"]
        for member, g in r["streams"].items():
            per_member[member] = per_member.get(member, 0) + g["n_new"]
            total += g["n_new"]
        polls += 1
        if r["done"]:
            break
        time.sleep(pause)
    results[name] = {"total": total, "polls": polls,
                     "per_member": per_member}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=os.path.join("results",
                                                  "ensemble_smoke.json"))
    ap.add_argument("--workdir", default=None,
                    help="run/checkpoint directory (default: a fresh "
                         "temp dir, removed on success)")
    args = ap.parse_args(argv)

    from repro.launch.analyze import main as analyze_main
    from repro.launch.serve import serve_sim
    from repro.runtime import SimJobSpec, build_sim_driver

    work = args.workdir or tempfile.mkdtemp(prefix="ensemble_smoke_")
    os.makedirs(work, exist_ok=True)

    # 1. solo reference: a plain run whose dynamics seed is member 0's
    solo_spec = SimJobSpec(ckpt_dir=os.path.join(work, "solo"),
                           grid=GRID, n_per_column=NPC, law=LAW,
                           state_seed=SEEDS[0], t_steps=T_STEPS,
                           segment_steps=SEG, record=True)
    solo = build_sim_driver(solo_spec)
    solo.run(T_STEPS)
    solo_digest = spk_digests(solo.spool.directory)

    # 2. the service: POST the ensemble job
    httpd, jobs = serve_sim(port=0)
    host, port = httpd.server_address[:2]
    base = f"http://{host}:{port}"
    ens_dir = os.path.join(work, "ens")
    spec = SimJobSpec(ckpt_dir=ens_dir, grid=GRID, n_per_column=NPC,
                      law=LAW, seeds=SEEDS, t_steps=T_STEPS,
                      segment_steps=SEG, record=True)
    job_id = post(base, "/v1/sim/jobs", spec.to_json())["job_id"]

    # 3. two concurrent cursor-streaming clients at different paces
    results = {}
    clients = [threading.Thread(target=stream_until_done,
                                args=(base, job_id, pause, results, name))
               for name, pause in (("fast", 0.05), ("slow", 0.4))]
    for c in clients:
        c.start()
    job = jobs.wait(job_id, timeout=600)
    for c in clients:
        c.join(timeout=120)
    assert job.status == "done", job.error
    res = job.result
    assert res["final_step"] == T_STEPS and res["members"] == len(SEEDS)
    assert res["compiled_steps"] == 1, res   # ONE compiled step for M members
    spooled = res["spooled_events"]
    assert spooled > 0
    for name in ("fast", "slow"):
        assert results[name]["total"] == spooled, (name, results, spooled)
        assert len(results[name]["per_member"]) == len(SEEDS)

    # 4. a different-seeds job shares the resident compiled step
    spec2 = SimJobSpec(ckpt_dir=os.path.join(work, "ens2"), grid=GRID,
                       n_per_column=NPC, law=LAW, seeds=(7, 8, 9),
                       t_steps=SEG, segment_steps=SEG, record=True)
    job2 = jobs.wait(post(base, "/v1/sim/jobs",
                          spec2.to_json())["job_id"], timeout=600)
    assert job2.status == "done", job2.error
    assert jobs.compiled_steps() == 1, jobs.compiled_steps()

    # 5. stitched per-member analyze reports (next to --out, so CI
    # ships them in the results artifact)
    out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    report_path = os.path.join(out_dir, "ensemble_analysis.json")
    payload = analyze_main(["--run", f"ens={ens_dir}",
                            "--out", report_path])
    labels = sorted(payload["runs"])
    assert labels == [f"ens/member_{m:03d}" for m in range(len(SEEDS))]
    assert all(r["t_steps"] == T_STEPS for r in payload["runs"].values())
    assert "comparison" in payload

    # 6. member 0's spool == the solo reference, byte for byte
    ens_digest = spk_digests(os.path.join(ens_dir, "spool"))
    member0 = {rel.split(os.sep, 1)[1]: h for rel, h in ens_digest.items()
               if rel.startswith("member_000" + os.sep)}
    assert member0 == solo_digest, (member0, solo_digest)

    httpd.shutdown()
    jobs.shutdown()

    summary = {
        "seeds": list(SEEDS), "t_steps": T_STEPS,
        "spooled_events": spooled,
        "clients": results,
        "compiled_steps": res["compiled_steps"],
        "server_compiled_steps_after_2_jobs": 1,
        "member0_matches_solo": True,
        "member_reports": labels,
        "rate_hz": res["rate_hz"],
    }
    d = os.path.dirname(args.out)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"ensemble service smoke OK: {spooled} events, "
          f"{len(SEEDS)} members, 1 compiled step, 2 clients -> "
          f"{args.out}")
    if args.workdir is None:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    main()
