"""CI guard for the committed time-per-phase breakdown.

Validates the repo-root ``BENCH_phase_breakdown.json`` (the committed,
cross-PR trajectory written by ``benchmarks.fig_phase_breakdown``)
without re-measuring -- wall-clock in CI is noisy, but the *structure*
of the committed artifact is exact:

  * schema: format marker, both connectivity laws, both sections
    (static + plastic), positive totals;
  * full phase coverage: every paper phase present, no extras --
    a phase silently dropped from the ladder would otherwise vanish
    from the breakdown while the file still "validates";
  * attribution closes: per-section phase fractions are sane and sum
    (with the reported residual) to 1 exactly -- the prefix-ablation
    telescoping invariant;
  * the unattributed residual (passthrough-scan overhead + timing
    noise) stays within ``[--min-residual, --max-residual]`` of total
    segment wall: a residual blowing past 10% means the ladder no
    longer brackets the real step (e.g. a new phase was added to the
    step body but not to the ladder).

Exit code 1 on any violation (the ``phase-guard`` CI check).
"""

import argparse
import json
import os
import sys

from .common import REPO_ROOT
from .fig_phase_breakdown import FORMAT, PLASTIC_PHASES, STATIC_PHASES

LAWS = ("gaussian", "exponential")
SECTIONS = {"static": STATIC_PHASES, "plastic": PLASTIC_PHASES}


def check(base: dict, max_residual: float, min_residual: float) -> list:
    errors = []
    if base.get("format") != FORMAT:
        errors.append(f"format {base.get('format')!r} != {FORMAT!r}")
        return errors
    laws = base.get("laws", {})
    for law in LAWS:
        if law not in laws:
            errors.append(f"missing law {law!r}")
            continue
        for section, want_phases in SECTIONS.items():
            b = laws[law].get(section)
            where = f"{law}/{section}"
            if b is None:
                errors.append(f"{where}: missing section")
                continue
            if not (b.get("total_s", 0) > 0):
                errors.append(f"{where}: total_s must be > 0")
                continue
            have = tuple(b.get("phases", {}))
            if set(have) != set(want_phases):
                errors.append(
                    f"{where}: phase coverage {sorted(have)} != "
                    f"{sorted(want_phases)}")
                continue
            frac_sum = 0.0
            for name, p in b["phases"].items():
                f = p.get("fraction")
                if f is None or not (0.0 <= f <= 1.0):
                    errors.append(f"{where}: phase {name} fraction "
                                  f"{f!r} outside [0, 1]")
                    continue
                frac_sum += f
            res = b.get("residual_fraction")
            if res is None:
                errors.append(f"{where}: missing residual_fraction")
                continue
            # telescoping invariant: residual is defined as total minus
            # attributed, so this closes exactly up to float rounding
            if abs(frac_sum + res - 1.0) > 1e-6:
                errors.append(
                    f"{where}: fractions ({frac_sum:.6f}) + residual "
                    f"({res:.6f}) do not sum to 1")
            if not (min_residual <= res <= max_residual):
                errors.append(
                    f"{where}: residual_fraction {res:.4f} outside "
                    f"[{min_residual}, {max_residual}] -- the phase "
                    "ladder no longer brackets the step")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT,
                                         "BENCH_phase_breakdown.json"))
    ap.add_argument("--max-residual", type=float, default=0.10,
                    help="max unattributed fraction of segment wall")
    ap.add_argument("--min-residual", type=float, default=-0.05,
                    help="floor (attribution noise can slightly "
                         "over-count on near-free phases)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    errors = check(base, args.max_residual, args.min_residual)
    for law in LAWS:
        for section in SECTIONS:
            b = base.get("laws", {}).get(law, {}).get(section)
            if not b or "phases" not in b:
                continue
            parts = " ".join(f"{n}={p.get('fraction', 0)*100:.1f}%"
                             for n, p in b["phases"].items())
            print(f"{law}/{section}: {parts} "
                  f"residual={b.get('residual_fraction', 0)*100:.1f}% ok")
    for e in errors:
        print(f"PHASE-GUARD VIOLATION: {e}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
