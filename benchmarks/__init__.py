"""Benchmarks: one per paper table/figure + roofline aggregation."""
