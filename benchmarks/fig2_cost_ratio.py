"""Paper Figure 2: simulation cost per synaptic event, exponential vs
Gaussian connectivity.  The paper measures 1.9-2.3x on its CPU cluster.

We measure the same metric -- elapsed / (simulated_sec x total_syn x
rate) -- on reduced grids (CPU container), in the event-driven mode
whose work is proportional to synaptic events, exactly like DPSNN.

Also emits ``BENCH_event_delivery.json``: a kernel-vs-XLA A/B of the
event-delivery hot path (fused Pallas pipeline vs pure-XLA
``deliver_events``) per connectivity law, plus a fused-vs-two-pass A/B
of the *plastic* step (one-launch delivery+LTD kernel vs the kernel
delivery + separate ``stdp_step`` fallback), so the perf trajectory of
the kernel layer is machine-readable across PRs.
"""

import time

import jax
import numpy as np

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_plasticity, init_sim_state,
                               simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.metrics import cost_per_synaptic_event
from repro.core.stdp import STDPParams

from .common import write_json


def measure(law, grid=8, n_per_col=60, steps=400, reps=3,
            use_kernels=False) -> dict:
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels=use_kernels)
    tabs = build_shard_tables(cfg)
    st = init_sim_state(cfg)
    fn = jax.jit(lambda s: simulate(s, tabs, cfg, steps))
    # warmup + state advance past transient
    st, _ = fn(st)
    jax.block_until_ready(st["t"])
    times, rates, events = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        st2, _ = fn(st)
        jax.block_until_ready(st2["t"])
        times.append(time.perf_counter() - t0)
        sp = float(st2["metrics"]["spikes"]) - float(st["metrics"]["spikes"])
        ev = float(st2["metrics"]["events"]) - float(st["metrics"]["events"])
        n_active = float(np.asarray(st2["active"]).sum())
        rates.append(sp / n_active / (steps * 1e-3))
        events.append(ev)
        st = st2
    elapsed = float(np.median(times))
    rate = float(np.mean(rates))
    n_syn = tabs["stats"]["n_synapses"]
    sim_s = steps * 1e-3
    return {
        "law": law.kind,
        "elapsed_s": elapsed,
        "rate_hz": rate,
        "synapses": n_syn,
        "recurrent_events": float(np.mean(events)),
        "cost_per_event": cost_per_synaptic_event(elapsed, sim_s, n_syn,
                                                  rate),
        "stencil": law.stencil_width,
    }


def measure_distributed(devices=8, grid=8, n_per_col=60, steps=300) -> dict:
    """Same metric with the REAL distributed engine (halo exchange over
    host devices) -- runs in a subprocess so the device count does not
    leak into the caller."""
    import json as _json
    import os
    import subprocess
    import sys
    code = f"""
import json
import jax
from repro.core.connectivity import gaussian_law, exponential_law
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.engine import EngineConfig
from repro.core.dist_engine import DistConfig, simulate
from repro.parallel.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
out = {{}}
for name, law in (("gaussian", gaussian_law()),
                  ("exponential", exponential_law())):
    dec = TileDecomposition(grid=ColumnGrid({grid}, {grid}, {n_per_col}),
                            tiles_y=4, tiles_x=2, radius=law.radius)
    cfg = DistConfig(engine=EngineConfig(decomp=dec, law=law))
    r = simulate(cfg, mesh, n_steps={steps}, timed=True)
    ev = max(r["events_timed"], 1)
    out[name] = dict(elapsed_s=r["elapsed_s"], events=ev,
                     rate_hz=r["rate_hz"],
                     cost_per_event=r["elapsed_s"] / ev)
print("JSON:" + json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        return {"error": r.stderr[-500:]}
    payload = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
    return _json.loads(payload[0][5:])


def analytic_fullscale(shards=1024, grid=96) -> dict:
    """TPU-target roofline model at the paper's scale."""
    from repro.core.grid import ColumnGrid, TileDecomposition
    from repro.core.metrics import step_time_model
    from repro.core.synapses import SynapseTableSpec
    import numpy as np
    ty = int(np.sqrt(shards))
    out = {}
    for name, law, rate in (("gaussian", gaussian_law(), 7.5),
                            ("exponential", exponential_law(), 35.0)):
        dec = TileDecomposition(grid=ColumnGrid(grid, grid), tiles_y=ty,
                                tiles_x=shards // ty, radius=law.radius)
        spec = SynapseTableSpec(decomp=dec, law=law)
        t = step_time_model(spec, rate)
        out[name] = t["step_s"] / t["events_per_step"]
    out["ratio"] = out["exponential"] / out["gaussian"]
    return out


def measure_pair(law, grid=8, n_per_col=60, steps=300, reps=3) -> dict:
    """Paired kernel-vs-XLA measurement of one law.

    Both arms reuse ONE table realization (the A/B times delivery, not
    setup) and are timed *interleaved*, one XLA segment then one kernel
    segment per rep, with the reported ratio the median of per-rep
    ratios: machine throughput drifts (shared containers swing ~2x over
    minutes), and pairing makes both arms sample the same machine state
    instead of comparing timings taken minutes apart.
    """
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfgs = {"xla": EngineConfig(decomp=d, law=law, use_kernels=False),
            "kernel": EngineConfig(decomp=d, law=law, use_kernels="auto")}
    tabs = build_shard_tables(cfgs["xla"])
    fns, sts = {}, {}
    for arm, cfg in cfgs.items():
        fns[arm] = jax.jit(lambda s, c=cfg: simulate(s, tabs, c, steps))
        st = init_sim_state(cfg)
        st, _ = fns[arm](st)          # warmup: compile + transient
        jax.block_until_ready(st["t"])
        sts[arm] = st
    times = {"xla": [], "kernel": []}
    ratios, rates, events = [], [], []
    for _ in range(reps):
        rep = {}
        for arm in ("xla", "kernel"):
            st = sts[arm]
            t0 = time.perf_counter()
            st2, _ = fns[arm](st)
            jax.block_until_ready(st2["t"])
            rep[arm] = time.perf_counter() - t0
            times[arm].append(rep[arm])
            if arm == "kernel":       # identical dynamics in both arms
                sp = (float(st2["metrics"]["spikes"])
                      - float(st["metrics"]["spikes"]))
                events.append(float(st2["metrics"]["events"])
                              - float(st["metrics"]["events"]))
                n_active = float(np.asarray(st2["active"]).sum())
                rates.append(sp / n_active / (steps * 1e-3))
            sts[arm] = st2
        ratios.append(rep["kernel"] / max(rep["xla"], 1e-12))
    n_syn = tabs["stats"]["n_synapses"]
    sim_s = steps * 1e-3
    rate = float(np.mean(rates))
    ab = {}
    for arm in ("xla", "kernel"):
        elapsed = float(np.median(times[arm]))
        ab[arm] = {"elapsed_s": elapsed, "rate_hz": rate,
                   "recurrent_events": float(np.mean(events)),
                   "cost_per_event": cost_per_synaptic_event(
                       elapsed, sim_s, n_syn, rate)}
    ab["kernel_vs_xla_wall_ratio"] = float(np.median(ratios))
    ab["per_rep_ratios"] = [round(r, 4) for r in ratios]
    return ab


def measure_plastic_pair(law, grid=8, n_per_col=60, steps=300,
                         segment_steps=50, reps=3) -> dict:
    """Paired fused-vs-two-pass A/B of the plastic step for one law.

    Both arms run the SAME engine config with kernels enabled; the
    baseline ("twopass") arm is traced with
    ``kernels.plastic_step.RING_N_MAX`` forced to 0, which routes
    ``plastic_delivery_stdp`` through its fallback -- the delivery
    kernel followed by the separate XLA ``stdp_step`` pass, i.e. the
    pre-fusion plastic step.  The fused arm is the one-launch
    delivery+LTD kernel.  Routing is resolved at trace time, so the
    monkeypatch is restored as soon as each arm has compiled.

    Each rep runs ``steps`` as a chain of ``segment_steps``-long jitted
    calls -- the shape the segmented ``SimDriver`` actually executes
    (the committed benchmark config is 50-step segments) -- with the
    arms interleaved per segment so both sample the same machine state
    (see ``measure_pair``); the reported ratio is the median of
    per-rep ratios.  ``gc.collect()`` is fenced between timed segments:
    interpret-mode pallas calls generate enough per-call garbage that a
    collection landing inside one arm's segment skews the pair by
    ~1.5x.  Both arms evolve bit-identical dynamics (asserted on the
    warmup segment's weights) -- the A/B times the step, not the
    physics.
    """
    import gc

    import repro.kernels.plastic_step as ps

    if steps % segment_steps:
        raise ValueError(f"steps={steps} must be a multiple of "
                         f"segment_steps={segment_steps}")
    n_seg = steps // segment_steps
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels="auto",
                       stdp=STDPParams())
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)

    def segment(st, tb, traces):
        aux_seg = dict(aux, traces=traces)
        (st, tb, traces), _ = simulate(st, tb, cfg, segment_steps,
                                       plasticity=aux_seg)
        return st, tb, traces

    orig = ps.RING_N_MAX
    fns, carries = {}, {}
    for arm in ("twopass", "fused"):
        ps.RING_N_MAX = 0 if arm == "twopass" else orig
        try:
            fn = jax.jit(segment)
            # warmup inside the patched region: jit traces (and locks
            # in the routing) on this first call; run a full rep worth
            # of segments so the timed window starts at steady state
            carry = fn(init_sim_state(cfg), tabs, aux["traces"])
            for _ in range(n_seg - 1):
                carry = fn(*carry)
            jax.block_until_ready(carry[0]["t"])
        finally:
            ps.RING_N_MAX = orig
        fns[arm], carries[arm] = fn, carry
    np.testing.assert_array_equal(
        np.asarray(carries["twopass"][1]["local"]["w"]),
        np.asarray(carries["fused"][1]["local"]["w"]),
        err_msg="fused plastic step diverged from the two-pass "
                "reference -- the A/B is only meaningful bit-identical")

    times = {"twopass": [], "fused": []}
    ratios = []
    for _ in range(reps):
        rep = {"twopass": 0.0, "fused": 0.0}
        for _ in range(n_seg):
            for arm in ("twopass", "fused"):
                gc.collect()
                st, tb, tr = carries[arm]
                t0 = time.perf_counter()
                out = fns[arm](st, tb, tr)
                jax.block_until_ready(out[0]["t"])
                rep[arm] += time.perf_counter() - t0
                carries[arm] = out
        for arm in ("twopass", "fused"):
            times[arm].append(rep[arm])
        ratios.append(rep["fused"] / max(rep["twopass"], 1e-12))
    out = {"steps": steps, "segment_steps": segment_steps,
           "n_synapses": int(tabs["stats"]["n_synapses"])}
    for arm in ("twopass", "fused"):
        elapsed = float(np.median(times[arm]))
        out[arm] = {"elapsed_s": elapsed,
                    "ms_per_step": round(elapsed / steps * 1e3, 3)}
    out["fused_vs_twopass_wall_ratio"] = float(np.median(ratios))
    out["per_rep_ratios"] = [round(r, 4) for r in ratios]
    return out


def bench_event_delivery(grid=8, n_per_col=60, steps=300,
                         update_root=True, include_plastic=True,
                         plastic_steps=300) -> dict:
    """Kernel-vs-XLA A/B of the event-delivery hot path per law.

    ``kernel`` routes LIF + delivery through the fused Pallas pipeline
    (compiled on TPU, interpret-mode on CPU -- identical code path);
    ``xla`` is the pure-XLA reference; timing is paired (see
    ``measure_pair``).  With ``include_plastic`` the payload gains a
    ``plastic`` section: the fused one-launch plastic step vs the
    two-pass fallback per law (see ``measure_plastic_pair``).  Written
    to ``results/BENCH_event_delivery.json`` (CI artifact) and --
    unless ``update_root=False`` -- to the repo-root copy, the
    committed cross-PR perf trajectory that
    ``benchmarks.delivery_guard`` gates regressions against.
    """
    out = {"backend": jax.default_backend(),
           "interpret": jax.default_backend() != "tpu",
           "grid": f"{grid}x{grid}x{n_per_col}", "steps": steps,
           "laws": {}}
    for name, law in (("gaussian", gaussian_law()),
                      ("exponential", exponential_law())):
        out["laws"][name] = measure_pair(law, grid=grid,
                                         n_per_col=n_per_col, steps=steps)
    if include_plastic:
        out["plastic"] = {"steps": plastic_steps, "laws": {}}
        for name, law in (("gaussian", gaussian_law()),
                          ("exponential", exponential_law())):
            out["plastic"]["laws"][name] = measure_plastic_pair(
                law, grid=grid, n_per_col=n_per_col, steps=plastic_steps)
    write_json("BENCH_event_delivery.json", out, also_root=update_root)
    return out


def run_bench(grid=8, steps=400, with_distributed=True) -> dict:
    g = measure(gaussian_law(), grid=grid, steps=steps)
    e = measure(exponential_law(), grid=grid, steps=steps)
    out = {
        "gaussian": g, "exponential": e,
        "cost_ratio_single_shard": e["cost_per_event"]
        / g["cost_per_event"],
        "wall_ratio": e["elapsed_s"] / g["elapsed_s"],
        "analytic_tpu_1024shards": analytic_fullscale(),
        "paper_range": [1.9, 2.3],
        "note": (
            "The paper's 1.9-2.3x per-event penalty for exponential "
            "connectivity is a CPU/MPI substrate cost (per-message "
            "overhead + irregular event queues degrade with range). "
            "The TPU-native redesign (halo collectives + fixed-capacity "
            "tables) makes per-event cost range-independent, so the "
            "ratio drops below 1: longer-range connectivity amortizes "
            "fixed per-neuron work over 2.4x more events. Same metric, "
            "opposite sign -- a substrate win the paper's own scaling "
            "question makes visible."),
    }
    if with_distributed:
        d = measure_distributed(grid=grid, steps=steps)
        out["distributed_8dev"] = d
        if "gaussian" in d:
            out["cost_ratio_distributed"] = (
                d["exponential"]["cost_per_event"]
                / d["gaussian"]["cost_per_event"])
    # update_root=False: the Fig-2 run reports the A/B but must not
    # silently rewrite the committed regression-guard baseline --
    # refreshing that is an explicit bench_event_delivery() run.
    # include_plastic=False: the plastic A/B belongs to the guard
    # trajectory, not the Fig-2 cost-ratio story
    out["event_delivery_ab"] = bench_event_delivery(
        grid=grid, update_root=False, include_plastic=False)
    write_json("fig2.json", out)
    return out


def main():
    out = run_bench()
    g, e = out["gaussian"], out["exponential"]
    print(f"gaussian:    cost/event {g['cost_per_event']:.3e} s "
          f"(rate {g['rate_hz']:.1f} Hz, {g['synapses']} syn)")
    print(f"exponential: cost/event {e['cost_per_event']:.3e} s "
          f"(rate {e['rate_hz']:.1f} Hz, {e['synapses']} syn)")
    print(f"cost ratio exp/gauss (single shard): "
          f"{out['cost_ratio_single_shard']:.2f}")
    if "cost_ratio_distributed" in out:
        print(f"cost ratio exp/gauss (8-device halo): "
              f"{out['cost_ratio_distributed']:.2f}")
    for name, ab in out["event_delivery_ab"]["laws"].items():
        print(f"{name}: kernel/xla wall ratio "
              f"{ab['kernel_vs_xla_wall_ratio']:.2f} "
              f"(kernel {ab['kernel']['elapsed_s']:.3f}s, "
              f"xla {ab['xla']['elapsed_s']:.3f}s)")
    print(f"cost ratio (analytic TPU @1024 shards): "
          f"{out['analytic_tpu_1024shards']['ratio']:.2f}")
    print(f"paper (CPU/MPI cluster): 1.9-2.3  -- see note in fig2.json")


if __name__ == "__main__":
    main()
