"""Paper Figure 2: simulation cost per synaptic event, exponential vs
Gaussian connectivity.  The paper measures 1.9-2.3x on its CPU cluster.

We measure the same metric -- elapsed / (simulated_sec x total_syn x
rate) -- on reduced grids (CPU container), in the event-driven mode
whose work is proportional to synaptic events, exactly like DPSNN.

Also emits ``BENCH_event_delivery.json``: a kernel-vs-XLA A/B of the
event-delivery hot path (fused Pallas pipeline vs pure-XLA
``deliver_events``) per connectivity law, so the perf trajectory of the
kernel layer is machine-readable across PRs.
"""

import time

import jax
import numpy as np

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               init_sim_state, run)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.metrics import cost_per_synaptic_event

from .common import write_json


def measure(law, grid=8, n_per_col=60, steps=400, reps=3,
            use_kernels=False) -> dict:
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels=use_kernels)
    tabs = build_shard_tables(cfg)
    st = init_sim_state(cfg)
    fn = jax.jit(lambda s: run(s, tabs, cfg, steps))
    # warmup + state advance past transient
    st, _ = fn(st)
    jax.block_until_ready(st["t"])
    times, rates, events = [], [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        st2, _ = fn(st)
        jax.block_until_ready(st2["t"])
        times.append(time.perf_counter() - t0)
        sp = float(st2["metrics"]["spikes"]) - float(st["metrics"]["spikes"])
        ev = float(st2["metrics"]["events"]) - float(st["metrics"]["events"])
        n_active = float(np.asarray(st2["active"]).sum())
        rates.append(sp / n_active / (steps * 1e-3))
        events.append(ev)
        st = st2
    elapsed = float(np.median(times))
    rate = float(np.mean(rates))
    n_syn = tabs["stats"]["n_synapses"]
    sim_s = steps * 1e-3
    return {
        "law": law.kind,
        "elapsed_s": elapsed,
        "rate_hz": rate,
        "synapses": n_syn,
        "recurrent_events": float(np.mean(events)),
        "cost_per_event": cost_per_synaptic_event(elapsed, sim_s, n_syn,
                                                  rate),
        "stencil": law.stencil_width,
    }


def measure_distributed(devices=8, grid=8, n_per_col=60, steps=300) -> dict:
    """Same metric with the REAL distributed engine (halo exchange over
    host devices) -- runs in a subprocess so the device count does not
    leak into the caller."""
    import json as _json
    import os
    import subprocess
    import sys
    code = f"""
import json
import jax
from repro.core.connectivity import gaussian_law, exponential_law
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.engine import EngineConfig
from repro.core.dist_engine import DistConfig, simulate
from repro.parallel.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
out = {{}}
for name, law in (("gaussian", gaussian_law()),
                  ("exponential", exponential_law())):
    dec = TileDecomposition(grid=ColumnGrid({grid}, {grid}, {n_per_col}),
                            tiles_y=4, tiles_x=2, radius=law.radius)
    cfg = DistConfig(engine=EngineConfig(decomp=dec, law=law))
    r = simulate(cfg, mesh, n_steps={steps}, timed=True)
    ev = max(r["events_timed"], 1)
    out[name] = dict(elapsed_s=r["elapsed_s"], events=ev,
                     rate_hz=r["rate_hz"],
                     cost_per_event=r["elapsed_s"] / ev)
print("JSON:" + json.dumps(out))
"""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=1800)
    if r.returncode != 0:
        return {"error": r.stderr[-500:]}
    payload = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
    return _json.loads(payload[0][5:])


def analytic_fullscale(shards=1024, grid=96) -> dict:
    """TPU-target roofline model at the paper's scale."""
    from repro.core.grid import ColumnGrid, TileDecomposition
    from repro.core.metrics import step_time_model
    from repro.core.synapses import SynapseTableSpec
    import numpy as np
    ty = int(np.sqrt(shards))
    out = {}
    for name, law, rate in (("gaussian", gaussian_law(), 7.5),
                            ("exponential", exponential_law(), 35.0)):
        dec = TileDecomposition(grid=ColumnGrid(grid, grid), tiles_y=ty,
                                tiles_x=shards // ty, radius=law.radius)
        spec = SynapseTableSpec(decomp=dec, law=law)
        t = step_time_model(spec, rate)
        out[name] = t["step_s"] / t["events_per_step"]
    out["ratio"] = out["exponential"] / out["gaussian"]
    return out


def measure_pair(law, grid=8, n_per_col=60, steps=300, reps=3) -> dict:
    """Paired kernel-vs-XLA measurement of one law.

    Both arms reuse ONE table realization (the A/B times delivery, not
    setup) and are timed *interleaved*, one XLA segment then one kernel
    segment per rep, with the reported ratio the median of per-rep
    ratios: machine throughput drifts (shared containers swing ~2x over
    minutes), and pairing makes both arms sample the same machine state
    instead of comparing timings taken minutes apart.
    """
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfgs = {"xla": EngineConfig(decomp=d, law=law, use_kernels=False),
            "kernel": EngineConfig(decomp=d, law=law, use_kernels="auto")}
    tabs = build_shard_tables(cfgs["xla"])
    fns, sts = {}, {}
    for arm, cfg in cfgs.items():
        fns[arm] = jax.jit(lambda s, c=cfg: run(s, tabs, c, steps))
        st = init_sim_state(cfg)
        st, _ = fns[arm](st)          # warmup: compile + transient
        jax.block_until_ready(st["t"])
        sts[arm] = st
    times = {"xla": [], "kernel": []}
    ratios, rates, events = [], [], []
    for _ in range(reps):
        rep = {}
        for arm in ("xla", "kernel"):
            st = sts[arm]
            t0 = time.perf_counter()
            st2, _ = fns[arm](st)
            jax.block_until_ready(st2["t"])
            rep[arm] = time.perf_counter() - t0
            times[arm].append(rep[arm])
            if arm == "kernel":       # identical dynamics in both arms
                sp = (float(st2["metrics"]["spikes"])
                      - float(st["metrics"]["spikes"]))
                events.append(float(st2["metrics"]["events"])
                              - float(st["metrics"]["events"]))
                n_active = float(np.asarray(st2["active"]).sum())
                rates.append(sp / n_active / (steps * 1e-3))
            sts[arm] = st2
        ratios.append(rep["kernel"] / max(rep["xla"], 1e-12))
    n_syn = tabs["stats"]["n_synapses"]
    sim_s = steps * 1e-3
    rate = float(np.mean(rates))
    ab = {}
    for arm in ("xla", "kernel"):
        elapsed = float(np.median(times[arm]))
        ab[arm] = {"elapsed_s": elapsed, "rate_hz": rate,
                   "recurrent_events": float(np.mean(events)),
                   "cost_per_event": cost_per_synaptic_event(
                       elapsed, sim_s, n_syn, rate)}
    ab["kernel_vs_xla_wall_ratio"] = float(np.median(ratios))
    ab["per_rep_ratios"] = [round(r, 4) for r in ratios]
    return ab


def bench_event_delivery(grid=8, n_per_col=60, steps=300,
                         update_root=True) -> dict:
    """Kernel-vs-XLA A/B of the event-delivery hot path per law.

    ``kernel`` routes LIF + delivery through the fused Pallas pipeline
    (compiled on TPU, interpret-mode on CPU -- identical code path);
    ``xla`` is the pure-XLA reference; timing is paired (see
    ``measure_pair``).  Written to
    ``results/BENCH_event_delivery.json`` (CI artifact) and -- unless
    ``update_root=False`` -- to the repo-root copy, the committed
    cross-PR perf trajectory that ``benchmarks.delivery_guard`` gates
    regressions against.
    """
    out = {"backend": jax.default_backend(),
           "interpret": jax.default_backend() != "tpu",
           "grid": f"{grid}x{grid}x{n_per_col}", "steps": steps,
           "laws": {}}
    for name, law in (("gaussian", gaussian_law()),
                      ("exponential", exponential_law())):
        out["laws"][name] = measure_pair(law, grid=grid,
                                         n_per_col=n_per_col, steps=steps)
    write_json("BENCH_event_delivery.json", out, also_root=update_root)
    return out


def run_bench(grid=8, steps=400, with_distributed=True) -> dict:
    g = measure(gaussian_law(), grid=grid, steps=steps)
    e = measure(exponential_law(), grid=grid, steps=steps)
    out = {
        "gaussian": g, "exponential": e,
        "cost_ratio_single_shard": e["cost_per_event"]
        / g["cost_per_event"],
        "wall_ratio": e["elapsed_s"] / g["elapsed_s"],
        "analytic_tpu_1024shards": analytic_fullscale(),
        "paper_range": [1.9, 2.3],
        "note": (
            "The paper's 1.9-2.3x per-event penalty for exponential "
            "connectivity is a CPU/MPI substrate cost (per-message "
            "overhead + irregular event queues degrade with range). "
            "The TPU-native redesign (halo collectives + fixed-capacity "
            "tables) makes per-event cost range-independent, so the "
            "ratio drops below 1: longer-range connectivity amortizes "
            "fixed per-neuron work over 2.4x more events. Same metric, "
            "opposite sign -- a substrate win the paper's own scaling "
            "question makes visible."),
    }
    if with_distributed:
        d = measure_distributed(grid=grid, steps=steps)
        out["distributed_8dev"] = d
        if "gaussian" in d:
            out["cost_ratio_distributed"] = (
                d["exponential"]["cost_per_event"]
                / d["gaussian"]["cost_per_event"])
    # update_root=False: the Fig-2 run reports the A/B but must not
    # silently rewrite the committed regression-guard baseline --
    # refreshing that is an explicit bench_event_delivery() run
    out["event_delivery_ab"] = bench_event_delivery(grid=grid,
                                                    update_root=False)
    write_json("fig2.json", out)
    return out


def main():
    out = run_bench()
    g, e = out["gaussian"], out["exponential"]
    print(f"gaussian:    cost/event {g['cost_per_event']:.3e} s "
          f"(rate {g['rate_hz']:.1f} Hz, {g['synapses']} syn)")
    print(f"exponential: cost/event {e['cost_per_event']:.3e} s "
          f"(rate {e['rate_hz']:.1f} Hz, {e['synapses']} syn)")
    print(f"cost ratio exp/gauss (single shard): "
          f"{out['cost_ratio_single_shard']:.2f}")
    if "cost_ratio_distributed" in out:
        print(f"cost ratio exp/gauss (8-device halo): "
              f"{out['cost_ratio_distributed']:.2f}")
    for name, ab in out["event_delivery_ab"]["laws"].items():
        print(f"{name}: kernel/xla wall ratio "
              f"{ab['kernel_vs_xla_wall_ratio']:.2f} "
              f"(kernel {ab['kernel']['elapsed_s']:.3f}s, "
              f"xla {ab['xla']['elapsed_s']:.3f}s)")
    print(f"cost ratio (analytic TPU @1024 shards): "
          f"{out['analytic_tpu_1024shards']['ratio']:.2f}")
    print(f"paper (CPU/MPI cluster): 1.9-2.3  -- see note in fig2.json")


if __name__ == "__main__":
    main()
