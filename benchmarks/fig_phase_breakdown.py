"""Paper-style time-per-phase breakdown of the compiled step, per law.

DPSNN's companion scaling study (arXiv:1511.09325) reports not just
total wall-clock but *time per phase* -- spike delivery vs
synaptic/neural dynamics vs exchange -- and shows how the exponential
connectivity law shifts cost between phases.  The host-side span
tracer (``repro.obs.telemetry``) cannot see inside the compiled
segment, so this harness attributes device cost by **prefix
ablation**: for each connectivity law it times a ladder of jitted
scans, each running one more phase of the step body than the last,
under identical carry threading.  Phase cost is the difference between
adjacent rungs, so the phases telescope: their sum plus the
``residual`` (rung 0: the passthrough scan, i.e. scan/carry overhead
plus timing noise) equals the full step's wall by construction.

Ladders (pure-XLA path, ``use_kernels=False``, so the attribution is
of the reference step, not of interpret-mode Pallas overhead):

  * **static** -- passthrough -> +external_drive -> +neuron_update
    (LIF/SFA + ring-slot consume) -> +spike_delivery (the full static
    step) -> +recorder_compaction (device-side spike recording);
  * **plastic** -- passthrough -> +external_drive -> +neuron_update ->
    +spike_delivery (delivery through the live carried weights, no
    update) -> +stdp (the full plastic body: delivery + STDP weight /
    trace update).

Commits ``BENCH_phase_breakdown.json`` (repo root: the cross-PR
trajectory; ``results/``: the per-run CI artifact).
``benchmarks.phase_guard`` gates the committed file's schema, phase
coverage and residual bound in CI.
"""

import argparse
import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectivity import exponential_law, gaussian_law
from repro.core.engine import (EngineConfig, build_shard_tables,
                               deliver_event_tiers, external_drive,
                               init_plasticity, init_sim_state,
                               plastic_delivery_stdp, step)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.neuron import lif_sfa_step
from repro.core.stdp import STDPParams
from repro.core.synapses import with_local_tier
from repro.obs.record import (init_recorder_state, record_step,
                              recorder_spec, tile_gid_map)

from .common import write_json

FORMAT = "dpsnn-phase-breakdown-v1"
STATIC_PHASES = ("external_drive", "neuron_update", "spike_delivery",
                 "recorder_compaction")
PLASTIC_PHASES = ("external_drive", "neuron_update", "spike_delivery",
                  "stdp")


def _timed_scan(body, carry, steps: int, reps: int) -> float:
    """Median wall of a jitted ``steps``-long scan of ``body``.

    The carry evolves across reps (the timed window samples steady-state
    dynamics, not the cold start); ``gc.collect()`` is fenced before
    each rep so a collection never lands inside a timed window."""
    fn = jax.jit(lambda c: jax.lax.scan(body, c, None, length=steps))
    carry, out = fn(carry)                    # compile + transient
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        gc.collect()
        t0 = time.perf_counter()
        carry, out = fn(carry)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _breakdown(names, ladder_times, steps: int) -> dict:
    """Adjacent-rung differences -> per-phase wall + fraction.

    ``ladder_times[0]`` is the passthrough rung: it becomes the
    reported residual (scan/carry overhead no phase owns).  Negative
    differences (timing noise on a near-free phase) clamp to zero; the
    residual absorbs the clamp so fractions still sum to ~1."""
    total = ladder_times[-1]
    phases = {}
    for name, lo, hi in zip(names, ladder_times[:-1], ladder_times[1:]):
        wall = max(hi - lo, 0.0)
        phases[name] = {"wall_s": wall, "fraction": wall / total}
    attributed = sum(p["wall_s"] for p in phases.values())
    return {
        "total_s": total,
        "ms_per_step": total / steps * 1e3,
        "steps_per_s": steps / total,
        "scan_overhead_s": ladder_times[0],
        "phases": phases,
        "residual_s": total - attributed,
        "residual_fraction": (total - attributed) / total,
    }


def measure_static(law, grid=8, n_per_col=60, steps=100, reps=3) -> dict:
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels=False)
    tabs = build_shard_tables(cfg)
    n_local = cfg.spec().n_local
    rspec = recorder_spec(cfg, steps)
    gids = jnp.asarray(tile_gid_map(cfg.decomp, 0, 0))

    # every rung repeats all previous rungs' work verbatim; outputs are
    # consumed (summed per step) so XLA cannot dead-code a phase away
    def passthrough(st, _):
        key, _k_ext = jax.random.split(st["rng"])
        i_now = st["i_ring"][st["t"] % cfg.d_ring]
        return dict(st, rng=key, t=st["t"] + 1), jnp.sum(i_now)

    def plus_drive(st, _):
        key, k_ext = jax.random.split(st["rng"])
        i_now = st["i_ring"][st["t"] % cfg.d_ring] \
            + external_drive(k_ext, n_local, cfg)
        return dict(st, rng=key, t=st["t"] + 1), jnp.sum(i_now)

    def plus_neuron(st, _):
        key, k_ext = jax.random.split(st["rng"])
        slot = st["t"] % cfg.d_ring
        i_now = st["i_ring"][slot] + external_drive(k_ext, n_local, cfg)
        neuron, spikes = lif_sfa_step(st["neuron"], i_now, cfg.lif,
                                      st["active"])
        i_ring = st["i_ring"].at[slot].set(0.0)
        return dict(st, neuron=neuron, i_ring=i_ring, rng=key,
                    t=st["t"] + 1), jnp.sum(spikes)

    def plus_delivery(st, _):                 # the full static step
        new_state, spikes = step(st, tabs, cfg, halo_band_spikes=None)
        return new_state, jnp.sum(spikes)

    def plus_recorder(carry, _):
        st, rec = carry
        new_state, spikes = step(st, tabs, cfg, halo_band_spikes=None)
        rec = record_step(rec, spikes, gids, st["t"], rspec)
        return (new_state, rec), jnp.sum(spikes)

    st0 = init_sim_state(cfg)
    times = [
        _timed_scan(passthrough, st0, steps, reps),
        _timed_scan(plus_drive, st0, steps, reps),
        _timed_scan(plus_neuron, st0, steps, reps),
        _timed_scan(plus_delivery, st0, steps, reps),
        _timed_scan(plus_recorder,
                    (st0, init_recorder_state(rspec)), steps, reps),
    ]
    out = _breakdown(STATIC_PHASES, times, steps)
    out["n_synapses"] = int(tabs["stats"]["n_synapses"])
    return out


def measure_plastic(law, grid=8, n_per_col=60, steps=100, reps=3) -> dict:
    d = TileDecomposition(grid=ColumnGrid(grid, grid, n_per_col),
                          tiles_y=1, tiles_x=1, radius=law.radius)
    cfg = EngineConfig(decomp=d, law=law, use_kernels=False,
                       stdp=STDPParams())
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    spec = cfg.spec()
    n_local = spec.n_local
    plan = spec.delivery_plan(getattr(tabs, "storage", None))[:1]
    masks = aux["masks"][:1]
    traces0 = {"x_pre": aux["traces"]["x_pre"][:1],
               "x_post": aux["traces"]["x_post"]}

    # same ladder discipline, with the plastic carry (state, tables,
    # traces) threaded through every rung so rung-to-rung differences
    # isolate phases, not carry-size changes
    def passthrough(carry, _):
        st, tb, tr = carry
        key, _k_ext = jax.random.split(st["rng"])
        i_now = st["i_ring"][st["t"] % cfg.d_ring]
        return (dict(st, rng=key, t=st["t"] + 1), tb, tr), jnp.sum(i_now)

    def plus_drive(carry, _):
        st, tb, tr = carry
        key, k_ext = jax.random.split(st["rng"])
        i_now = st["i_ring"][st["t"] % cfg.d_ring] \
            + external_drive(k_ext, n_local, cfg)
        return (dict(st, rng=key, t=st["t"] + 1), tb, tr), jnp.sum(i_now)

    def plus_neuron(carry, _):
        st, tb, tr = carry
        new_state, spikes = step(st, tb, cfg, halo_band_spikes=None,
                                 deliver=False)
        return (new_state, tb, tr), jnp.sum(spikes)

    def plus_delivery(carry, _):
        # delivery through the live carried weights, no weight update:
        # the next rung's difference is the marginal STDP cost
        st, tb, tr = carry
        slot = st["t"] % cfg.d_ring
        new_state, spikes = step(st, tb, cfg, halo_band_spikes=None,
                                 deliver=False)
        i_ring, ev, dr = deliver_event_tiers(
            {"local": tb["local"], "halo": []}, spikes, [], spec,
            new_state["i_ring"], slot, cfg.d_ring, False, plan=plan)
        m = new_state["metrics"]
        new_state = dict(new_state, i_ring=i_ring,
                         metrics=dict(m, events=m["events"] + ev,
                                      dropped=m["dropped"] + dr))
        return (new_state, tb, tr), jnp.sum(spikes)

    def plus_stdp(carry, _):                  # the full plastic body
        st, tb, tr = carry
        slot = st["t"] % cfg.d_ring
        new_state, spikes = step(st, tb, cfg, halo_band_spikes=None,
                                 deliver=False)
        i_ring, tiers, tr, ev, dr = plastic_delivery_stdp(
            [tb["local"]], masks, aux["inv"], tr, [spikes], spec,
            new_state["i_ring"], slot, cfg, plan)
        m = new_state["metrics"]
        new_state = dict(new_state, i_ring=i_ring,
                         metrics=dict(m, events=m["events"] + ev,
                                      dropped=m["dropped"] + dr))
        tb = with_local_tier(tb, tiers[0])
        return (new_state, tb, tr), jnp.sum(spikes)

    carry0 = (init_sim_state(cfg), tabs, traces0)
    times = [
        _timed_scan(passthrough, carry0, steps, reps),
        _timed_scan(plus_drive, carry0, steps, reps),
        _timed_scan(plus_neuron, carry0, steps, reps),
        _timed_scan(plus_delivery, carry0, steps, reps),
        _timed_scan(plus_stdp, carry0, steps, reps),
    ]
    out = _breakdown(PLASTIC_PHASES, times, steps)
    out["n_synapses"] = int(tabs["stats"]["n_synapses"])
    return out


def run_bench(grid=8, n_per_col=60, steps=100, reps=3,
              update_root=True) -> dict:
    out = {
        "format": FORMAT,
        "grid": f"{grid}x{grid}x{n_per_col}",
        "steps": steps, "reps": reps,
        "backend": jax.default_backend(),
        "use_kernels": False,
        "note": ("Prefix-ablation phase attribution of the jitted step "
                 "(pure-XLA path): phase cost = wall difference between "
                 "adjacent scan ladder rungs, so phases + residual "
                 "(passthrough scan overhead + timing noise) telescope "
                 "to the full step's wall by construction."),
        "laws": {},
    }
    for name, law in (("gaussian", gaussian_law()),
                      ("exponential", exponential_law())):
        out["laws"][name] = {
            "static": measure_static(law, grid=grid, n_per_col=n_per_col,
                                     steps=steps, reps=reps),
            "plastic": measure_plastic(law, grid=grid,
                                       n_per_col=n_per_col,
                                       steps=steps, reps=reps),
        }
    write_json("BENCH_phase_breakdown.json", out, also_root=update_root)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", type=int, default=8)
    ap.add_argument("--n-per-col", type=int, default=60)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-update-root", action="store_true",
                    help="write results/ only; keep the committed "
                         "repo-root trajectory file untouched")
    args = ap.parse_args(argv)
    out = run_bench(grid=args.grid, n_per_col=args.n_per_col,
                    steps=args.steps, reps=args.reps,
                    update_root=not args.no_update_root)
    for law, sections in out["laws"].items():
        for section, b in sections.items():
            parts = " ".join(
                f"{n}={p['fraction']*100:.1f}%"
                for n, p in b["phases"].items())
            print(f"{law}/{section}: {b['ms_per_step']:.2f} ms/step  "
                  f"{parts}  residual={b['residual_fraction']*100:.1f}%")


if __name__ == "__main__":
    main()
