"""Aggregate the dry-run JSONs into the roofline report (section
Roofline of EXPERIMENTS.md reads this).  Single-pod mesh only, per the
assignment; the multi-pod numbers prove pod-axis sharding separately.
"""

import glob
import json
import os

from .common import RESULTS, write_json

DRYRUN = os.path.join(RESULTS, "dryrun")


def load_cells(mesh="single"):
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        d = json.load(open(f))
        if not d.get("ok"):
            rows.append({"cell": d["cell"], "ok": False,
                         "error": d.get("error", "")[:100]})
            continue
        r = d["roofline"]
        k = d.get("kernelized") or {}
        mem_flash = k.get("memory_s_flash", r["memory_s"])
        step_flash = max(r["compute_s"], mem_flash) + r["collective_s"]
        chips = r["chips"]
        from repro.perf.roofline import HW
        rl_flash = (r["model_flops"] / (step_flash * chips)
                    / HW().peak_flops if step_flash > 0 else 0.0)
        rows.append({
            "cell": d["cell"], "ok": True,
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "memory_s_flash": mem_flash,
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "step_time_s": r["step_time_s"],
            "step_time_s_flash": step_flash,
            "model_flops": r["model_flops"],
            "hlo_flops_per_device": r["hlo_flops"],
            "useful_frac": r["useful_frac"],
            "roofline_frac": r["roofline_frac"],
            "roofline_frac_flash": rl_flash,
            "peak_gb": (d["memory"]["peak_bytes"] or 0) / 2 ** 30,
            "state_gb": (d["memory"].get(
                "input_state_bytes_per_device", 0)) / 2 ** 30,
            "coll_by_kind": r["coll_by_kind"],
        })
    return rows


def improvement_note(r) -> str:
    """One sentence: what would move the dominant term down (section
    Roofline requirement).  Derived from the cell's own numbers."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    if arch.startswith("snn-"):
        return ("right-size event-compaction capacity to the observed "
                "rate (x2.5, demonstrated by variant snn_tight_caps) and "
                "fuse LIF+ring via the Pallas lif_step kernel")
    if dom == "collective":
        if "kimi" in arch and shape == "train_4k":
            return ("fewer grad-accumulation loops cut FSDP regathers "
                    "(micro2: 2.4x, demonstrated); next: sequence-sharded "
                    "MoE combine turns the psum into a reduce-scatter")
        if shape.startswith("decode") or shape.startswith("long"):
            return ("decode is latency-bound on TP all-reduces of tiny "
                    "activations: batch more requests per step or shrink "
                    "TP degree for small models")
        return "overlap the per-layer collectives with the next block's compute"
    if dom == "memory":
        flash_gain = r["memory_s"] - r["memory_s_flash"]
        if flash_gain > 0.05 * r["memory_s"]:
            return ("lower attention through the Pallas flash kernel "
                    "(VMEM-resident chunks; credited column) and pad "
                    "heads to the model axis where not divisible")
        if "mamba" in arch:
            return ("reformulate the selective scan as the SSD "
                    "block-matmul form so the (B,C,d_inner,N) discretized "
                    "tensors never round-trip HBM")
        if r["useful_frac"] < 0.1:
            return ("shard the replicated attention (pad heads to 16 -- "
                    "11x on qwen2-1.5b prefill, demonstrated) ")
        return ("fuse residual/norm chains and keep bf16 end-to-end to "
                "cut activation round-trips")
    return "increase per-chip batch until memory-bound, then see memory note"


def to_markdown(rows) -> str:
    lines = [
        "| cell | dominant | compute_s | memory_s | mem_flash | "
        "collective_s | useful | roofline(flash) | peakGB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r["ok"]:
            lines.append(
                f"| {r['cell']} | FAILED {r['error']} | | | | | | | |")
            continue
        lines.append(
            f"| {r['cell']} | {r['dominant']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['memory_s_flash']:.4f} | "
            f"{r['collective_s']:.4f} | "
            f"{r['useful_frac']:.3f} | {r['roofline_frac_flash']:.4f} | "
            f"{r['peak_gb']:.2f} |")
    return "\n".join(lines)


def main():
    rows = load_cells("single")
    for r in rows:
        if r.get("ok"):
            r["improvement"] = improvement_note(r)
    write_json("roofline.json", {"rows": rows})
    md = to_markdown(rows)
    notes = "\n".join(
        f"* **{r['cell']}** ({r['dominant']}-bound): {r['improvement']}"
        for r in rows if r.get("ok"))
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write(md + "\n\n### What would move the dominant term\n\n"
                + notes + "\n")
    print(md)


if __name__ == "__main__":
    main()
