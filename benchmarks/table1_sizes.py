"""Paper Table 1: problem sizes (neurons / recurrent / total synapses).

Reproduced exactly from the connectivity laws with edge effects -- the
check that our synapse-generation rules ARE the paper's.
"""

from repro.core.connectivity import (exponential_law, gaussian_law,
                                     expected_synapse_counts)

from .common import write_json

PAPER = {  # grid -> law -> (recurrent G, total G)
    (24, 24): {"gaussian": (0.9, 1.2), "exponential": (1.5, 1.8)},
    (48, 48): {"gaussian": (3.5, 5.0), "exponential": (5.9, 7.4)},
    (96, 96): {"gaussian": (14.2, 20.4), "exponential": (23.4, 29.6)},
}


def run() -> dict:
    rows = []
    for grid, laws in PAPER.items():
        for law_name, (p_rec, p_tot) in laws.items():
            law = gaussian_law() if law_name == "gaussian" else \
                exponential_law()
            c = expected_synapse_counts(law, *grid)
            rows.append({
                "grid": f"{grid[0]}x{grid[1]}",
                "law": law_name,
                "neurons_M": round(c["neurons"] / 1e6, 2),
                "recurrent_G": round(c["recurrent_synapses"] / 1e9, 2),
                "total_G": round(c["total_synapses"] / 1e9, 2),
                "paper_recurrent_G": p_rec,
                "paper_total_G": p_tot,
                "recurrent_err": round(abs(
                    c["recurrent_synapses"] / 1e9 - p_rec) / p_rec, 3),
                "remote_per_neuron": round(c["remote_per_neuron"], 1),
            })
    out = {"rows": rows,
           "max_recurrent_err": max(r["recurrent_err"] for r in rows)}
    write_json("table1.json", out)
    return out


def main():
    out = run()
    print("grid,law,neurons_M,recurrent_G(paper),total_G(paper),err")
    for r in out["rows"]:
        print(f"{r['grid']},{r['law']},{r['neurons_M']},"
              f"{r['recurrent_G']}({r['paper_recurrent_G']}),"
              f"{r['total_G']}({r['paper_total_G']}),{r['recurrent_err']}")
    print(f"max recurrent error vs paper: {out['max_recurrent_err']:.1%}")


if __name__ == "__main__":
    main()
