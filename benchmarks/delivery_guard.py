"""CI regression guard for the event-delivery and plastic-step kernels.

Re-measures the CPU-interpret kernel-vs-XLA A/B
(``benchmarks.fig2_cost_ratio.bench_event_delivery``) and fails (exit
code 1) if either law's ``kernel_vs_xla_wall_ratio`` regresses by more
than ``--tol`` (default 25%) against the committed repo-root
``BENCH_event_delivery.json`` trajectory.  When the baseline carries a
``plastic`` section, the fused-vs-two-pass plastic-step ratio
(``measure_plastic_pair``: one-launch delivery+LTD kernel vs kernel
delivery + separate XLA ``stdp_step``) is gated the same way -- the
committed ratio is steady-state parity (~0.98; the interpreter prices
ops, not the memory traffic the fusion saves, and the early
low-activity window's 0.59-0.68 is not stable enough to gate), so a
>25% regression means the one-launch step got materially *worse* than
running delivery and STDP separately.

By default the measurement replicates the baseline's own grid and step
count (read from the JSON): the wall ratio is NOT step-count-invariant
-- the kernel arm's cost tracks the firing rate over the measured
window while the XLA arm streams the full capacity head-room regardless
-- so comparing against the committed number is only meaningful at the
committed configuration.  Kept OUT of the tier-1 test job so the
``pytest -m "not slow"`` gate stays under two minutes.

Baseline hygiene: even with paired timing (``measure_pair`` interleaves
the arms so both sample the same machine state) the measured ratio
spreads noticeably on shared containers -- observed gaussian spread
0.7-2.6 across quiet runs (verified container-state, not code: the
same commit measures 1.6x and 2.5x weeks apart), partly a per-process
bimodality of the XLA arm's compiled artifact (~14 s vs ~23 s for
identical work).  Commit
baselines from the HIGH side of the observed spread: the limit is
``committed * (1 + tol)``, so a conservative (high) committed ratio
absorbs machine-state swings without false-failing, while order-of-
magnitude regressions (the 3.5-7x class this kernel rework fixed) are
still caught in every observed state.
"""

import argparse
import json
import os
import sys

from .common import REPO_ROOT
from .fig2_cost_ratio import bench_event_delivery


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", type=int, default=None,
                    help="default: the baseline's grid")
    ap.add_argument("--n-per-col", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None,
                    help="default: the baseline's step count")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional ratio regression "
                         "(0.25 = 25%%)")
    ap.add_argument("--baseline",
                    default=os.path.join(REPO_ROOT,
                                         "BENCH_event_delivery.json"))
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = json.load(f)
    grid_y, grid_x, n_per_col = (int(v) for v in base["grid"].split("x"))
    assert grid_y == grid_x, "baseline grid is square by construction"
    grid = args.grid if args.grid is not None else grid_y
    npc = args.n_per_col if args.n_per_col is not None else n_per_col
    steps = args.steps if args.steps is not None else int(base["steps"])

    with_plastic = "plastic" in base
    fresh = bench_event_delivery(
        grid=grid, n_per_col=npc, steps=steps, update_root=False,
        include_plastic=with_plastic,
        plastic_steps=int(base["plastic"]["steps"]) if with_plastic
        else 300)
    failed = False
    for law, ab in fresh["laws"].items():
        committed = base["laws"][law]["kernel_vs_xla_wall_ratio"]
        measured = ab["kernel_vs_xla_wall_ratio"]
        limit = committed * (1.0 + args.tol)
        bad = measured > limit
        failed |= bad
        print(f"{law}: kernel/xla wall ratio {measured:.3f} "
              f"(committed {committed:.3f}, limit {limit:.3f}) "
              f"{'REGRESSION' if bad else 'ok'}")
    if with_plastic:
        for law, ab in fresh["plastic"]["laws"].items():
            committed = base["plastic"]["laws"][law][
                "fused_vs_twopass_wall_ratio"]
            measured = ab["fused_vs_twopass_wall_ratio"]
            limit = committed * (1.0 + args.tol)
            bad = measured > limit
            failed |= bad
            print(f"{law}: plastic fused/two-pass wall ratio "
                  f"{measured:.3f} (committed {committed:.3f}, "
                  f"limit {limit:.3f}) "
                  f"{'REGRESSION' if bad else 'ok'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
