"""LM micro-benchmarks: reduced-config train/decode steps per family
(CPU wall time -- regression tracking, not roofline)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import LMBatchPipeline
from repro.models.config import ShapeConfig
from repro.models.model import make_serve_step, make_train_step
from repro.models.transformer import init_decode_state, init_model
from repro.optim import adamw
from repro.optim.schedules import constant
from repro.parallel.sharding import MeshRules

from .common import write_json

RULES = MeshRules(batch=None, fsdp=None, heads=None, mlp=None,
                  experts=None, vocab=None, kv_seq=None, d_inner=None)
ARCHS = ["qwen2-1.5b", "falcon-mamba-7b", "recurrentgemma-9b",
         "granite-moe-1b-a400m", "whisper-small"]


def bench_attention_ab(cfg, batch=2, seq=64, iters=3) -> dict:
    """Kernel-vs-XLA A/B on this arch's attention shape: the Pallas
    flash-attention kernel (compiled on TPU, interpret elsewhere) vs the
    jnp reference.  Returns per-call medians in ms."""
    from repro.kernels import ops as kops
    h = cfg.n_heads or 4
    kv = cfg.n_kv_heads or h
    d = cfg.resolved_head_dim or 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((batch * h, seq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((batch * kv, seq, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((batch * kv, seq, d)), jnp.float32)
    out = {}
    for col, impl in (("kernel", "auto"), ("xla", "ref")):
        f = jax.jit(lambda q, k, v, impl=impl: kops.attention(
            q, k, v, causal=True, impl=impl, block_q=32, block_k=32))
        jax.block_until_ready(f(q, k, v))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(f(q, k, v))
            ts.append(time.perf_counter() - t0)
        out[f"attn_{col}_ms"] = round(float(np.median(ts)) * 1e3, 3)
    out["attn_kernel_vs_xla"] = round(
        out["attn_kernel_ms"] / max(out["attn_xla_ms"], 1e-9), 2)
    return out


def bench_arch(arch: str, batch=2, seq=64, iters=3) -> dict:
    cfg = get_reduced(arch)
    shape = ShapeConfig("bench", seq, batch, "train")
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    pipe = LMBatchPipeline(cfg=cfg, shape=shape, seed=0)
    b = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, RULES, opt))
    params2, opt_state2, outm = step(params, opt_state, b)  # compile
    jax.block_until_ready(outm["loss"])
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        params2, opt_state2, outm = step(params2, opt_state2, b)
        jax.block_until_ready(outm["loss"])
        ts.append(time.perf_counter() - t0)
    train_s = float(np.median(ts))

    st = init_decode_state(cfg, batch, seq)
    serve = jax.jit(make_serve_step(cfg, RULES))
    tok = b["tokens"][:, :1]
    lg, st = serve(params, st, tok, jnp.int32(0))
    jax.block_until_ready(lg)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        lg, st = serve(params, st, tok, jnp.int32(i + 1))
        jax.block_until_ready(lg)
        ts.append(time.perf_counter() - t0)
    decode_s = float(np.median(ts))
    return {
        "arch": arch,
        "train_step_s": round(train_s, 4),
        "train_tokens_per_s": round(batch * seq / train_s, 1),
        "decode_ms_per_token": round(decode_s * 1e3, 2),
        "loss": float(outm["loss"]),
        **bench_attention_ab(cfg, batch=batch, seq=seq, iters=iters),
    }


def run_bench() -> dict:
    rows = [bench_arch(a) for a in ARCHS]
    out = {"rows": rows}
    write_json("lm_micro.json", out)
    return out


def main():
    for r in run_bench()["rows"]:
        print(f"{r['arch']:24s} train {r['train_step_s']*1e3:8.1f} ms "
              f"({r['train_tokens_per_s']:8.1f} tok/s)  "
              f"decode {r['decode_ms_per_token']:6.2f} ms/tok  "
              f"attn k/x {r['attn_kernel_vs_xla']:5.2f}  "
              f"loss {r['loss']:.3f}")


if __name__ == "__main__":
    main()
