"""Paper Figure 3: memory occupation in bytes/synapse.

Claim: bytes/synapse is ~flat across connectivity scheme and problem
size (memory is synapse-dominated), and the exponential law's memory
envelope -- not compute -- sets the maximum problem size.  Every byte
saved per synapse is a proportionally larger grid per host, so this
benchmark doubles as the producer of the committed repo-root
``BENCH_memory.json`` trajectory that ``benchmarks.memory_guard``
gates in CI.

Accounting covers *everything the engine holds live per shard* (see
``core.metrics.shard_memory_bytes``): synapse tables sized by their
``TableStorage`` descriptor, neuron state, delayed-current rings, the
active mask, and -- where requested -- the STDP carry and the spike
recorder buffer.  Tables-only numbers are reported alongside for
comparison with the pre-compression trajectory.

Three sections:

- ``analytic``: per paper case x shard count, dense (pre-compression
  int32 targets / float32 weights at analytic caps) vs packed (int16
  targets / bfloat16 weights) bytes/synapse.
- ``laws`` (measured, 8x8x60 single shard): materialized tables per
  law; the committed ``compressed.bytes_per_synapse`` is the guard
  baseline.  ``reduction_vs_dense`` is the acceptance ratio.  Each law
  also carries ``plastic_analytic`` (STDP accounting post fold-away:
  the scan carry holds the only full-width weights, the static tables
  keep the int8 mask) and ``carry`` -- the *measured* combined
  plastic + recording carry buffers of a segmented run, both gated by
  the memory guard.
- ``materialized``: a real >= 16x16x60 single-host run (build +
  ``simulate`` for a few steps) proving the compressed tables hold up
  at the next grid size, with its measured bytes/synapse.
"""

import dataclasses

import jax
import numpy as np

from repro.configs.snn import CASES, reduced_case
from repro.core.engine import (build_shard_tables, firing_rate_hz,
                               init_plasticity, init_sim_state, simulate)
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.metrics import bytes_per_synapse, shard_memory_bytes
from repro.core.synapses import (SynapseTableSpec, TableStorage,
                                 materialized_table_bytes)

from .common import write_json


def dense_storage(spec: SynapseTableSpec) -> TableStorage:
    """The pre-compression storage layout: int32 target ids, float32
    weights, analytic (uncompressed) row capacities."""
    return TableStorage(tgt_dtype="int32", weight_dtype="float32",
                        cap_local=spec.cap_local,
                        halo_caps=tuple(spec.band_caps()))


def analytic_rows(shard_counts=(16, 64, 256)) -> list:
    """Dense-vs-packed bytes/synapse for the paper's six configurations
    over a sweep of shard counts (analytic caps; full accounting)."""
    rows = []
    for name, case in CASES.items():
        law = case.connectivity()
        for n in shard_counts:
            ty = int(np.sqrt(n))
            dec = TileDecomposition(
                grid=ColumnGrid(*case.grid), tiles_y=ty, tiles_x=n // ty,
                radius=law.radius)
            spec = SynapseTableSpec(decomp=dec, law=law,
                                    weight_dtype="bfloat16")
            rows.append({
                "case": name, "shards": n,
                "bytes_per_synapse_dense":
                    round(bytes_per_synapse(spec, dense_storage(spec)), 2),
                "bytes_per_synapse":
                    round(bytes_per_synapse(spec), 2),
            })
    return rows


def _full(spec, storage, n_synapses) -> dict:
    mem = shard_memory_bytes(spec, storage)
    return {"breakdown": {k: int(v) for k, v in mem.items()},
            "bytes_per_synapse": round(mem["total"] / n_synapses, 3)}


def _nbytes(tree) -> int:
    return int(sum(np.asarray(leaf).nbytes
                   for leaf in jax.tree.leaves(tree)))


def measured_carry(case, segment_steps: int = 50) -> dict:
    """Real buffer bytes of the combined plastic + recording carry.

    Builds the actual single-shard plastic run state at this config --
    live weight tiers (post fold-away, the carry is the ONLY full-width
    weight copy), the local pre-trace, post-traces, the inverse
    (target -> slot) index, and the spike recorder's per-segment buffer
    at its no-drop default capacity (``active_cap_local *
    segment_steps``) -- and sums what the buffers really occupy,
    alongside the ``shard_memory_bytes`` analytic for the same
    accounting.  This is everything a segmented plastic+recording run
    holds live beyond the static-run footprint.
    """
    from repro.core.stdp import STDPParams
    from repro.obs.record import init_recorder_state, recorder_spec

    cfg = case.engine_config(1, 1, stdp=STDPParams())
    tabs = build_shard_tables(cfg)
    aux = init_plasticity(tabs, cfg)
    rspec = recorder_spec(cfg, segment_steps)
    rec = init_recorder_state(rspec)
    tiers = [tabs["local"]] + list(tabs.get("halo", []))
    breakdown = {
        "weight_tiers": _nbytes([t["w"] for t in tiers]),
        "pre_trace": _nbytes(aux["traces"]["x_pre"][:1]),
        "post_traces": _nbytes(aux["traces"]["x_post"]),
        "inverse_index": _nbytes(aux["inv"]),
        "recorder": _nbytes(rec),
    }
    total = sum(breakdown.values())
    n_syn = int(tabs.stats["n_synapses"])
    spec = cfg.spec()
    amem = shard_memory_bytes(spec, tabs.storage, plastic=True,
                              recorder_capacity=rspec.capacity)
    analytic = int(amem["plastic"] + amem["recorder"])
    return {
        "segment_steps": segment_steps,
        "recorder_capacity": int(rspec.capacity),
        "n_synapses": n_syn,
        "measured": {"breakdown": breakdown, "total": int(total),
                     "bytes_per_synapse": round(total / n_syn, 3)},
        "analytic": {"total": analytic,
                     "bytes_per_synapse": round(analytic / n_syn, 3)},
    }


def measured_law(law_name: str, grid: int = 8,
                 n_per_column: int = 60) -> dict:
    """Materialized single-shard tables for one law: pre-compression
    vs compressed bytes/synapse over realized synapse counts."""
    case = reduced_case(law_name, grid=grid, n_per_column=n_per_column)
    cfg = case.engine_config(1, 1)
    spec = cfg.spec()
    tabs = build_shard_tables(cfg)          # compressed by default
    n_syn = int(tabs.stats["n_synapses"])
    dense_st = dense_storage(spec)
    out = {
        "case": case.name,
        "n_synapses": n_syn,
        # what the pre-compression code measured (tables only, dense):
        "tables_only": {
            "dense_bytes": int(spec.table_bytes(dense_st)),
            "compressed_bytes": int(materialized_table_bytes(tabs)),
        },
        "dense": _full(spec, dense_st, n_syn),
        "compressed": _full(spec, tabs.storage, n_syn),
        "storage": tabs.storage.meta(),
    }
    to = out["tables_only"]
    to["reduction"] = round(to["dense_bytes"] / to["compressed_bytes"], 3)
    out["reduction_vs_dense"] = round(
        out["dense"]["bytes_per_synapse"]
        / out["compressed"]["bytes_per_synapse"], 3)
    # STDP adds a weight-tier carry + traces + inverse index; plastic
    # specs force float32 weights and halo_floor=0, so account on the
    # plastic spec, not this one.  Post fold-away the carry is the
    # single full-width weight copy: the static tables' weight leaves
    # shrink to the int8 mask, and the halo pre-trace replicas are
    # exchanged per step instead of stored.
    pspec = dataclasses.replace(spec, weight_dtype="float32",
                                halo_floor=0.0)
    pmem = shard_memory_bytes(pspec, plastic=True)
    out["plastic_analytic"] = {
        "breakdown": {k: int(v) for k, v in pmem.items()},
        "bytes_per_synapse": round(
            pmem["total"] / pspec.expected_synapses(), 3),
    }
    out["carry"] = measured_carry(case)
    return out


def measured_materialized(grid: int = 16, n_per_column: int = 60,
                          steps: int = 20) -> list:
    """Build + run a real single-host simulation at ``grid`` (>= 2x the
    8x8 acceptance config in columns): proof the compressed tables
    materialize and deliver at the next problem size."""
    out = []
    for law_name in ("gaussian", "exponential"):
        case = reduced_case(law_name, grid=grid, n_per_column=n_per_column)
        cfg = case.engine_config(1, 1, use_kernels=False)
        spec = cfg.spec()
        tabs = build_shard_tables(cfg)
        state = init_sim_state(cfg)
        state, _ = simulate(state, tabs, cfg, steps)
        n_syn = int(tabs.stats["n_synapses"])
        mem = shard_memory_bytes(spec, tabs.storage)
        out.append({
            "case": case.name,
            "steps": steps,
            "completed": True,
            "rate_hz": round(float(firing_rate_hz(state, cfg)), 3),
            "n_synapses": n_syn,
            "table_bytes": int(materialized_table_bytes(tabs)),
            "bytes_per_synapse": round(mem["total"] / n_syn, 3),
            "storage": tabs.storage.meta(),
        })
    return out


def run_bench(update_root: bool = False,
              include_materialized: bool = True,
              materialized_grid: int = 16) -> dict:
    laws = {law: measured_law(law) for law in ("gaussian", "exponential")}
    rows = analytic_rows()
    vals = [r["bytes_per_synapse"] for r in rows]
    out = {
        "config": "8x8x60",
        "laws": laws,
        "analytic": rows,
        "mean_bytes_per_synapse": float(np.mean(vals)),
        "rel_std": float(np.std(vals) / np.mean(vals)),
        "reference": ("paper Fig. 3: ~flat bytes/synapse across configs; "
                      "the exponential law's memory envelope bounds the "
                      "maximum problem size"),
    }
    if include_materialized:
        out["materialized"] = measured_materialized(grid=materialized_grid)
    write_json("BENCH_memory.json", out, also_root=update_root)
    return out


def main():
    out = run_bench(update_root=False)
    for m in out["laws"].values():
        print(f"{m['case']:28s} dense {m['dense']['bytes_per_synapse']:6.2f}"
              f" -> compressed {m['compressed']['bytes_per_synapse']:6.2f}"
              f" B/syn  ({m['reduction_vs_dense']:.2f}x, "
              f"{m['n_synapses']} syn)")
    for r in out.get("materialized", []):
        print(f"{r['case']:28s} materialized run: {r['steps']} steps, "
              f"{r['rate_hz']:.2f} Hz, {r['bytes_per_synapse']:6.2f} B/syn")
    print(f"analytic mean {out['mean_bytes_per_synapse']:.1f} B/syn, "
          f"rel std {out['rel_std']:.1%} (paper: ~flat across configs)")


if __name__ == "__main__":
    main()
