"""Paper Figure 3: memory occupation in bytes/synapse.

Claim: bytes/synapse is ~flat across connectivity scheme and problem
size (memory is synapse-dominated).  We compute exact per-shard buffer
footprints (tables + neuron state + rings) for the paper's six
configurations over a sweep of shard counts, plus a *measured* check at
reduced scale where tables actually materialize.
"""

import numpy as np

from repro.configs.snn import CASES
from repro.core.engine import build_shard_tables
from repro.core.grid import ColumnGrid, TileDecomposition
from repro.core.metrics import bytes_per_synapse
from repro.core.synapses import SynapseTableSpec

from .common import write_json


def analytic_rows(shard_counts=(16, 64, 256)) -> list:
    rows = []
    for name, case in CASES.items():
        law = case.connectivity()
        for n in shard_counts:
            ty = int(np.sqrt(n))
            dec = TileDecomposition(
                grid=ColumnGrid(*case.grid), tiles_y=ty, tiles_x=n // ty,
                radius=law.radius)
            spec = SynapseTableSpec(decomp=dec, law=law)
            rows.append({
                "case": name, "shards": n,
                "bytes_per_synapse": round(bytes_per_synapse(spec), 2),
            })
    return rows


def measured_reduced() -> list:
    """Materialized tables at reduced scale: stats from real buffers."""
    out = []
    for law_name in ("gaussian", "exponential"):
        from repro.configs.snn import reduced_case
        case = reduced_case(law_name, grid=8, n_per_column=60)
        cfg = case.engine_config(1, 1)
        tabs = build_shard_tables(cfg)
        out.append({
            "case": case.name,
            "n_synapses": tabs["stats"]["n_synapses"],
            "bytes_per_synapse":
                round(tabs["stats"]["bytes_per_synapse"], 2),
        })
    return out


def run_bench() -> dict:
    rows = analytic_rows()
    vals = [r["bytes_per_synapse"] for r in rows]
    flatness = float(np.std(vals) / np.mean(vals))
    out = {"analytic": rows, "measured_reduced": measured_reduced(),
           "mean_bytes_per_synapse": float(np.mean(vals)),
           "rel_std": flatness}
    write_json("fig3.json", out)
    return out


def main():
    out = run_bench()
    for r in out["analytic"]:
        print(f"{r['case']:28s} shards={r['shards']:4d} "
              f"{r['bytes_per_synapse']:6.2f} B/syn")
    for r in out["measured_reduced"]:
        print(f"{r['case']:28s} measured  {r['bytes_per_synapse']:6.2f} "
              f"B/syn ({r['n_synapses']} syn)")
    print(f"mean {out['mean_bytes_per_synapse']:.1f} B/syn, "
          f"rel std {out['rel_std']:.1%} (paper: ~flat across configs)")


if __name__ == "__main__":
    main()
