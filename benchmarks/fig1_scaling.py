"""Paper Figure 1: strong scaling of cost per synaptic event.

Two complementary reproductions:

  * **measured (reduced scale)**: the per-shard *work* scan -- we time
    event-mode simulation at 1..K shards' worth of tiles on the host
    and derive cost/event; on a single CPU the shards execute serially,
    so we report per-shard work directly (the scaling-relevant unit).
  * **analytic (full scale, TPU target)**: the roofline step-time model
    of core.metrics applied to the paper's six configurations over
    1..1024 shards, yielding speedup efficiency at 96 shards to compare
    with the paper's 57-83% of ideal.
"""

from repro.configs.snn import CASES
from repro.core.metrics import strong_scaling_curve

from .common import write_json

PAPER_EFFICIENCY = {  # paper section 3, at 96 processes
    "snn-24x24-gaussian": 0.70,
    "snn-48x48-gaussian": 0.57,
    "snn-96x96-gaussian": 0.68,
    "snn-24x24-exponential": 0.79,
    "snn-48x48-exponential": 0.83,
}

RATES = {"gaussian": 7.5, "exponential": 35.0}     # paper-observed Hz


def weak_scaling(tile_cols: int = 6) -> list:
    """Weak scaling (DPSNN lineage, arXiv:1310.8478): grow the grid with
    the shard count at a fixed 6x6-column tile -- cost/event should stay
    flat if communication stays surface-like."""
    from repro.core.grid import ColumnGrid, TileDecomposition
    from repro.core.metrics import step_time_model
    from repro.core.synapses import SynapseTableSpec
    from repro.configs.snn import CASES
    rows = []
    for law_name, rate in (("gaussian", 7.5), ("exponential", 35.0)):
        law = CASES[f"snn-48x48-{law_name}"].connectivity()
        for t in (2, 4, 8, 16, 32):
            n = t * t
            dec = TileDecomposition(
                grid=ColumnGrid(t * tile_cols, t * tile_cols),
                tiles_y=t, tiles_x=t, radius=law.radius)
            spec = SynapseTableSpec(decomp=dec, law=law,
                                    single_shard=(n == 1))
            m = step_time_model(spec, rate)
            rows.append({
                "law": law_name, "shards": n,
                "neurons": dec.grid.n_neurons,
                "cost_per_event": m["step_s"] / m["events_per_step"],
            })
    return rows


def run_bench() -> dict:
    shard_counts = [1, 4, 16, 64, 96, 256, 1024]
    out = {"curves": {}, "efficiency_at_96": {},
           "weak_scaling": weak_scaling()}
    for name, case in CASES.items():
        law = case.connectivity()
        rows = strong_scaling_curve(
            case.grid[0], case.grid[1], law, shard_counts,
            RATES[case.law], case.n_per_column)
        out["curves"][name] = rows
        c1 = rows[0]["cost_per_event"]
        c96 = next(r for r in rows if r["shards"] == 96)["cost_per_event"]
        eff = (c1 / c96) / 96
        out["efficiency_at_96"][name] = round(eff, 3)
    out["paper_efficiency_at_96"] = PAPER_EFFICIENCY
    write_json("fig1.json", out)
    return out


def main():
    out = run_bench()
    print("case,efficiency@96(model),paper")
    for name, eff in out["efficiency_at_96"].items():
        paper = PAPER_EFFICIENCY.get(name, "-")
        print(f"{name},{eff},{paper}")
    print("(model: analytic TPU-target roofline; paper: CPU cluster)")
    for law in ("gaussian", "exponential"):
        c = [r["cost_per_event"] for r in out["weak_scaling"]
             if r["law"] == law]
        print(f"weak scaling {law}: cost/event flat within "
              f"{(max(c)/min(c)-1)*100:.0f}% over 4..1024 shards")


if __name__ == "__main__":
    main()
