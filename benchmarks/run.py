"""Run every paper-table benchmark; print a summary per table/figure."""

from . import fig1_scaling, fig2_cost_ratio, fig3_memory, lm_micro, \
    table1_sizes


def main() -> None:
    print("=== Table 1: problem sizes ===")
    table1_sizes.main()
    print("\n=== Figure 2: cost per synaptic event (measured) ===")
    fig2_cost_ratio.main()
    print("\n=== Figure 1: strong scaling ===")
    fig1_scaling.main()
    print("\n=== Figure 3: bytes per synapse ===")
    fig3_memory.main()
    print("\n=== LM micro-benchmarks ===")
    lm_micro.main()


if __name__ == "__main__":
    main()
