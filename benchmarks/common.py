"""Shared benchmark plumbing."""

import json
import os

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def write_json(name: str, payload) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
