"""Shared benchmark plumbing."""

import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO_ROOT, "results")


def write_json(name: str, payload, also_root: bool = False) -> str:
    """Write ``results/<name>``; ``also_root`` additionally writes the
    repo-root copy -- the committed, cross-PR trajectory file (the
    ``results/`` copy is the per-run CI artifact)."""
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    if also_root:
        with open(os.path.join(REPO_ROOT, name), "w") as f:
            json.dump(payload, f, indent=1, default=str)
    return path
